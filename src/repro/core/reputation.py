"""UE reputation (paper §III-B.2, Eq. 1).

    R_k^t = R_k^{t-1} - eta * ( beta1 * (acc_local - avg(acc))
                              + beta2 * (acc_local - acc_test) )

Reputation drops when a UE uploads a bad / poisoned model (its test accuracy
trails the cohort) or when it over-reports its local accuracy versus the
server-side test-set evaluation — catching both malicious and overfitting /
dishonest UEs. Reputations start at 1 (Alg. 1 line 4) and are clipped to
[0, 1] so a long honest history cannot mask a late attack indefinitely.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import FeelConfig


class ReputationTracker:
    def __init__(self, cfg: FeelConfig):
        self.cfg = cfg
        self.values = np.ones(cfg.n_ues)

    def update(self, participants: np.ndarray,
               acc_local: np.ndarray, acc_test: np.ndarray) -> np.ndarray:
        """Apply Eq. 1 to the participating UEs of this round.

        participants — indices; acc_local — self-reported accuracies
        (len == len(participants)); acc_test — server-measured accuracies of
        the uploaded models on the held-out test set.
        """
        cfg = self.cfg
        if len(participants) == 0:
            return self.values
        avg_acc = float(np.mean(acc_local))
        delta = cfg.eta * (cfg.beta1 * (acc_local - avg_acc)
                           + cfg.beta2 * (acc_local - acc_test))
        self.values[participants] = np.clip(
            self.values[participants] - delta, 0.0, 1.0)
        return self.values
