"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)
plus hypothesis property tests on the FedAvg aggregation kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,T,D,causal,window", [
    (2, 4, 256, 256, 64, True, None),
    (1, 2, 128, 256, 64, True, None),      # prefill-style, right-aligned
    (2, 2, 256, 256, 128, True, 64),       # sliding window
    (1, 1, 256, 256, 64, False, None),     # bidirectional (encoder)
    (1, 2, 512, 512, 64, True, None),
])
def test_flash_attention(B, H, S, T, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, S, D), dtype)
    k = _rand(ks[1], (B, H, T, D), dtype)
    v = _rand(ks[2], (B, H, T, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,T,D,length", [
    (2, 4, 512, 64, 300),
    (1, 8, 1024, 128, 1024),
    (4, 2, 256, 64, 1),
])
def test_decode_attention(B, H, T, D, length, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, H, D), dtype)
    k = _rand(ks[1], (B, T, H, D), dtype)
    v = _rand(ks[2], (B, T, H, D), dtype)
    out = ops.decode_attention(q, k, v, length)
    expect = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,L,H,P,N,G,chunk", [
    (2, 256, 4, 32, 16, 4, 64),
    (1, 128, 2, 64, 32, 1, 128),   # grouped B/C broadcast
    (1, 64, 8, 16, 8, 8, 16),
])
def test_ssd_scan(B, L, H, P, N, G, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(0.2 * jax.random.normal(ks[2], (H,)))
    B_ = jax.random.normal(ks[3], (B, L, G, N))
    C_ = jax.random.normal(ks[4], (B, L, G, N))
    y = ops.ssd_scan(x, dt, A, B_, C_, chunk=chunk)
    rep = H // G
    yr, _ = ref.ssd_scan_ref(x, dt, A, jnp.repeat(B_, rep, 2),
                             jnp.repeat(C_, rep, 2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)


def test_ssd_scan_matches_model_path():
    """Kernel == models.ssm.ssd_chunked (the XLA path it replaces)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, L, H, P, N = 2, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(0.2 * jax.random.normal(ks[2], (H,)))
    B_ = jax.random.normal(ks[3], (B, L, H, N))
    C_ = jax.random.normal(ks[4], (B, L, H, N))
    y1 = ops.ssd_scan(x, dt, A, B_, C_, chunk=32)
    y2, _ = ssd_chunked(x, dt, A, B_, C_, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,d,f", [
    (4, 128, 256, 128),
    (8, 64, 128, 384),
    (2, 256, 512, 256),
])
def test_moe_gemm(E, C, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = _rand(ks[0], (E, C, d), dtype)
    w = _rand(ks[1], (E, d, f), dtype)
    out = ops.moe_gemm(x, w, block_c=64, block_f=128, block_k=128)
    expect = ref.moe_gemm_ref(x, w)
    tol = {jnp.float32: 1e-4, jnp.bfloat16: 2e-1}[dtype]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@given(st.integers(1, 7), st.integers(1, 5000), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_weighted_aggregate_property(n, m, seed):
    """FedAvg kernel: matches oracle for arbitrary (N, M); convex combination
    stays within the per-coordinate envelope of the updates."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (n, m))
    w = jnp.abs(jax.random.normal(ks[1], (n,))) + 1e-3
    out = ops.weighted_aggregate(x, w)
    expect = ref.weighted_aggregate_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)
    assert np.all(np.asarray(out) <= np.asarray(x.max(0)) + 1e-5)
    assert np.all(np.asarray(out) >= np.asarray(x.min(0)) - 1e-5)


def test_weighted_aggregate_tree():
    """Tree wrapper == leaf-wise ref twin on a ragged-shape pytree."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    stacked = {"w": jax.random.normal(ks[0], (4, 3, 5)),
               "b": jax.random.normal(ks[1], (4, 5))}
    w = jnp.abs(jax.random.normal(ks[2], (4,))) + 1e-3
    out = ops.weighted_aggregate_tree(stacked, w)
    expect = ref.weighted_aggregate_tree_ref(stacked, w)
    for k in stacked:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(expect[k]),
                                   atol=1e-5, rtol=1e-5)
        assert out[k].shape == stacked[k].shape[1:]


@pytest.mark.parametrize("mode", ["trimmed_mean", "median"])
@pytest.mark.parametrize("n,n_pad,m", [(5, 8, 300), (8, 8, 2048),
                                       (13, 16, 700)])
def test_robust_aggregate_kernel(mode, n, n_pad, m):
    """Defense-plane kernel (sort/select over the stacked-client axis):
    matches the jnp ref twin to documented-ulp on real rows with padding
    rows riding along under the +inf sentinel."""
    rng = np.random.default_rng(n * 1000 + m)
    x = np.zeros((n_pad, m), np.float32)
    x[:n] = rng.normal(size=(n, m)).astype(np.float32)
    xj = jnp.asarray(x)
    trim = max(int(0.2 * n), 0) if mode == "trimmed_mean" else 0
    out = ops.robust_aggregate(xj, n, trim=trim, mode=mode, block_m=256)
    expect = ref.robust_aggregate_ref(xj, n, trim=trim, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6, rtol=1e-6)
    # envelope: a rank-window statistic stays within the real rows
    assert np.all(np.asarray(out) <= x[:n].max(0) + 1e-6)
    assert np.all(np.asarray(out) >= x[:n].min(0) - 1e-6)


def test_robust_aggregate_kernel_matches_host_oracle():
    """Kernel == the defense plane's host numpy oracle (same rank
    window), so REPRO_USE_PALLAS=1 swaps implementations, not results."""
    from repro.core import defenses as dfs
    rng = np.random.default_rng(7)
    n, n_pad = 11, 16
    x = np.zeros((n_pad, 400), np.float32)
    x[:n] = rng.normal(size=(n, 400)).astype(np.float32)
    tm = dfs.TrimmedMean(0.2)
    host, _ = tm.aggregate_host(x[:n])
    kern, _ = tm.aggregate_batched(jnp.asarray(x), n, kernel=True)
    np.testing.assert_allclose(host, np.asarray(kern), atol=1e-6,
                               rtol=1e-6)
    md = dfs.Median()
    host_m, _ = md.aggregate_host(x[:n])
    kern_m, _ = md.aggregate_batched(jnp.asarray(x), n, kernel=True)
    np.testing.assert_array_equal(host_m, np.asarray(kern_m))
