"""Regression tests for the single bench-results writer
(benchmarks/bench_round.py::write_bench_json).

The bug under pin: the old writer ran ``payload.pop("bench", ...)`` on
the CALLER's dict, so the first canonical write silently stripped the
"bench" key and any second write of the same payload landed under the
wrong record name. The writer must treat its input as read-only.
"""
import copy
import json
import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from benchmarks.bench_round import write_bench_json  # noqa: E402


def test_payload_dict_is_not_mutated(tmp_path):
    payload = {"bench": "writer_regression", "cells": [{"acc": 0.9}],
               "note": "pinned"}
    snapshot = copy.deepcopy(payload)
    write_bench_json("writer_regression", payload,
                     results_dir=str(tmp_path))
    assert payload == snapshot
    # a second write of the SAME dict must behave identically — the old
    # pop-based writer lost "bench" here
    write_bench_json("writer_regression", payload,
                     results_dir=str(tmp_path))
    assert payload == snapshot


def test_record_schema_and_history(tmp_path):
    payload = {"bench": "schema_probe", "cells": [1, 2, 3]}
    write_bench_json("schema_probe", payload, results_dir=str(tmp_path))
    with open(tmp_path / "BENCH_schema_probe.json") as f:
        record = json.load(f)
    assert record["bench"] == "schema_probe"
    assert record["cells"] == [1, 2, 3]
    assert "bench" not in record["meta"]
    for key in ("commit", "python", "timestamp"):
        assert key in record["meta"], record["meta"]
    with open(tmp_path / "BENCH_history.jsonl") as f:
        lines = f.read().splitlines()
    assert len(lines) == 1
    assert json.loads(lines[0])["bench"] == "schema_probe"


def test_non_canonical_write_is_skipped(tmp_path, capsys):
    write_bench_json("adhoc", {"bench": "adhoc"}, canonical=False,
                     results_dir=str(tmp_path))
    assert not os.path.exists(tmp_path / "BENCH_adhoc.json")
    assert not os.path.exists(tmp_path / "BENCH_history.jsonl")
    assert "non-canonical" in capsys.readouterr().err
