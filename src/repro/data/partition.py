"""Non-IID federated partition (paper §V-A "Data distribution").

Sort the training data by label, form groups of 50 same-digit images, then
allocate uniformly between 1 and 30 groups to each of the K UEs (the paper
states 1200 groups; with 50,000 training samples the scheme yields
len(train)//50 groups — the allocation protocol is identical). Groups are
drawn without replacement, so datasets are unbalanced AND class-skewed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.poisoning import LabelFlipAttack
from repro.data.synthetic_mnist import Dataset

GROUP_SIZE = 50
MIN_GROUPS = 1
MAX_GROUPS = 30


@dataclasses.dataclass
class ClientData:
    ue_id: int
    data: Dataset
    malicious: bool = False

    @property
    def size(self) -> int:
        return len(self.data)


def partition(train: Dataset, n_ues: int, rng: np.random.Generator,
              malicious: Optional[np.ndarray] = None,
              attack: Optional[LabelFlipAttack] = None) -> List[ClientData]:
    order = np.argsort(train.y, kind="stable")
    n_groups = len(train) // GROUP_SIZE
    groups = order[: n_groups * GROUP_SIZE].reshape(n_groups, GROUP_SIZE)

    perm = rng.permutation(n_groups)
    counts = rng.integers(MIN_GROUPS, MAX_GROUPS + 1, size=n_ues)
    # truncate if the draw exceeds the pool (keeps the protocol well-defined)
    while counts.sum() > n_groups:
        counts[np.argmax(counts)] -= 1

    clients, cursor = [], 0
    mal = set(malicious.tolist()) if malicious is not None else set()
    for k in range(n_ues):
        take = perm[cursor: cursor + counts[k]]
        cursor += counts[k]
        idx = groups[take].reshape(-1)
        ds = train.subset(idx)
        is_mal = k in mal
        if is_mal and attack is not None:
            ds = Dataset(ds.x, attack.apply(ds.y, rng))
        clients.append(ClientData(ue_id=k, data=ds, malicious=is_mal))
    return clients


def label_histogram(ds: Dataset, n_classes: int = 10) -> np.ndarray:
    return np.bincount(ds.y.astype(int), minlength=n_classes)
