"""Pallas TPU FedAvg weighted aggregation (the paper's Alg. 1 line 13):

    g = sum_k (D_k / D_t) Omega_k

over N stacked client updates, flattened to (N, M). Grid (n_m,) over the
parameter dimension; the normalised weight vector sits in SMEM; each step
reduces an (N, block_m) tile to (block_m,). The aggregation is bandwidth-bound
(reads N x M, writes M), so block_m just needs to keep tiles VMEM-resident —
default 2048 floats x N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_kernel(w_ref, x_ref, o_ref, *, n):
    x = x_ref[...].astype(jnp.float32)                    # (N, bm)
    acc = jnp.zeros((x.shape[1],), jnp.float32)
    for i in range(n):                                    # N is small, unroll
        acc += w_ref[i] * x[i]
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret",
                                              "assume_normalized"))
def weighted_aggregate(stacked, weights, *, block_m=2048, interpret=False,
                       assume_normalized=False):
    """stacked (N, M), weights (N,) -> (M,) weighted mean.

    assume_normalized — weights already sum to 1 (e.g. pre-normalised in
    float64 by ``federated.aggregation``); skip the in-graph renormalisation
    so the caller's rounding is preserved exactly.
    """
    N, M = stacked.shape
    block_m = min(block_m, M)
    pad = (-M) % block_m
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    Mp = M + pad
    if assume_normalized:
        w = jnp.asarray(weights, jnp.float32)
    else:
        w = (weights / jnp.maximum(weights.sum(), 1e-9)).astype(jnp.float32)

    kernel = functools.partial(_agg_kernel, n=N)
    out = pl.pallas_call(
        kernel,
        grid=(Mp // block_m,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((N, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Mp,), stacked.dtype),
        interpret=interpret,
    )(w, stacked)
    return out[:M]
