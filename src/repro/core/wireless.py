"""Wireless edge model (paper §III-C, Eq. 4-7, 9).

OFDMA uplink from K UEs to one BS at the centre of a square cell. Channel
gain = large-scale pathloss x Rayleigh small-scale fading:
``|g_k|^2 = d_k^-alpha |h_k|^2``. Achievable rate with bandwidth fraction
``a_k`` (Eq. 4):

    r_k = a_k B log2(1 + g_k P_k / (a_k B N0))

Round deadline T bounds ``t_train + t_up`` (Eq. 5); training time follows the
cycles/bit model (Eq. 6); upload time ``t_up = s / r_k`` (Eq. 7). The DQS
bandwidth *cost* c_k (Eq. 9) is the minimum number of uniform 1/K fractions
that meets the UE's minimum rate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import FeelConfig


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) / 1000.0


@dataclasses.dataclass
class ChannelState:
    """Per-round channel realisation for K UEs."""
    gains: np.ndarray          # |g_k|^2, linear
    distances: np.ndarray      # d_k in metres

    @property
    def k(self) -> int:
        return self.gains.shape[0]


class WirelessModel:
    def __init__(self, cfg: FeelConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng
        half = cfg.cell_side_m / 2.0
        xy = rng.uniform(-half, half, size=(cfg.n_ues, 2))
        self.distances = np.maximum(np.linalg.norm(xy, axis=1), 1.0)
        self.p_watt = dbm_to_watt(cfg.tx_power_dbm)
        self.n0 = dbm_to_watt(cfg.noise_dbm_hz)     # W/Hz

    def draw_channels(self) -> ChannelState:
        """Rayleigh |h|^2 ~ Exp(1); gains = d^-alpha |h|^2."""
        h2 = self.rng.exponential(1.0, size=self.distances.shape)
        gains = self.distances ** (-self.cfg.pathloss_exp) * h2
        return ChannelState(gains=gains, distances=self.distances)

    # ------------------------------------------------------------------ #
    # Eq. 4 / 7 / 6
    # ------------------------------------------------------------------ #
    def rate(self, gains: np.ndarray, alpha: np.ndarray) -> np.ndarray:
        """Eq. 4 — vectorised; rate is 0 where alpha == 0."""
        cfg = self.cfg
        alpha = np.asarray(alpha, float)
        with np.errstate(divide="ignore", invalid="ignore"):
            snr = gains * self.p_watt / (alpha * cfg.bandwidth_hz * self.n0)
            r = alpha * cfg.bandwidth_hz * np.log2(1.0 + snr)
        return np.where(alpha > 0, r, 0.0)

    def upload_time(self, gains, alpha) -> np.ndarray:
        r = self.rate(gains, alpha)
        with np.errstate(divide="ignore"):
            return np.where(r > 0, self.cfg.model_size_bits / r, np.inf)

    def train_time(self, dataset_sizes: np.ndarray,
                   cpu_hz: np.ndarray) -> np.ndarray:
        """Eq. 6: t = eps * |D_k| * zeta / f."""
        cfg = self.cfg
        bits = dataset_sizes * cfg.sample_bits
        return cfg.local_epochs * bits * cfg.cycles_per_bit / cpu_hz

    # ------------------------------------------------------------------ #
    # Eq. 9 — bandwidth cost in uniform 1/K fractions
    # ------------------------------------------------------------------ #
    def min_rate(self, train_times: np.ndarray) -> np.ndarray:
        """r_min = s / (T - t_train); inf when the deadline is already blown."""
        slack = self.cfg.deadline_s - train_times
        with np.errstate(divide="ignore"):
            return np.where(slack > 0, self.cfg.model_size_bits / slack, np.inf)

    def cost(self, gains: np.ndarray, train_times: np.ndarray) -> np.ndarray:
        """c_k = min{c in [1,K] : r_k(c/K) >= r_min}; K+1 when infeasible."""
        K = self.cfg.n_ues
        r_min = self.min_rate(train_times)                      # (K,)
        cs = np.arange(1, K + 1) / K                            # (K,) fractions
        rates = self.rate(gains[:, None], cs[None, :])          # (K, K)
        feasible = rates >= r_min[:, None]
        c = np.where(feasible.any(1), feasible.argmax(1) + 1, K + 1)
        return c.astype(int)
