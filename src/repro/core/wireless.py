"""Wireless edge model (paper §III-C, Eq. 4-7, 9).

OFDMA uplink from K UEs to one BS at the centre of a square cell. Channel
gain = large-scale pathloss x Rayleigh small-scale fading:
``|g_k|^2 = d_k^-alpha |h_k|^2``. Achievable rate with bandwidth fraction
``a_k`` (Eq. 4):

    r_k = a_k B log2(1 + g_k P_k / (a_k B N0))

Round deadline T bounds ``t_train + t_up`` (Eq. 5); training time follows the
cycles/bit model (Eq. 6); upload time ``t_up = s / r_k`` (Eq. 7). The DQS
bandwidth *cost* c_k (Eq. 9) is the minimum number of uniform 1/K fractions
that meets the UE's minimum rate.

Eq. 9 is solved by monotone bisection: r_k(c/K) is strictly increasing in c
(Eq. 4 is concave increasing in the bandwidth fraction), so the minimal
feasible c is found in O(log K) rate evaluations per UE instead of the
seed's dense (K, K) rate matrix — O(K log K) total, which is what lets the
control plane scale to thousands of UEs. ``cost_scan`` keeps the exhaustive
scan as the test oracle (tests/test_wireless.py pins exact equality,
including the infeasible c = K+1 and blown-deadline t_train >= T edges).

The module also exposes the pure-JAX twins (``rate_eq4``, ``cost_bisect``)
used by the batched control plane (core/control.py): same formulas over
arbitrary leading batch axes, jit/vmap-able, run in float64 (under
``jax.experimental.enable_x64``) so they agree with the numpy oracle to
the last integer cost. The Eq. 9 right-hand side (min rates) is
round-invariant, so the control plane precomputes it once per run with
the numpy ``min_rate`` — there is deliberately no jnp twin for it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FeelConfig, dbm_to_watt  # noqa: F401
# dbm_to_watt is defined beside FeelConfig's p_watt/n0_watt_hz properties
# (one conversion shared by both control planes) and re-exported here for
# the historical import path.


@dataclasses.dataclass
class ChannelState:
    """Per-round channel realisation for K UEs."""
    gains: np.ndarray          # |g_k|^2, linear
    distances: np.ndarray      # d_k in metres

    @property
    def k(self) -> int:
        return self.gains.shape[0]


class WirelessModel:
    def __init__(self, cfg: FeelConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng
        half = cfg.cell_side_m / 2.0
        # one position per *candidate* (N == K when no population is set);
        # Eq. 9's budget/denominator stays cfg.n_ues in cost()/cost_scan()
        xy = rng.uniform(-half, half, size=(cfg.n_population, 2))
        self.distances = np.maximum(np.linalg.norm(xy, axis=1), 1.0)
        self.p_watt = cfg.p_watt
        self.n0 = cfg.n0_watt_hz     # W/Hz
        # AR(1)/Gauss-Markov fading state (DESIGN.md §13): complex h per
        # candidate, components N(0, 1/2) so |h|^2 is stationary Exp(1).
        # Only touched when cfg.channel_corr > 0 — the rho = 0 path keeps
        # the legacy memoryless exponential draw bit-for-bit.
        self._h: Optional[np.ndarray] = None       # (N, 2) re/im
        self.last_gains: Optional[np.ndarray] = None

    def draw_channels(self) -> ChannelState:
        """Rayleigh |h|^2 ~ Exp(1); gains = d^-alpha |h|^2.

        With ``cfg.channel_corr = rho > 0`` the small-scale component is a
        per-UE Gauss-Markov process ``h_t = rho h_{t-1} + sqrt(1-rho^2) w_t``
        (w complex, components N(0, 1/2)): stationary |h|^2 ~ Exp(1) as in
        the memoryless model, lag-1 correlation of |h|^2 equal to rho^2.
        rho = 0 (the default) draws the exact legacy exponential variate so
        existing goldens pin bit-for-bit.
        """
        rho = self.cfg.channel_corr
        if rho == 0.0:
            h2 = self.rng.exponential(1.0, size=self.distances.shape)
        else:
            w = self.rng.standard_normal(self.distances.shape + (2,)) \
                * np.sqrt(0.5)
            if self._h is None:
                self._h = w
            else:
                self._h = rho * self._h + np.sqrt(1.0 - rho * rho) * w
            h2 = (self._h ** 2).sum(axis=-1)
        gains = self.distances ** (-self.cfg.pathloss_exp) * h2
        self.last_gains = gains
        return ChannelState(gains=gains, distances=self.distances)

    # ------------------------------------------------------------------ #
    # Eq. 4 / 7 / 6
    # ------------------------------------------------------------------ #
    def rate(self, gains: np.ndarray, alpha: np.ndarray) -> np.ndarray:
        """Eq. 4 — vectorised; rate is 0 where alpha == 0."""
        cfg = self.cfg
        alpha = np.asarray(alpha, float)
        with np.errstate(divide="ignore", invalid="ignore"):
            snr = gains * self.p_watt / (alpha * cfg.bandwidth_hz * self.n0)
            r = alpha * cfg.bandwidth_hz * np.log2(1.0 + snr)
        return np.where(alpha > 0, r, 0.0)

    def upload_time(self, gains, alpha) -> np.ndarray:
        r = self.rate(gains, alpha)
        with np.errstate(divide="ignore"):
            return np.where(r > 0, self.cfg.model_size_bits / r, np.inf)

    def train_time(self, dataset_sizes: np.ndarray,
                   cpu_hz: np.ndarray) -> np.ndarray:
        """Eq. 6: t = eps * |D_k| * zeta / f."""
        cfg = self.cfg
        bits = dataset_sizes * cfg.sample_bits
        return cfg.local_epochs * bits * cfg.cycles_per_bit / cpu_hz

    # ------------------------------------------------------------------ #
    # Eq. 9 — bandwidth cost in uniform 1/K fractions
    # ------------------------------------------------------------------ #
    def min_rate(self, train_times: np.ndarray) -> np.ndarray:
        """r_min = s / (T - t_train); inf when the deadline is already blown."""
        slack = self.cfg.deadline_s - train_times
        with np.errstate(divide="ignore"):
            return np.where(slack > 0, self.cfg.model_size_bits / slack, np.inf)

    def cost(self, gains: np.ndarray, train_times: np.ndarray) -> np.ndarray:
        """c_k = min{c in [1,K] : r_k(c/K) >= r_min}; K+1 when infeasible.

        Monotone bisection (see module docstring): rate is strictly
        increasing in c, so binary search over the integers [1, K] finds
        the same minimum the exhaustive scan finds, in O(log K) rate
        evaluations. Infeasibility (including a blown deadline, r_min =
        inf) is decided up front by probing the whole band (c = K).
        """
        K = self.cfg.n_ues
        r_min = self.min_rate(train_times)                      # (K,)
        feasible = self.rate(gains, np.ones_like(gains)) >= r_min
        lo = np.ones(gains.shape, int)
        hi = np.full(gains.shape, K, int)
        while np.any(lo < hi):
            mid = (lo + hi) // 2
            ok = self.rate(gains, mid / K) >= r_min
            lo = np.where(ok, lo, mid + 1)
            hi = np.where(ok, mid, hi)
        return np.where(feasible, lo, K + 1).astype(int)

    def cost_scan(self, gains: np.ndarray,
                  train_times: np.ndarray) -> np.ndarray:
        """Exhaustive Eq. 9 (the seed's dense (K, K) rate matrix) — kept as
        the O(K^2) test oracle for ``cost``."""
        K = self.cfg.n_ues
        r_min = self.min_rate(train_times)                      # (K,)
        cs = np.arange(1, K + 1) / K                            # (K,) fractions
        rates = self.rate(gains[:, None], cs[None, :])          # (K, K)
        feasible = rates >= r_min[:, None]
        c = np.where(feasible.any(1), feasible.argmax(1) + 1, K + 1)
        return c.astype(int)


# ---------------------------------------------------------------------- #
# Pure-JAX twins (batched control plane) — arbitrary leading batch axes.
# ---------------------------------------------------------------------- #
def rate_eq4(gains, alpha, bandwidth_hz, p_watt, n0):
    """Eq. 4 in jnp; 0 where alpha == 0 (the inf/nan the division produces
    there is discarded by the where)."""
    snr = gains * p_watt / (alpha * bandwidth_hz * n0)
    return jnp.where(alpha > 0, alpha * bandwidth_hz * jnp.log2(1.0 + snr),
                     0.0)


def cost_bisect(gains, r_min, k: int, bandwidth_hz, p_watt, n0):
    """Eq. 9 by monotone bisection, jnp, batched: (..., K_ues) -> int32.

    ``k`` (static) is the fraction denominator (cfg.n_ues). The loop runs a
    fixed ceil(log2 k) + 1 iterations — once the bracket collapses the
    extra iterations are no-ops for feasible UEs, and infeasible UEs are
    overridden by the up-front whole-band probe.
    """
    def ok(c):
        return rate_eq4(gains, c / k, bandwidth_hz, p_watt, n0) >= r_min

    feasible = ok(jnp.full(gains.shape, k, jnp.int32))
    n_iter = max(1, math.ceil(math.log2(max(k, 2)))) + 1

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        hit = ok(mid)
        return jnp.where(hit, lo, mid + 1), jnp.where(hit, mid, hi)

    lo, hi = jax.lax.fori_loop(
        0, n_iter, body, (jnp.ones(gains.shape, jnp.int32),
                          jnp.full(gains.shape, k, jnp.int32)))
    return jnp.where(feasible, lo, k + 1).astype(jnp.int32)
