"""End-to-end FEEL experiment driver — reproduces the paper's §V protocol.

    run_experiment(...) -> accuracy curve per round (one run)
    run_sweep(...)      -> tidy per-(policy, seed, round) table (many runs)

Protocol (paper §V-A): synthetic-MNIST 50k/10k; sort-by-label groups of 50;
1-30 groups per UE; K=50 UEs, 5 random malicious with a label-flip attack
((6,2) easy / (8,4) hard); 2-layer MLP via FedAvg; 15 rounds; results
averaged over independent runs.

The model/data pair is a ``FeelTask`` (federated/task.py) and a first-class
sweep axis: ``run_experiment(task="lm_tiny")`` runs the same DQS protocol
on federated LM fine-tuning, and ``run_sweep(tasks=[...])`` crosses tasks
with scenarios, defenses, policies and seeds in ONE invocation — per-task
batched cohorts share one batched control plane, because the control plane
(Eq. 1-3, Eq. 9, Alg. 2) never touches the model.

``engine`` selects the cohort execution path: "vectorized" (default) runs
every scheduled UE in one vmapped step; "loop" is the original sequential
per-client oracle (see federated/server.py).

``run_sweep`` is the recommended entry point for multi-seed studies
(§V averages, robustness sweeps): it generates each (task, seed) dataset
once, shares each (task, seed, data-attack) partition and its
device-resident padded layout across policies (and across scenarios with
identical poisoned data), and — where shapes allow (same cfg => same
padded bucket levels) — stacks the per-round cohorts of a task's runs into
one ``cohort_train_multi``/``cohort_eval`` call per size bucket, so seeds,
policies and threat scenarios become one more slice of the vmapped client
axis. Every run reproduces its sequential ``run_experiment`` twin exactly
(same RNG streams; tests/test_sweep.py pins the parity).

The threat-model axis (``scenarios=[...]``) runs heterogeneous attack
scenarios — label-flip variants, feature noise, token attacks, free-riders,
model poisoning, colluding schedules (core/attacks.py, DESIGN.md §8) — in
the same stacked sweep; ``attack_pairs`` survives as a back-compat shim.
Data attacks are dataset-typed (label/feature attacks need feature
datasets, token attacks need token datasets — ``attacks.poison_dataset``
fails loudly on a mismatch), so a mixed-task grid crosses tasks with
data-free scenarios (model/report attacks, "none") or task-compatible
data attacks. The defense axis (``defenses=[...]``) crosses every scenario
with a server-side counter-measure (core/defenses.py, DESIGN.md §9: robust
aggregation + validation detection) at zero extra partition/layout cost —
defenses are deterministic, so (scenario x defense) cells share the
scenario's partitions and RNG streams.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.core import control as ctl
from repro.core import defenses as dfs
from repro.core import population
from repro.core.poisoning import pick_malicious
from repro.core.scheduler import Schedule
from repro.federated import cohort
from repro.federated.async_engine import AsyncFeelEngine
from repro.federated.server import FeelServer, build_cohort_data
from repro.federated.task import FeelTask, as_task
from repro.obs import trace


def run_experiment(policy: str = "dqs",
                   attack_pair: Tuple[int, int] = (6, 2),
                   cfg: Optional[FeelConfig] = None,
                   seed: int = 0,
                   n_train: Optional[int] = None,
                   n_test: Optional[int] = None,
                   omega: Optional[Tuple[float, float]] = None,
                   adaptive_omega: bool = False,
                   rounds: Optional[int] = None,
                   no_attack: bool = False,
                   model_poison_scale: Optional[float] = None,
                   lie_boost: float = 0.0,
                   engine: str = "vectorized",
                   control: str = "batched",
                   scenario=None, defense=None,
                   task: Optional[FeelTask] = None,
                   population: Optional[int] = None) -> Dict:
    """One FEEL experiment; returns the per-round curves + run summary.

    ``population`` — candidate population size N (DESIGN.md §12): the
    scheduler ranks over N candidate UEs per round while ``cfg.n_ues``
    stays the bandwidth budget K. None (default) pins the legacy N == K
    regime — bit-identical streams and schedules to every pre-population
    caller. With N > K the batched control plane routes through the
    schedule-preserving top-M prefilter (core/population.py).

    ``task`` — a ``federated.task.FeelTask`` (object or registry name;
    None defers to ``cfg.task``, default the paper's ``mnist_mlp``).
    ``n_train``/``n_test`` default to the task's protocol sizes.

    Threat model — either an explicit ``scenario`` (an
    ``core.attacks.AttackScenario``, a registry name, or a legacy
    ``(source, target)`` pair) or the legacy knobs. The legacy-knob
    contract is regression-tested (tests/test_attacks.py):

    - ``model_poison_scale`` REPLACES the label-flip data attack —
      malicious UEs keep clean data and poison their *updates* instead
      (the two never compose through these knobs; compose explicitly via
      an ``AttackScenario`` if both are wanted);
    - ``no_attack=True`` wins over everything: no data attack, no model
      poisoning, no lie_boost, and malicious flags are not set;
    - ``lie_boost`` composes with whichever attack is active;
    - metrics always watch ``attack_pair``.

    ``scenario`` supersedes the legacy knobs (they must stay at their
    defaults when it is given).

    ``defense`` — a ``core.defenses.DefensePolicy`` spec (object or
    registry name; None defers to ``cfg.defense``): the server-side
    counter-measure plane (robust aggregation + validation detection,
    DESIGN.md §9).
    """
    cfg = cfg or FeelConfig()
    tsk = as_task(task if task is not None else cfg.task)
    cfg = dataclasses.replace(cfg, task=tsk.name)
    if population is not None:
        cfg = dataclasses.replace(cfg, population=int(population))
    if omega is not None:
        cfg = dataclasses.replace(cfg, omega_rep=omega[0], omega_div=omega[1])
    n_train = tsk.default_n_train if n_train is None else n_train
    n_test = tsk.default_n_test if n_test is None else n_test
    if scenario is not None:
        assert (not no_attack and model_poison_scale is None
                and not lie_boost and tuple(attack_pair) == (6, 2)), \
            "scenario supersedes the legacy attack knobs (incl. " \
            "attack_pair — set AttackScenario.watch instead)"
        scn = atk.as_scenario(scenario)
    else:
        scn = atk.legacy_scenario(attack_pair, no_attack,
                                  model_poison_scale, lie_boost)
    rng = np.random.default_rng(seed)
    train, test = tsk.generate_data(n_train, n_test, seed)
    malicious = pick_malicious(cfg.n_population, cfg.n_malicious, rng)
    clients = tsk.partition_clients(train, cfg.n_population, rng,
                                    None if scn.benign else malicious,
                                    scn.data,
                                    context=f"task={tsk.name}, "
                                            f"scenario={scn.name}")
    server = FeelServer(cfg, clients, test, rng, policy=policy,
                        adaptive_omega=adaptive_omega, scenario=scn,
                        engine=engine, control=control, defense=defense,
                        task=tsk)
    with trace.span("experiment") as sp:
        if trace.enabled():
            sp.set(policy=policy, task=tsk.name, mode=cfg.mode,
                   engine=engine, control=control)
        if cfg.mode == "async":
            # event-driven engine (federated/async_engine.py, DESIGN.md
            # §13): one RoundLog per aggregation + simulated-clock extras
            eng = AsyncFeelEngine(server)
            logs = eng.run(rounds)
        else:
            eng = None
            logs = server.run(rounds)
        if trace.enabled():
            for k, v in cohort.cache_sizes().items():
                trace.gauge_set(f"compile.{k}", float(v))
    out = {
        "task": tsk.name,
        "scenario": scn.name,
        "defense": server.defense.name,
        "acc": [l.global_acc for l in logs],
        "loss": [l.global_loss for l in logs],
        "source_acc": [l.source_acc for l in logs],
        "attack_success": [l.attack_success for l in logs],
        "malicious_selected": [l.n_malicious_selected for l in logs],
        "objective": [l.objective for l in logs],
        "rep_gap": [l.rep_gap for l in logs],
        "n_clipped": [l.n_clipped for l in logs],
        "n_rejected": [l.n_rejected for l in logs],
        "n_flagged": [l.n_flagged for l in logs],
        "det_precision": [l.det_precision for l in logs],
        "det_recall": [l.det_recall for l in logs],
        "recovery_rounds": atk.recovery_rounds(
            [l.attack_success for l in logs], cfg.recovery_threshold),
        "final_reputation_malicious": float(
            np.mean(server.reputation.values[malicious])),
        "final_reputation_honest": float(np.mean(np.delete(
            server.reputation.values, malicious))),
        "malicious": malicious.tolist(),
    }
    if eng is not None:
        out.update({
            "sim_time": [a.sim_time for a in eng.agg_logs],
            "trigger": [a.trigger for a in eng.agg_logs],
            "n_uploads": [a.n_uploads for a in eng.agg_logs],
            "mean_age": [float(np.mean(a.ages)) for a in eng.agg_logs],
        })
    return out


# ---------------------------------------------------------------------- #
# Batched multi-run sweeps
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class SweepResult:
    """Tidy results of a (tasks x policies x seeds x scenarios x defenses)
    sweep.

    rows — one record per (task, policy, seed, scenario, defense, round)
        with the per-round metrics (acc, loss, source_acc, attack_success,
        malicious_selected, objective, rep_gap, forced, and the defense
        metrics n_clipped / n_rejected / n_flagged / det_precision /
        det_recall).
    runs — one record per run, shaped exactly like ``run_experiment``'s
        return value plus the (task, policy, seed, scenario, defense,
        attack_pair) key (``attack_pair`` is the scenario's watched pair,
        None if it has none — kept for back-compat with pair-keyed
        callers).
    """
    rows: List[Dict]
    runs: List[Dict]

    def select(self, **key) -> List[Dict]:
        """Run summaries matching e.g. task=..., policy=..., seed=...,
        scenario=..., defense=..."""
        return [r for r in self.runs
                if all(r[k] == v for k, v in key.items())]

    def mean_curve(self, field: str = "acc", **key) -> np.ndarray:
        """Per-round mean of ``field`` over the runs matching ``key``
        (the paper's average-over-independent-runs reduction).

        NaN-aware: watch-metric entries (attack_success / source_acc /
        det_precision / det_recall) are NaN where undefined — a watch-less
        scenario, a round with nothing flagged — and must not poison the
        cross-seed mean of the runs that DO define them. A round where
        every matched run is NaN stays NaN (computed without numpy's
        all-NaN RuntimeWarning).
        """
        runs = self.select(**key)
        assert runs, key
        a = np.asarray([r[field] for r in runs], float)
        finite = np.isfinite(a)
        n = finite.sum(axis=0)
        s = np.where(finite, a, 0.0).sum(axis=0)
        return np.where(n > 0, s / np.maximum(n, 1), np.nan)

    def averaged(self, fields: Sequence[str] = ("acc", "source_acc",
                                                "attack_success",
                                                "malicious_selected",
                                                "rep_gap"),
                 **key) -> Dict[str, np.ndarray]:
        """NaN-aware mean curves of several fields at once (the standard
        averaged-over-seeds reduction of a sweep slice)."""
        return {f: self.mean_curve(f, **key) for f in fields}


class _SweepRun:
    """One (task, policy, seed, scenario, defense) run's server +
    in-flight round state."""

    def __init__(self, task, policy, seed, scenario, defense, server,
                 malicious, watch_mask, ty_target):
        self.task = task
        self.policy = policy
        self.seed = seed
        self.scenario = scenario
        self.defense = defense
        self.pair = scenario.watch         # back-compat attack_pair key
        self.server = server
        self.malicious = malicious
        self.watch_mask = watch_mask       # (U,) float32, source-unit rows
        self.ty_target = ty_target         # (U,) unit labels relabelled to
        #                                    the attack target (== ey if none)
        self.plan = None                   # (values, sched, sel, forced)
        self.stacked = None                # merged cohort params (sel order)
        self.acc_local = None
        self.acc_test = None
        self.acc_val = None                # detector validation accuracies
        self.g_acc = float("nan")
        self.g_loss = float("nan")
        self.src_acc = float("nan")
        self.atk_succ = float("nan")

    def summary(self) -> Dict:
        s = self.server
        return {
            "task": self.task.name,
            "policy": self.policy, "seed": self.seed,
            "scenario": self.scenario.name,
            "defense": self.defense.name,
            "attack_pair": self.pair,
            "acc": [l.global_acc for l in s.logs],
            "loss": [l.global_loss for l in s.logs],
            "source_acc": [l.source_acc for l in s.logs],
            "attack_success": [l.attack_success for l in s.logs],
            "malicious_selected": [l.n_malicious_selected for l in s.logs],
            "objective": [l.objective for l in s.logs],
            "rep_gap": [l.rep_gap for l in s.logs],
            "forced": [l.forced for l in s.logs],
            "n_clipped": [l.n_clipped for l in s.logs],
            "n_rejected": [l.n_rejected for l in s.logs],
            "n_flagged": [l.n_flagged for l in s.logs],
            "det_precision": [l.det_precision for l in s.logs],
            "det_recall": [l.det_recall for l in s.logs],
            "recovery_rounds": atk.recovery_rounds(
                [l.attack_success for l in s.logs],
                s.cfg.recovery_threshold),
            "final_reputation_malicious": float(
                np.mean(s.reputation.values[self.malicious])),
            "final_reputation_honest": float(np.mean(np.delete(
                s.reputation.values, self.malicious))),
            "malicious": self.malicious.tolist(),
        }


def run_sweep(policies: Sequence[str], seeds: Sequence[int],
              attack_pairs: Sequence[Tuple[int, int]] = ((6, 2),),
              cfg: Optional[FeelConfig] = None, *,
              tasks: Optional[Sequence] = None,
              scenarios: Optional[Sequence] = None,
              defenses: Optional[Sequence] = None,
              n_train: Optional[int] = None,
              n_test: Optional[int] = None,
              omega: Optional[Tuple[float, float]] = None,
              adaptive_omega: bool = False,
              rounds: Optional[int] = None,
              no_attack: bool = False,
              model_poison_scale: Optional[float] = None,
              lie_boost: float = 0.0,
              engine: str = "vectorized",
              control: str = "batched",
              n_buckets: int = 3,
              stack_runs: bool = True,
              population: Optional[int] = None) -> SweepResult:
    """Run the full (tasks x policies x seeds x scenarios x defenses) grid
    batched.

    The task axis: ``tasks`` is a sequence of ``federated.task.FeelTask``
    specs (objects or registry names; None = the single ``cfg.task``
    default) — the model/data pair becomes one more sweep axis. Tasks
    cannot share parameter pytrees, so the cohort phases batch WITHIN each
    task while the control plane (schedule + Eq. 1 reputation, which never
    touches the model) still runs ONE vmapped kernel across every run of
    every task. Per-run metrics gain the ``task`` key and the
    task-defined ``loss`` curve (NaN for tasks without one). Data attacks
    are dataset-typed — cross tasks with data-free scenarios or
    task-compatible data attacks (module docstring).

    The defense axis: ``defenses`` is a sequence of
    ``core.defenses.DefensePolicy`` specs (objects or registry names;
    None = the single ``cfg.defense`` default). Defenses are
    deterministic server-side counter-measures, so every (scenario,
    defense) cell shares the scenario's partition, device layout and RNG
    streams — (scenario x defense x policy x seed) runs as ONE stacked
    sweep with shared partitions, and a defended run's undefended twin
    differs only through the defense's model/reputation effects.

    The threat-model axis: ``scenarios`` is a sequence of
    ``core.attacks.AttackScenario`` specs (scenario objects, registry
    names, or legacy ``(source, target)`` pairs) — HETEROGENEOUS threat
    models (label-flip variants, feature noise, token attacks,
    free-riders, model poisoning, colluding schedules, ...) run as one
    stacked sweep through the bucketed engine and batched control plane.
    When ``scenarios`` is None the legacy ``attack_pairs`` +
    ``no_attack`` / ``model_poison_scale`` / ``lie_boost`` knobs are
    shimmed into one scenario per pair (``attacks.legacy_scenario`` —
    same contract as ``run_experiment``); the legacy knobs must stay at
    their defaults when ``scenarios`` is given.

    Semantics: every run is exactly ``run_experiment(policy, task=tsk,
    scenario=scn, seed=seed, ...)`` — same datasets, partitions and RNG
    streams — but the sweep (1) generates each (task, seed) dataset once,
    (2) builds each (task, seed, data-attack) partition and its
    device-resident padded bucket layout once, shared across policies AND
    across scenarios whose poisoned data is identical (e.g. every pure
    model-poisoning scenario shares the clean ``mal_only`` partition),
    and (3) with ``stack_runs`` and the vectorized engine,
    trains/evaluates the per-round cohorts of a task's runs in one
    vmapped call per size bucket: a shared per-task ``pad_to`` makes the
    bucket levels identical across runs, so runs become one more slice of
    the stacked client axis (``cohort.cohort_train_multi``).

    ``control="batched"`` (default) also stacks the *control plane*: with
    ``stack_runs``, round t of every run is scheduled by ONE vmapped
    ``core.control.schedule_runs`` call over a sweep-wide ``ControlState``
    (and Eq. 1 reputations update in one ``finalize_runs``) instead of a
    per-run numpy loop, so the schedule phase stops scaling linearly in
    the number of runs. ``control="host"`` keeps the sequential numpy
    control oracle per run.

    ``stack_runs=False`` (or engine="loop") executes the runs sequentially
    while still sharing the dataset/partition caches — the oracle the
    batched path is tested against.

    ``n_train``/``n_test`` default per task (each task's protocol sizes);
    an explicit value applies to every task in the grid.

    ``population`` — candidate population size N for EVERY run of the
    sweep (DESIGN.md §12; None = the legacy N == cfg.n_ues regime). The
    data is partitioned over all N candidates, the control plane ranks
    over N through the schedule-preserving top-M prefilter, and only the
    per-round scheduled cohorts (<= K fractions' worth) train.
    """
    cfg = cfg or FeelConfig()
    if population is not None:
        cfg = dataclasses.replace(cfg, population=int(population))
    if omega is not None:
        cfg = dataclasses.replace(cfg, omega_rep=omega[0],
                                  omega_div=omega[1])
    policies = list(policies)
    seeds = [int(s) for s in seeds]
    tsks = ([as_task(cfg.task)] if tasks is None
            else [as_task(t) for t in tasks])
    assert len({t.name for t in tsks}) == len(tsks), \
        "duplicate task names in the tasks axis"
    if scenarios is None:
        scns = [atk.legacy_scenario(tuple(p), no_attack,
                                    model_poison_scale, lie_boost)
                for p in attack_pairs]
    else:
        assert (not no_attack and model_poison_scale is None
                and not lie_boost
                and tuple(map(tuple, attack_pairs)) == ((6, 2),)), \
            "the scenarios axis supersedes the legacy attack knobs " \
            "(incl. attack_pairs — set AttackScenario.watch instead)"
        scns = [atk.as_scenario(s) for s in scenarios]
    dfns = ([dfs.as_defense(cfg.defense)] if defenses is None
            else [dfs.as_defense(d) for d in defenses])

    # -- shared caches (all keyed per task) ------------------------------ #
    data_cache = {
        (tsk.name, s): tsk.generate_data(
            n_train if n_train is not None else tsk.default_n_train,
            n_test if n_test is not None else tsk.default_n_test, s)
        for tsk in tsks for s in set(seeds)}

    part_cache: Dict = {}
    for tsk in tsks:
        for seed in set(seeds):
            for scn in scns:
                key = (tsk.name, seed, scn.data_key())
                if key in part_cache:
                    continue
                train, test = data_cache[(tsk.name, seed)]
                rng = np.random.default_rng(seed)
                malicious = pick_malicious(cfg.n_population,
                                           cfg.n_malicious, rng)
                clients = tsk.partition_clients(
                    train, cfg.n_population, rng,
                    None if scn.benign else malicious, scn.data,
                    context=f"task={tsk.name}, scenario={scn.name}")
                # freeze the post-partition RNG state: each run restores it
                # so its downstream stream (wireless placement, channel
                # draws) matches its sequential run_experiment twin exactly
                part_cache[key] = (clients, malicious,
                                   rng.bit_generator.state)

    # one pad_to per task across the whole sweep => identical bucket
    # levels => every compiled per-bucket program is shared by that
    # task's runs
    pad_to = {
        tsk.name: max(c.size for (tn, _, _), (clients, _, _)
                      in part_cache.items() if tn == tsk.name
                      for c in clients)
        for tsk in tsks}

    cohort_cache: Dict = {}
    if engine == "vectorized":
        for tsk in tsks:
            for (tn, seed, akey), (clients, _, _) in part_cache.items():
                if tn != tsk.name:
                    continue
                _, test = data_cache[(tn, seed)]
                unit_labels = tsk.unit_labels(test)
                hists = [tsk.histogram(c.data) for c in clients]
                mask_arr = np.stack(
                    [np.isin(unit_labels, np.flatnonzero(h > 0))
                     for h in hists]).astype(np.float32)
                cohort_cache[(tn, seed, akey)] = build_cohort_data(
                    clients, mask_arr, batch_size=tsk.batch_size,
                    pad_to=pad_to[tn], n_buckets=n_buckets)

    runs: List[_SweepRun] = []
    for tsk in tsks:
        cfg_t = dataclasses.replace(cfg, task=tsk.name)
        for scn in scns:
            for dfn in dfns:
                for seed in seeds:
                    for policy in policies:
                        key = (tsk.name, seed, scn.data_key())
                        clients, malicious, rng_state = part_cache[key]
                        _, test = data_cache[(tsk.name, seed)]
                        rng = np.random.default_rng(seed)
                        rng.bit_generator.state = rng_state
                        server = FeelServer(
                            cfg_t, clients, test, rng, policy=policy,
                            adaptive_omega=adaptive_omega, scenario=scn,
                            engine=engine, defense=dfn,
                            control=control, pad_to=pad_to[tsk.name],
                            n_buckets=n_buckets, task=tsk,
                            cohort_data=cohort_cache.get(key))
                        unit_labels = tsk.unit_labels(test)
                        watch = ((unit_labels == scn.watch[0])
                                 .astype(np.float32) if scn.watch else
                                 np.zeros(unit_labels.size, np.float32))
                        ty_target = (np.full_like(unit_labels,
                                                  scn.watch[1])
                                     if scn.watch else unit_labels)
                        runs.append(_SweepRun(tsk, policy, seed, scn, dfn,
                                              server, malicious, watch,
                                              jnp.asarray(ty_target)))

    n_rounds = rounds or cfg.rounds
    if cfg.mode == "async":
        # event-driven mode: every run gets its own event loop (waves are
        # per-run decisions, so rounds cannot interleave across runs), but
        # the whole (scenario x defense x policy) grid still shares the
        # dataset/partition/cohort caches built above
        for run in runs:
            AsyncFeelEngine(run.server).run(n_rounds)
    elif stack_runs and engine == "vectorized":
        # sweep-wide control state: ONE vmapped schedule / reputation
        # kernel call per round for ALL runs — of every task
        # (core/control.py; the control plane is model-free)
        sweep_ctrl = (ctl.ControlState.from_servers(
            [r.server for r in runs]) if control == "batched" else None)
        for t in range(n_rounds):
            _sweep_round_stacked(runs, t, sweep_ctrl)
    else:
        for run in runs:
            for t in range(n_rounds):
                run.server.run_round(t)
    if trace.enabled():
        for k, v in cohort.cache_sizes().items():
            trace.gauge_set(f"compile.{k}", float(v))

    rows = [
        {"task": run.task.name,
         "policy": run.policy, "seed": run.seed,
         "scenario": run.scenario.name, "defense": run.defense.name,
         "attack_pair": run.pair,
         "round": l.round, "acc": l.global_acc, "loss": l.global_loss,
         "source_acc": l.source_acc,
         "attack_success": l.attack_success,
         "malicious_selected": l.n_malicious_selected,
         "objective": l.objective, "rep_gap": l.rep_gap,
         "forced": l.forced, "n_clipped": l.n_clipped,
         "n_rejected": l.n_rejected, "n_flagged": l.n_flagged,
         "det_precision": l.det_precision, "det_recall": l.det_recall}
        for run in runs for l in run.server.logs]
    return SweepResult(rows=rows, runs=[r.summary() for r in runs])


_PAD = FeelServer._N_BUCKET
def _schedule_runs_stacked(runs: List[_SweepRun],
                           sweep_ctrl: ctl.ControlState, t: int) -> None:
    """Phase A, batched control plane: draw each run's channel (and
    ``random``-policy permutation) from its own host RNG — the oracle
    streams — then schedule round t of ALL runs in one vmapped
    ``control.schedule_runs`` call and scatter the per-run Schedules."""
    servers = [r.server for r in runs]
    sweep_ctrl.pull(servers)
    N = servers[0].cfg.n_population     # candidate width (== n_ues legacy)
    gains = np.empty((len(runs), N))
    rand_rank = np.empty((len(runs), N), int)
    omega = np.empty((len(runs), 2))
    for i, s in enumerate(servers):
        gains[i], rand_rank[i] = s.draw_control_inputs()
        omega[i] = s._omega(t)
    if sweep_ctrl.cfg.population is not None:
        # population cut: the schedule-preserving top-M prefilter
        # (identical selection by certificate, core/population.py)
        x, alpha, costs, values, forced, _ = \
            population.prefilter_schedule_runs(
                sweep_ctrl, gains, rand_rank, omega[:, 0], omega[:, 1])
    else:
        x, alpha, costs, values, forced = ctl.schedule_runs(
            sweep_ctrl, gains, rand_rank, omega[:, 0], omega[:, 1])
    for i, run in enumerate(runs):
        sched = Schedule(x=x[i], alpha=alpha[i], cost=costs[i],
                         value=values[i])
        run.plan = (values[i], sched, sched.selected, bool(forced[i]))


def _train_runs_stacked(runs: List[_SweepRun], t: int) -> None:
    """Phase B for ONE task's runs: one ``cohort_train_multi`` call per
    (shared client arrays, size bucket) group. Parameter pytrees are only
    stackable within a task, so the sweep round calls this once per task
    group; everything else batches across tasks or runs per run."""
    task = runs[0].task
    lr = runs[0].server.lr
    epochs = runs[0].server.cfg.local_epochs
    batch_size = runs[0].server.batch_size
    assert all(r.server.lr == lr and r.server.batch_size == batch_size
               and r.task == task for r in runs)

    # (R, ...) stacked run parameters; each group's per-row params are one
    # shape-stable gather from it
    params_all = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[r.server.params for r in runs])
    groups: Dict[int, Dict] = {}
    for i, run in enumerate(runs):
        sel = run.plan[2]
        waste_slots = 0
        for bkt, pos, rows in run.server._cohort_parts(sel, t, pad=False):
            g = groups.setdefault(id(bkt), {"bkt": bkt, "parts": []})
            g["parts"].append((i, pos, rows))
            # report the same metric the single-run path reports (per-part
            # padded slots); the cross-run group actually pads once for
            # the whole group, so this is a (slight) upper bound
            waste_slots += cohort.pad_count(pos.size, _PAD) * bkt["level"]
        run.server.pad_waste.append(
            waste_slots / max(float(
                run.server._ensure_cohort_data().sizes[sel].sum()), 1.0))

    stacks, acc_parts = [], []
    row_map: Dict[int, List] = {i: [] for i in range(len(runs))}
    g_off = 0            # row offset into the concatenated round stack
    for g in groups.values():
        bkt, parts = g["bkt"], g["parts"]
        rows_cat = [rows for _, _, rows in parts]
        ids_cat = [np.full(rows.size, i) for i, _, rows in parts]
        off = 0
        for i, pos, rows in parts:
            row_map[i].append((pos, g_off + off + np.arange(rows.size)))
            off += rows.size
        n_pad = cohort.pad_count(off, _PAD)
        rows_cat.append(np.full(n_pad - off, bkt["null"]))
        ids_cat.append(np.zeros(n_pad - off, int))   # null rows: any params
        idx = jnp.asarray(np.concatenate(rows_cat))
        p = jax.tree.map(
            lambda l, r=jnp.asarray(np.concatenate(ids_cat)):
                jnp.take(l, r, axis=0), params_all)
        data = {f: jnp.take(a, idx, axis=0)
                for f, a in bkt["data"].items()}
        stacked_g, acc_g = cohort.cohort_train_multi(
            task, p, data, jnp.take(bkt["mask"], idx, axis=0), lr, epochs,
            batch_size)
        stacks.append(stacked_g)
        acc_parts.append(acc_g)
        g_off += n_pad

    big = cohort.merge_stacks(stacks)        # (g_off, ...) round stack
    acc_all = np.asarray(jnp.concatenate(acc_parts), float)  # one sync
    for i, run in enumerate(runs):
        order = np.concatenate([pos for pos, _ in row_map[i]])
        gidx = np.concatenate([g for _, g in row_map[i]])
        inv = np.argsort(order, kind="stable")
        stacked = jax.tree.map(
            lambda l, r=jnp.asarray(gidx[inv]): jnp.take(l, r, axis=0),
            big)
        run.stacked, run.acc_local = run.server._apply_attacks(
            run.plan[2], stacked, acc_all[gidx][inv], t)


def _sweep_round_stacked(runs: List[_SweepRun], t: int,
                         sweep_ctrl: Optional[ctl.ControlState]
                         = None) -> None:
    """One round of every run, batched: one vmapped control-plane call for
    all runs' schedules (host numpy per run when ``sweep_ctrl`` is None),
    then — per task — one ``cohort_train_multi`` per (shared client
    arrays, size bucket) group, one ``cohort_eval`` per (task, seed) for
    the uploaded models, per-run FedAvg, one ``cohort_eval`` per (task,
    seed) for the global/watched-unit metrics, and one batched Eq. 1
    reputation update spanning every task's runs.

    All device-side reshuffling uses gathers (``jnp.take``) whose compile
    cache is keyed on *index shapes*, never value-dependent slicing — the
    eager-op cache stays warm across rounds even though every round
    selects different cohorts (value-keyed ``l[a:b]`` slicing recompiled a
    mini-program per new offset pair and dominated sweep wall-clock).
    """
    # -- phase A: schedules — one vmapped call for all runs ------------- #
    if sweep_ctrl is not None:
        with trace.span("schedule") as sp:
            _schedule_runs_stacked(runs, sweep_ctrl, t)
            if trace.enabled():
                est = runs[0].server._schedule_estimates()
                sp.set(t=t, runs=len(runs),
                       est_flops=est["est_flops"] * len(runs),
                       est_bytes=est["est_bytes"] * len(runs))
    else:
        for run in runs:
            run.plan = run.server._schedule_round(t)

    # -- phase B: train — per task, one call per (arrays, bucket) group - #
    for group in _by_task(runs):
        with trace.span("train") as sp:
            _train_runs_stacked(group, t)
            if trace.enabled():
                ests = [r.server._train_estimates(r.plan[2])
                        for r in group]
                sp.set(task=group[0].task.name, runs=len(group),
                       est_flops=sum(e["est_flops"] for e in ests),
                       est_bytes=sum(e["est_bytes"] for e in ests))

    # -- phase C: evaluate uploads — one call per (task, seed) ---------- #
    with trace.span("eval"):
        for group in _by_task_seed(runs):
            stacks = [run.stacked for run in group]
            masks = [run.server._eval_masks(run.plan[2], run.plan[2].size)
                     for run in group]
            counts = [run.plan[2].size for run in group]
            accs = _eval_stacked(group[0].server, stacks, masks, counts)
            for run, a in zip(group, accs):
                run.acc_test = a

    # -- phase C2: defense validation pass — the detector runs' uploads
    # AND their start-of-round global models scored on the held-out split
    # (per-UE unit masks) in one extra vmapped eval per (task, seed),
    # through the same machinery as phase C
    with trace.span("eval.validation"):
        for group in _by_task_seed(runs):
            det_runs = [r for r in group
                        if r.server.defense.detector is not None]
            if not det_runs:
                continue
            stacks, masks, counts = [], [], []
            for run in det_runs:
                n = run.plan[2].size
                vm = run.server._val_eval_masks(run.plan[2], n)
                stacks += [run.stacked,
                           cohort.broadcast_params(run.server.params, n)]
                masks += [vm, vm]
                counts += [n, n]
            accs = _eval_stacked(det_runs[0].server, stacks, masks, counts)
            for run, v, g in zip(det_runs, accs[::2], accs[1::2]):
                run.acc_val = np.stack([v, g])

    # -- phase D: per-run FedAvg (weights span the run's buckets) ------- #
    for run in runs:
        sel = run.plan[2]
        stacked_p = cohort.pad_stacked(run.stacked,
                                       cohort.pad_count(sel.size, _PAD))
        run.server._aggregate_cohort(sel, stacked_p)

    # -- phase E: global / watched-unit / attack-success — one call per
    # (task, seed). A watched run contributes three rows to the vmapped
    # eval: full-test unit accuracy, watched-unit accuracy, and the attack
    # success rate (unit labels relabelled to the attack's target over the
    # same watch mask); a watch-less run contributes only the accuracy
    # row — no wasted forward passes on rows whose result would be NaN
    # anyway. The task's loss metric (LM held-out CE) is one extra scalar
    # eval per run (free for loss-less tasks).
    with trace.span("eval.global"):
        for group in _by_task_seed(runs):
            ty = group[0].server._ey
            ones = jnp.ones_like(ty, jnp.float32)
            counts = [3 if run.scenario.watch else 1 for run in group]
            stacks = [cohort.broadcast_params(run.server.params, c)
                      for run, c in zip(group, counts)]
            masks, ys = [], []
            for run, c in zip(group, counts):
                if c == 3:
                    wm = jnp.asarray(run.watch_mask)
                    masks.append(jnp.stack([ones, wm, wm]))
                    ys.append(jnp.stack([ty, ty, run.ty_target]))
                else:
                    masks.append(ones[None])
                    ys.append(ty[None])
            accs = _eval_stacked(group[0].server, stacks, masks, counts,
                                 ys=ys)
            for run, c, a in zip(group, counts, accs):
                run.g_acc = float(a[0])
                run.g_loss = run.server._global_loss()
                watched = c == 3 and bool(run.watch_mask.any())
                run.src_acc = float(a[1]) if watched else float("nan")
                run.atk_succ = float(a[2]) if watched else float("nan")

    # -- phase F: detector penalties + reputation / staleness (one batched
    # Eq. 1 call) + logs
    if sweep_ctrl is not None:
        # state was pulled in phase A and nothing touched it since; update
        # every run's reputation/ages in one kernel call, push back, then
        # log per run against the servers' refreshed state. Detector
        # penalties (host numpy from the phase-C2 accuracies) ride into
        # the same Eq. 1 kernel call.
        with trace.span("finalize"):
            ctl.finalize_runs(sweep_ctrl, [run.plan[2] for run in runs],
                              [run.acc_local for run in runs],
                              [run.acc_test for run in runs],
                              penalties=[run.server._detect(run.plan[2],
                                                            run.acc_val)
                                         for run in runs])
            sweep_ctrl.push([run.server for run in runs])
            for run in runs:
                values, sched, sel, forced = run.plan
                run.server._log_round(t, values, sched, sel, forced,
                                      run.g_acc, run.src_acc,
                                      run.atk_succ, run.g_loss)
                run.plan = run.stacked = None
                run.acc_local = run.acc_test = None
                run.acc_val = None
    else:
        for run in runs:
            values, sched, sel, forced = run.plan
            run.server._finalize_round(t, values, sched, sel, forced,
                                       run.acc_local, run.acc_test,
                                       run.g_acc, run.src_acc,
                                       run.atk_succ, run.acc_val,
                                       run.g_loss)
            run.plan = run.stacked = run.acc_local = run.acc_test = None
            run.acc_val = None


def _by_task(runs: List[_SweepRun]) -> List[List[_SweepRun]]:
    groups: Dict[str, List[_SweepRun]] = {}
    for run in runs:
        groups.setdefault(run.task.name, []).append(run)
    return list(groups.values())


def _by_task_seed(runs: List[_SweepRun]) -> List[List[_SweepRun]]:
    groups: Dict[Tuple[str, int], List[_SweepRun]] = {}
    for run in runs:
        groups.setdefault((run.task.name, run.seed), []).append(run)
    return list(groups.values())


def _eval_stacked(server, stacks, masks, counts, ys=None) -> List[np.ndarray]:
    """One cohort_eval over the concatenated per-run stacks; split back.

    All stacks must come from runs sharing ``server``'s (task, seed) —
    the evaluation inputs/targets are the server's. ``ys`` (optional) —
    per-run (rows, U) unit-label arrays for metrics that score against
    relabelled targets (attack success); None keeps the shared test
    targets for every row."""
    n_tot = sum(counts)
    n_pad = cohort.pad_count(n_tot, _PAD)
    stacked = cohort.pad_stacked(cohort.merge_stacks(stacks), n_pad)
    mask = cohort.pad_stacked(cohort.merge_stacks(masks), n_pad)
    if ys is None:
        acc = np.asarray(
            cohort.cohort_eval(server.task, stacked, server._ex,
                               server._ey, mask), float)
    else:
        y_rows = cohort.pad_stacked(cohort.merge_stacks(ys), n_pad)
        acc = np.asarray(
            cohort.cohort_eval_rows(server.task, stacked, server._ex,
                                    y_rows, mask), float)
    out, off = [], 0
    for c in counts:
        out.append(acc[off:off + c])
        off += c
    return out


def averaged(policy, attack_pair, n_runs=3, **kw) -> Dict:
    """Paper reports the average of independent runs per setting —
    executed as one batched ``run_sweep`` over the seeds."""
    res = run_sweep([policy], seeds=range(n_runs),
                    attack_pairs=[attack_pair], **kw)
    return {"acc": res.mean_curve("acc").tolist(),
            "malicious_selected":
                res.mean_curve("malicious_selected").tolist(),
            "rep_gap": float(np.mean([r["final_reputation_honest"]
                                      - r["final_reputation_malicious"]
                                      for r in res.runs]))}
