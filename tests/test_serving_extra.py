"""Extra serving-path coverage: cache growth, enc-dec cross caches, batched
generation smoke via the serve launcher components."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, reduced, registry
from repro.models import api, transformer as tf


def test_grow_cache_pads_only_kv_axes():
    cfg = dataclasses.replace(reduced(get("yi-34b")), dtype="float32")
    params = api.init(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    _, cache = tf.lm_prefill(cfg, params, tok, target_len=32)
    k = cache["blocks"]["layers"][0]["k"]
    assert k.shape[2] == 32                  # (n_blocks, B, C, hkv, hd)
    assert int(cache["index"]) == 8


def test_encdec_cross_cache_shapes():
    cfg = dataclasses.replace(reduced(get("seamless-m4t-medium")),
                              dtype="float32")
    cache = api.cache_init(cfg, batch=2, seq_len=16)
    layer0 = cache["blocks"]["layers"][0]
    assert "xk" in layer0 and "xv" in layer0
    assert layer0["xk"].shape[2] == min(16, 4096)   # cross length


def test_greedy_generation_deterministic():
    cfg = dataclasses.replace(reduced(get("qwen2.5-32b")), dtype="float32")
    params = api.init(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab_size)

    def generate():
        logits, cache = api.prefill(cfg, params, {"tokens": prompts},
                                    target_len=16)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs = [tok]
        for _ in range(7):
            logits, cache = api.decode_step(cfg, params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            outs.append(tok)
        return jnp.concatenate(outs, 1)

    a, b = generate(), generate()
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_optimized_config_helper():
    cfg = registry.optimized(get("deepseek-v3-671b"), 16)
    assert cfg.moe.dispatch_groups == 16
    dense = registry.optimized(get("yi-34b"), 16)
    assert dense.moe is None
