"""Defense plane (core/defenses.py): host-vs-batched aggregator parity
(bitwise decisions, pinned payloads), the defense x engine x control
parity matrix, the validation detector's feature-noise rep-gap reversal
(the DESIGN.md §8 hole this plane closes), defense property tests, and
the run_sweep defenses axis."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.core import control as ctl
from repro.core import defenses as dfs
from repro.core.reputation import ReputationTracker
from repro.federated.simulation import run_experiment, run_sweep
from repro.models.mlp import mlp_init

KW = dict(n_train=1200, n_test=300, rounds=2)


def _cfg():
    return FeelConfig(n_ues=8, n_malicious=2, min_selected=3)


def _flat(seed, n, m=257):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, m)).astype(np.float32)


def _pad(flat, n_pad):
    out = np.zeros((n_pad,) + flat.shape[1:], flat.dtype)
    out[:flat.shape[0]] = flat
    return jnp.asarray(out)


# ---------------------------------------------------------------------- #
# Bitwise masked-vs-oracle aggregator regressions: decisions exact,
# payloads bit-equal where the reduction order is pinned (trimmed mean /
# median sequential accumulation, norm-clip elementwise), Krum selection
# index-exact (f64 scores).
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("n,n_pad", [(5, 8), (9, 16), (16, 16)])
def test_trimmed_mean_host_batched_bitwise(n, n_pad):
    x = _flat(0, n)
    tm = dfs.TrimmedMean(0.2)
    host, hs = tm.aggregate_host(x)
    bat, bs = tm.aggregate_batched(_pad(x, n_pad), n)
    np.testing.assert_array_equal(host, np.asarray(bat))
    assert hs.n_rejected == bs.n_rejected == 2 * tm.n_trim(n)


@pytest.mark.parametrize("n,n_pad", [(5, 8), (6, 8), (9, 16)])
def test_median_host_batched_bitwise(n, n_pad):
    x = _flat(1, n)
    md = dfs.Median()
    host, _ = md.aggregate_host(x)
    bat, _ = md.aggregate_batched(_pad(x, n_pad), n)
    np.testing.assert_array_equal(host, np.asarray(bat))
    # odd n: the exact middle row; even n: the two-rank midpoint
    xs = np.sort(x, axis=0)
    np.testing.assert_array_equal(
        host, (xs[(n - 1) // 2] + xs[n // 2]) * np.float32(0.5))


def test_normclip_host_batched_bitwise_and_stats():
    n, n_pad = 6, 8
    x = _flat(2, n)
    g = _flat(3, 1)[0]
    nc = dfs.NormClip(0.5)
    ch, hs = nc.clip_host(x, g)
    cb, bs = nc.clip_batched(_pad(x, n_pad), jnp.asarray(g), n)
    np.testing.assert_array_equal(ch, np.asarray(cb)[:n])
    assert hs.n_clipped == bs.n_clipped > 0


def test_krum_selection_host_batched_equal():
    n, n_pad, f = 10, 16, 3
    x = _flat(4, n)
    x[:f] += 25.0           # the Byzantine rows sit far out
    kr = dfs.Krum(f=f)
    sel_h = kr.select_host(x, n_byz=f)
    sel_b = kr.select_batched(_pad(x, n_pad), n, n_byz=f)
    np.testing.assert_array_equal(sel_h, sel_b)
    assert not set(sel_h) & set(range(f))       # outliers rejected
    assert sel_h.size == n - f                  # multi-Krum default m


def test_krum_degrades_to_fedavg_when_cohort_too_small():
    x = _flat(5, 4)
    sel = dfs.Krum().select_host(x, n_byz=2)    # n - f - 2 = 0
    np.testing.assert_array_equal(sel, np.arange(4))


def test_aggregate_entry_points_match_engines_shapes():
    """aggregate_host (compressed pytree list) == aggregate_stacked
    (padded stacked pytree) for every aggregator — the exact layouts the
    two engines feed them."""
    n, n_pad, n_byz = 6, 8, 2
    template = mlp_init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree.flatten(template)
    rng = np.random.default_rng(6)
    rows = [jax.tree.unflatten(treedef, [
        np.asarray(l) + rng.normal(size=l.shape).astype(np.float32)
        * (3.0 if i < n_byz else 0.1) for l in leaves])
        for i in range(n)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(
        [jnp.asarray(x) for x in xs]), *rows)
    stacked_p = jax.tree.map(
        lambda l: jnp.concatenate(
            [l, jnp.zeros((n_pad - n,) + l.shape[1:], l.dtype)]), stacked)
    weights = np.zeros(n_pad)
    weights[:n] = (rng.integers(1, 31, n) * 50).astype(float)
    for agg in (dfs.TrimmedMean(0.2), dfs.Median(), dfs.NormClip(1.0),
                dfs.Krum()):
        h, hs = dfs.aggregate_host(agg, rows, weights[:n], template, n_byz)
        b, bs = dfs.aggregate_stacked(agg, stacked_p, weights, template,
                                      n, n_byz)
        for x, y in zip(jax.tree.leaves(h), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=2e-6)
        assert (hs.n_clipped, hs.n_rejected) == (bs.n_clipped,
                                                 bs.n_rejected)


# ---------------------------------------------------------------------- #
# Tentpole acceptance: EVERY registered defense, batched == oracle under
# both engines and both control planes.
# ---------------------------------------------------------------------- #
_REFS = {}


def _reference(name):
    if name not in _REFS:
        _REFS[name] = run_experiment("dqs", scenario="noise_0.8",
                                     cfg=_cfg(), seed=0, engine="loop",
                                     control="host", defense=name, **KW)
    return _REFS[name]


@pytest.mark.parametrize("engine,control", [("vectorized", "batched"),
                                            ("vectorized", "host"),
                                            ("loop", "batched")])
@pytest.mark.parametrize("name", sorted(dfs.DEFENSES))
def test_defense_parity_matrix(name, engine, control):
    """Batched defense plane == host oracle for every registered defense,
    under both cohort engines and both control planes."""
    ref = _reference(name)
    got = run_experiment("dqs", scenario="noise_0.8", cfg=_cfg(), seed=0,
                         engine=engine, control=control, defense=name,
                         **KW)
    np.testing.assert_allclose(got["acc"], ref["acc"], atol=1e-5)
    np.testing.assert_allclose(got["rep_gap"], ref["rep_gap"], atol=1e-6)
    assert got["malicious_selected"] == ref["malicious_selected"]
    assert got["n_clipped"] == ref["n_clipped"]
    assert got["n_rejected"] == ref["n_rejected"]
    assert got["n_flagged"] == ref["n_flagged"]
    np.testing.assert_allclose(got["det_precision"], ref["det_precision"],
                               atol=1e-9)
    np.testing.assert_allclose(got["det_recall"], ref["det_recall"],
                               atol=1e-9)


def test_defense_none_matches_pre_defense_baseline():
    """The undefended path must be byte-compatible with not passing a
    defense at all (the pre-PR behaviour)."""
    a = run_experiment("dqs", scenario="flip_6to2", cfg=_cfg(), seed=0,
                       **KW)
    b = run_experiment("dqs", scenario="flip_6to2", cfg=_cfg(), seed=0,
                       defense="none", **KW)
    assert a["acc"] == b["acc"]
    assert a["rep_gap"] == b["rep_gap"]


# ---------------------------------------------------------------------- #
# The sweep defenses axis: (scenario x defense) stacked == sequential,
# shared partitions, tidy keys.
# ---------------------------------------------------------------------- #
def test_sweep_defense_axis_matches_sequential():
    scns = ["noise_0.8", "flip_6to2"]
    dfns = ["none", "trimmed_mean+validation"]
    res = run_sweep(["dqs"], seeds=[0], scenarios=scns, defenses=dfns,
                    cfg=_cfg(), **KW)
    seq = run_sweep(["dqs"], seeds=[0], scenarios=scns, defenses=dfns,
                    cfg=_cfg(), stack_runs=False, **KW)
    assert len(res.runs) == 4
    for a, b in zip(res.runs, seq.runs):
        assert (a["scenario"], a["defense"]) == (b["scenario"],
                                                 b["defense"])
        np.testing.assert_allclose(a["acc"], b["acc"], atol=1e-7)
        assert a["n_flagged"] == b["n_flagged"]
        assert a["n_rejected"] == b["n_rejected"]
    # every run equals its sequential run_experiment twin
    for r in res.runs:
        twin = run_experiment("dqs", scenario=r["scenario"], cfg=_cfg(),
                              seed=0, defense=r["defense"], **KW)
        np.testing.assert_allclose(r["acc"], twin["acc"], atol=1e-6)
        assert r["n_flagged"] == twin["n_flagged"]
    # defense key threads through rows/select; partitions shared across
    # the defense axis (defenses never touch data)
    assert {r["defense"] for r in res.rows} == set(dfns)
    assert (res.select(scenario="noise_0.8", defense="none")[0]["malicious"]
            == res.select(scenario="noise_0.8",
                          defense="trimmed_mean+validation")[0]["malicious"])


# ---------------------------------------------------------------------- #
# Acceptance: the validation detector reverses the feature-noise rep gap
# (DESIGN.md §8 -> §9) while leaving the benign baseline's accuracy alone.
# ---------------------------------------------------------------------- #
@pytest.mark.slow
def test_validation_detector_reverses_feature_noise_rep_gap():
    cfg = FeelConfig(n_ues=10, n_malicious=3, min_selected=4)
    kw = dict(n_train=8000, n_test=1600, rounds=8, cfg=cfg)
    res = run_sweep(["dqs"], seeds=[1], scenarios=["noise_0.8"],
                    defenses=["none", "validation"], **kw)
    undefended = res.select(defense="none")[0]
    defended = res.select(defense="validation")[0]
    gap = lambda r: (r["final_reputation_honest"]
                     - r["final_reputation_malicious"])
    assert gap(undefended) < 0, \
        "feature noise should defeat Eq. 1 undefended (DESIGN.md §8)"
    assert gap(defended) > 0, \
        "the validation detector should reverse the rep gap"
    assert sum(defended["n_flagged"]) > 0
    # detector recall: the flagged set does hit the malicious UEs
    rec = [r for r in defended["det_recall"] if np.isfinite(r)]
    assert rec and max(rec) > 0


@pytest.mark.slow
def test_validation_detector_benign_accuracy_within_noise():
    cfg = FeelConfig(n_ues=10, n_malicious=3, min_selected=4)
    kw = dict(n_train=8000, n_test=1600, rounds=8, cfg=cfg)
    res = run_sweep(["dqs"], seeds=[1], scenarios=["none"],
                    defenses=["none", "validation"], **kw)
    acc_u = res.select(defense="none")[0]["acc"][-1]
    acc_d = res.select(defense="validation")[0]["acc"][-1]
    assert abs(acc_u - acc_d) < 0.05


# ---------------------------------------------------------------------- #
# Detector internals + Eq. 1 penalty plumbing.
# ---------------------------------------------------------------------- #
def test_detector_anomaly_and_stats():
    det = dfs.ValidationDetector(tol=0.1, weight=5.0)
    acc_val = np.array([[0.9, 0.4, 0.85, 0.2],     # uploads
                        [0.8, 0.8, 0.80, 0.8]])    # global baseline
    a = det.anomaly(acc_val)
    np.testing.assert_allclose(a, [0.0, 0.3, 0.0, 0.5], atol=1e-12)
    prec, rec = dfs.detection_stats(a > 0, [False, True, False, False])
    assert prec == 0.5 and rec == 1.0
    prec, rec = dfs.detection_stats([False] * 4, [False] * 4)
    assert np.isnan(prec) and np.isnan(rec)


@pytest.mark.parametrize("kernel", ["hybrid", "jax"])
def test_finalize_penalty_matches_tracker(kernel):
    """finalize_runs(penalties=...) == ReputationTracker.update(penalty=)
    per run, on both control-plane kernel layouts."""
    cfg = _cfg()
    rng = np.random.default_rng(0)
    R, K = 3, cfg.n_ues
    reps = rng.uniform(0.2, 1.0, (R, K))
    state = ctl.ControlState(
        policy_id=np.zeros(R, np.int32), sizes=np.ones((R, K)),
        divs=np.zeros((R, K)), r_min=np.ones((R, K)),
        reputations=reps.copy(), ages=np.ones((R, K)), cfg=cfg)
    sels = [np.sort(rng.choice(K, 4, replace=False)) for _ in range(R)]
    als = [rng.uniform(0, 1, 4) for _ in range(R)]
    ats = [rng.uniform(0, 1, 4) for _ in range(R)]
    pens = [rng.uniform(0, 0.5, 4), None, np.zeros(4)]
    ctl.finalize_runs(state, sels, als, ats, penalties=pens,
                      kernel=kernel)
    for i in range(R):
        rt = ReputationTracker(cfg)
        rt.values = reps[i].copy()
        rt.update(sels[i], als[i], ats[i], penalty=pens[i])
        np.testing.assert_allclose(state.reputations[i], rt.values,
                                   atol=0 if kernel == "hybrid" else 1e-12)


# ---------------------------------------------------------------------- #
# Property tests (hypothesis_compat — exercises the new st.booleans /
# st.tuples / st.one_of fallback strategies).
# ---------------------------------------------------------------------- #
@given(st.tuples(st.integers(3, 24), st.integers(0, 1000)),
       st.floats(0.05, 0.45))
@settings(max_examples=15, deadline=None)
def test_trimmed_mean_within_coordinate_bounds(nn_seed, trim):
    """Coordinate-wise trimmed mean lies within [min, max] of the
    uploads, per coordinate."""
    n, seed = nn_seed
    x = _flat(seed, n, 64)
    agg, _ = dfs.TrimmedMean(trim).aggregate_host(x)
    assert (agg >= x.min(axis=0) - 1e-7).all()
    assert (agg <= x.max(axis=0) + 1e-7).all()


@given(st.integers(2, 16), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_median_permutation_invariant(n, seed):
    x = _flat(seed, n, 64)
    perm = np.random.default_rng(seed + 1).permutation(n)
    a, _ = dfs.Median().aggregate_host(x)
    b, _ = dfs.Median().aggregate_host(x[perm])
    np.testing.assert_array_equal(a, b)


@given(st.integers(0, 1000), st.floats(0.2, 3.0), st.booleans())
@settings(max_examples=15, deadline=None)
def test_norm_clip_idempotent_and_bounded(seed, tau, batched):
    """Clipping is idempotent (a clipped cohort re-clips to itself) and
    every clipped update norm is <= tau (up to float32 rounding)."""
    n = 6
    x = _flat(seed, n, 128)
    g = _flat(seed + 1, 1, 128)[0]
    nc = dfs.NormClip(tau)
    if batched:
        once, _ = nc.clip_batched(jnp.asarray(x), jnp.asarray(g), n)
        twice, _ = nc.clip_batched(once, jnp.asarray(g), n)
        once, twice = np.asarray(once), np.asarray(twice)
    else:
        once, _ = nc.clip_host(x, g)
        twice, _ = nc.clip_host(once, g)
    np.testing.assert_allclose(twice, once, atol=1e-6)
    norms = np.linalg.norm((once - g[None]).astype(np.float64), axis=1)
    assert (norms <= tau * (1 + 1e-5)).all()


@given(st.tuples(st.integers(8, 20), st.integers(0, 1000)),
       st.one_of(st.sampled_from([1]), st.sampled_from([2, 3])))
@settings(max_examples=15, deadline=None)
def test_krum_selects_honest_update(nn_seed, f):
    """With f malicious outliers, f < n/2 - 1, honest updates clustered:
    single-Krum's pick is honest and multi-Krum rejects every outlier."""
    n, seed = nn_seed
    f = min(f, max((n - 1) // 2 - 1, 1))
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=0.1, size=(n, 96)).astype(np.float32)
    x[:f] += 50.0
    pick = dfs.Krum(n_select=1, f=f).select_host(x, n_byz=f)
    assert pick.size == 1 and pick[0] >= f
    multi = dfs.Krum(f=f).select_host(x, n_byz=f)
    assert not set(multi) & set(range(f))


# ---------------------------------------------------------------------- #
# Registry / coercion.
# ---------------------------------------------------------------------- #
def test_registry_and_coercion():
    assert dfs.as_defense(None) is dfs.NO_DEFENSE
    assert dfs.as_defense("median").aggregator == dfs.Median()
    d = dfs.with_validation(dfs.trimmed_mean(0.2))
    assert d.name == "trimmed_mean+validation"
    assert d.aggregator == dfs.TrimmedMean(0.2)
    assert d.detector is not None
    with pytest.raises(KeyError):
        dfs.as_defense("nope")
    with pytest.raises(TypeError):
        dfs.as_defense(3.14)
    assert {"none", "trimmed_mean", "median", "norm_clip", "krum",
            "validation",
            "trimmed_mean+validation"} <= set(dfs.DEFENSES)


# ---------------------------------------------------------------------- #
# registry completeness (auto-generated from DEFENSES — a new entry is
# exercised here with zero test edits; repro.check pins the coverage)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(dfs.DEFENSES))
def test_defense_registry_contract(name):
    """Every registered defense satisfies the DefensePolicy interface:
    registry key == name, frozen/hashable, and its components expose the
    host-oracle entry points both engines dispatch on."""
    d = dfs.DEFENSES[name]
    assert d.name == name
    hash(d)                                     # frozen dataclass
    assert d.benign == (d.aggregator is None and d.detector is None)
    agg = d.aggregator
    if agg is not None:
        # every aggregator family exposes a host oracle + batched twin
        assert (hasattr(agg, "aggregate_host")
                and hasattr(agg, "aggregate_batched")) \
            or (hasattr(agg, "clip_host") and hasattr(agg, "clip_batched")) \
            or (hasattr(agg, "select_host")
                and hasattr(agg, "select_batched"))
        # ... and dispatches through the shared loop-engine entry point
        rng = np.random.default_rng(0)
        plist = [{"w": rng.normal(size=10).astype(np.float32)}
                 for _ in range(6)]
        out, stats = dfs.aggregate_host(
            agg, plist, np.ones(6, np.float32), plist[0], n_byz=1)
        assert out["w"].shape == (10,)
        assert isinstance(stats, dfs.DefenseStats)
    if d.detector is not None:
        # (2, n): row 0 per-upload val accuracy, row 1 global baseline
        acc = np.array([[0.9, 0.2, 0.5], [0.6, 0.6, 0.6]], np.float64)
        a = d.detector.anomaly(acc)
        assert a.shape == (3,) and (a >= 0).all()
        assert d.detector.penalties(acc).shape == (3,)
