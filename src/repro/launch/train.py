"""Production training launcher: builds the mesh, shards state per
repro.sharding rules, and runs the jitted train step with checkpointing.

On a real v5e deployment:
    python -m repro.launch.train --arch yi-34b --shape train_4k --steps 1000
On this CPU container it is exercised with --host-mesh (devices that exist)
and reduced configs (--smoke).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import restore, save
from repro.configs import SHAPES, TrainConfig, get, reduced
from repro.data.tokens import batches, make_stream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import init_state, make_train_step
from repro.models import api
from repro.obs.clock import wall_clock
from repro.sharding import (activation_specs, batch_specs, opt_state_specs,
                            param_specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config variant")
    ap.add_argument("--host-mesh", action="store_true",
                    help="mesh over available devices instead of 16x16")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch (smoke runs)")
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    shape = SHAPES[args.shape]
    B = args.batch or shape.global_batch
    S = args.seq or shape.seq_len

    from repro.launch.mesh import ADAFACTOR_ARCHS   # optimizer policy
    opt_name = args.optimizer or (
        "adafactor" if args.arch in ADAFACTOR_ARCHS else "adamw")
    tcfg = TrainConfig(optimizer=opt_name, lr=args.lr, remat=not args.smoke)

    mesh = (make_host_mesh() if args.host_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    print(f"mesh {dict(mesh.shape)}  arch {cfg.name}  batch {B} seq {S}  "
          f"opt {opt_name}")

    with mesh:
        params, opt_state, step = init_state(cfg, tcfg, jax.random.PRNGKey(0))
        pspecs = param_specs(cfg, params, mesh)
        ospecs = opt_state_specs(opt_name, params, pspecs, mesh)
        ns = lambda t, s: jax.tree.map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)), t, s)
        params = ns(params, pspecs)
        opt_state = ns(opt_state, ospecs)

        if args.ckpt:
            state, meta = restore(args.ckpt, (params, opt_state, step))
            if state is not None:
                params, opt_state, step = ns(state[0], pspecs), \
                    ns(state[1], ospecs), state[2]
                print(f"restored step {meta['step']}")

        dax = [a for a in mesh.axis_names if a != "model"]
        bspec = P(tuple(dax) if len(dax) > 1 else dax[0], None)
        train_step = jax.jit(make_train_step(cfg, tcfg),
                             donate_argnums=(0, 1))

        stream = make_stream(max(200_000, 2 * B * S), cfg.vocab_size, seed=0)
        it = batches(stream, B, S, np.random.default_rng(0))
        t0 = wall_clock()
        for i in range(args.steps):
            host = next(it)
            batch = {"tokens": jax.device_put(
                jnp.asarray(host["tokens"]), NamedSharding(mesh, bspec))}
            params, opt_state, step, m = train_step(params, opt_state, step,
                                                    batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {int(step):6d} loss={float(m['loss']):.4f} "
                      f"({(wall_clock()-t0)/(i+1):.2f}s/step)")
            if args.ckpt and (i + 1) % args.ckpt_every == 0:
                save(args.ckpt, int(step), (params, opt_state, step))
    print("done")


if __name__ == "__main__":
    main()
