"""Batched control plane (core/control.py) vs the host numpy oracle.

Parity contract: the hybrid kernel layout is bit-for-bit against the host
path on every output; the pure-jax layout matches the integer outputs
(selection, costs, forced) bit-for-bit and floats to ~1 ulp (XLA FMA
contraction). Pinned per policy on random instances, plus full-run and
full-sweep parity through FeelServer / run_sweep.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import FeelConfig
from repro.core import control as ctl
from repro.core.diversity import diversity_index
from repro.core.poisoning import EASY_PAIR, LabelFlipAttack, pick_malicious
from repro.core.quality import data_quality_value
from repro.core.reputation import ReputationTracker
from repro.core.scheduler import (POLICIES, POLICY_IDS, top_value_schedule)
from repro.core.wireless import WirelessModel

ALL_POLICIES = list(POLICY_IDS)


class _Replay:
    """numpy-Generator stand-in replaying one pre-drawn permutation."""

    def __init__(self, perm):
        self.perm = perm

    def permutation(self, n):
        assert n == len(self.perm)
        return self.perm


def _random_instance(seed, k, r=10, deadline=None):
    """R runs x K UEs of random control state + one round of draws."""
    rng = np.random.default_rng(seed)
    cfg = FeelConfig(n_ues=k, **({} if deadline is None
                                 else {"deadline_s": deadline}))
    wms = [WirelessModel(cfg, np.random.default_rng(seed * 100 + i))
           for i in range(r)]
    sizes = (rng.integers(1, 31, (r, k)) * 50).astype(float)
    cpu = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, (r, k))
    t_train = np.stack([wms[i].train_time(sizes[i], cpu[i])
                        for i in range(r)])
    policies = [ALL_POLICIES[i % len(ALL_POLICIES)] for i in range(r)]
    state = ctl.ControlState(
        policy_id=np.array([POLICY_IDS[p] for p in policies], np.int32),
        sizes=sizes, divs=rng.uniform(0, 0.9, (r, k)),
        r_min=np.stack([wms[i].min_rate(t_train[i]) for i in range(r)]),
        reputations=rng.uniform(0, 1, (r, k)), ages=np.ones((r, k)),
        cfg=cfg)
    gains = np.stack([wms[i].draw_channels().gains for i in range(r)])
    perms = [rng.permutation(k) for _ in range(r)]
    rand_rank = np.stack([np.argsort(p) for p in perms])
    omega = np.full(r, cfg.omega_rep), np.full(r, cfg.omega_div)
    return cfg, wms, t_train, policies, state, gains, perms, rand_rank, omega


def _host_schedule(cfg, wm, t_train, policy, state, i, gains, perm):
    """The sequential oracle: FeelServer._schedule_round's host path,
    recomposed from the per-equation numpy functions."""
    I = diversity_index(state.divs[i], state.sizes[i], state.ages[i],
                        cfg.gamma)
    values = data_quality_value(state.reputations[i], I, cfg)
    costs = wm.cost(gains, t_train)
    if policy == "top_value":
        s = top_value_schedule(values, costs, cfg, cfg.min_selected)
    elif policy == "random":
        s = POLICIES[policy](values, costs, cfg, _Replay(perm))
    elif policy == "best_channel":
        s = POLICIES[policy](values, costs, cfg, gains)
    else:
        s = POLICIES[policy](values, costs, cfg)
    x, alpha, forced = s.x.copy(), s.alpha.copy(), False
    if not x.any():
        x[np.argmax(values)] = True
        alpha[:] = 0.0
        alpha[np.argmax(values)] = 1.0
        forced = True
    return x, alpha, costs, values, forced


@given(st.integers(0, 2**31 - 1), st.integers(8, 60))
@settings(max_examples=15, deadline=None)
def test_batched_schedule_matches_host_per_policy(seed, k):
    """schedule_runs (hybrid layout) == host oracle, bit-for-bit, for a
    random mix of all five policies stacked in one call."""
    (cfg, wms, t_train, policies, state, gains, perms, rand_rank,
     omega) = _random_instance(seed, k)
    x, alpha, costs, values, forced = ctl.schedule_runs(
        state, gains, rand_rank, *omega, kernel="hybrid")
    for i, p in enumerate(policies):
        hx, halpha, hcosts, hvalues, hforced = _host_schedule(
            cfg, wms[i], t_train[i], p, state, i, gains[i], perms[i])
        np.testing.assert_array_equal(x[i], hx, err_msg=p)
        np.testing.assert_array_equal(costs[i], hcosts, err_msg=p)
        np.testing.assert_array_equal(alpha[i], halpha, err_msg=p)
        np.testing.assert_array_equal(values[i], hvalues, err_msg=p)
        assert bool(forced[i]) == hforced, p


@given(st.integers(0, 2**31 - 1), st.integers(8, 40))
@settings(max_examples=8, deadline=None)
def test_jax_kernel_matches_hybrid(seed, k):
    """The pure-jax layout (accelerator path) picks the same UEs/costs as
    the hybrid layout; floats agree to ~1 ulp (XLA FMA contraction)."""
    _, _, _, _, state, gains, _, rand_rank, omega = _random_instance(
        seed, k)
    h = ctl.schedule_runs(state, gains, rand_rank, *omega, kernel="hybrid")
    j = ctl.schedule_runs(state, gains, rand_rank, *omega, kernel="jax")
    np.testing.assert_array_equal(h[0], j[0])        # x
    np.testing.assert_array_equal(h[2], j[2])        # costs
    np.testing.assert_array_equal(h[4], j[4])        # forced
    np.testing.assert_allclose(h[1], j[1], rtol=1e-14, atol=0)   # alpha
    np.testing.assert_allclose(h[3], j[3], rtol=1e-14, atol=0)   # values


def test_all_policies_forced_when_deadline_blown():
    """t_train >= T for every UE -> every cost is K+1, problem (8) is
    infeasible: every policy (except top_value, which ignores wireless)
    reports forced=True with exactly one whole-band UE."""
    _, _, _, policies, state, gains, _, rand_rank, omega = \
        _random_instance(3, 12, deadline=1e-6)
    x, alpha, costs, values, forced = ctl.schedule_runs(
        state, gains, rand_rank, *omega)
    assert np.all(costs == state.cfg.n_ues + 1)
    for i, p in enumerate(policies):
        if p == "top_value":
            assert not forced[i]
            continue
        assert forced[i], p
        assert x[i].sum() == 1
        k = int(np.flatnonzero(x[i])[0])
        assert k == int(np.argmax(values[i]))
        assert alpha[i, k] == 1.0


@given(st.integers(0, 2**31 - 1), st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_finalize_runs_matches_reputation_tracker(seed, n_sel):
    """finalize_runs == per-run ReputationTracker.update + age rules."""
    rng = np.random.default_rng(seed)
    R, K = 6, 12
    cfg = FeelConfig(n_ues=K)
    rep = rng.uniform(0, 1, (R, K))
    ages = rng.integers(1, 10, (R, K)).astype(float)
    state = ctl.ControlState(
        policy_id=np.zeros(R, np.int32), sizes=np.ones((R, K)),
        divs=np.ones((R, K)), r_min=np.ones((R, K)),
        reputations=rep.copy(), ages=ages.copy(), cfg=cfg)
    sels = [rng.choice(K, size=n_sel, replace=False) for _ in range(R)]
    accs_l = [rng.uniform(0, 1, n_sel) for _ in range(R)]
    accs_t = [rng.uniform(0, 1, n_sel) for _ in range(R)]
    ctl.finalize_runs(state, sels, accs_l, accs_t)
    for i in range(R):
        rt = ReputationTracker(cfg)
        rt.values = rep[i].copy()
        rt.update(sels[i], accs_l[i], accs_t[i])
        np.testing.assert_allclose(state.reputations[i], rt.values,
                                   rtol=0, atol=1e-12)
        expect_ages = ages[i] + 1.0
        expect_ages[sels[i]] = 1.0
        np.testing.assert_array_equal(state.ages[i], expect_ages)


# ---------------------------------------------------------------------- #
# End-to-end parity through the server / sweep
# ---------------------------------------------------------------------- #
KW = dict(n_train=2500, n_test=300, rounds=3)


@pytest.mark.slow
def test_run_experiment_control_parity():
    from repro.federated.simulation import run_experiment
    for policy in ("dqs", "random", "top_value"):
        a = run_experiment(policy, EASY_PAIR, seed=0, control="batched",
                           **KW)
        b = run_experiment(policy, EASY_PAIR, seed=0, control="host", **KW)
        np.testing.assert_allclose(a["acc"], b["acc"], atol=1e-7)
        assert a["malicious_selected"] == b["malicious_selected"]
        np.testing.assert_allclose(a["objective"], b["objective"],
                                   atol=1e-9)
        np.testing.assert_allclose(
            a["final_reputation_honest"], b["final_reputation_honest"],
            atol=1e-9)


@pytest.mark.slow
def test_full_sweep_control_parity():
    """run_sweep with the stacked batched control plane reproduces the
    host-control sweep run for run: same selections, curves, objectives."""
    from repro.federated.simulation import run_sweep
    a = run_sweep(["dqs", "max_count"], seeds=[0, 1],
                  attack_pairs=[EASY_PAIR], control="batched", **KW)
    b = run_sweep(["dqs", "max_count"], seeds=[0, 1],
                  attack_pairs=[EASY_PAIR], control="host", **KW)
    assert len(a.runs) == len(b.runs)
    for ra, rb in zip(a.runs, b.runs):
        assert (ra["policy"], ra["seed"]) == (rb["policy"], rb["seed"])
        np.testing.assert_allclose(ra["acc"], rb["acc"], atol=1e-7)
        assert ra["malicious_selected"] == rb["malicious_selected"]
        np.testing.assert_allclose(ra["objective"], rb["objective"],
                                   atol=1e-9)
        assert ra["forced"] == rb["forced"]
        np.testing.assert_allclose(
            ra["final_reputation_malicious"],
            rb["final_reputation_malicious"], atol=1e-9)
    for rowa, rowb in zip(a.rows, b.rows):
        assert rowa["round"] == rowb["round"]
        np.testing.assert_allclose(rowa["acc"], rowb["acc"], atol=1e-7)
