"""Vectorized cohort engine vs the sequential loop oracle, the
padding/masking contract, the degenerate-schedule fallback, and the Eq. 1
reputation ordering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FeelConfig
from repro.core.poisoning import EASY_PAIR, LabelFlipAttack, pick_malicious
from repro.core.reputation import ReputationTracker
from repro.data.partition import pad_clients, partition
from repro.data.synthetic_mnist import generate
from repro.federated import cohort
from repro.federated.server import FeelServer
from repro.federated.simulation import run_experiment
from repro.models.mlp import (mlp_accuracy, mlp_init, mlp_sgd_epoch,
                              mlp_sgd_epoch_masked)

KW = dict(n_train=3000, n_test=400, rounds=5)


def _k10_cfg():
    return FeelConfig(n_ues=10, n_malicious=2)


# ---------------------------------------------------------------------- #
# Tentpole acceptance: the engines produce the same experiment.
# ---------------------------------------------------------------------- #
def test_vectorized_matches_loop_fixed_seed_k10():
    """Identical accuracy curve (within 1e-5 per round) on a fixed-seed
    K=10 experiment — the loop engine is the correctness oracle."""
    a = run_experiment("dqs", EASY_PAIR, cfg=_k10_cfg(), seed=0,
                       engine="loop", **KW)
    b = run_experiment("dqs", EASY_PAIR, cfg=_k10_cfg(), seed=0,
                       engine="vectorized", **KW)
    np.testing.assert_allclose(b["acc"], a["acc"], atol=1e-5)
    np.testing.assert_allclose(b["source_acc"], a["source_acc"], atol=1e-5)
    # same schedules round for round -> same malicious-selection counts
    assert b["malicious_selected"] == a["malicious_selected"]
    assert b["final_reputation_malicious"] == pytest.approx(
        a["final_reputation_malicious"], abs=1e-5)


# ---------------------------------------------------------------------- #
# Padding / masking contract
# ---------------------------------------------------------------------- #
def test_masked_epoch_padding_is_a_no_op():
    """Training on a zero-padded, masked dataset reproduces the unpadded
    epoch: padding batches contribute exactly zero gradient."""
    rng = np.random.default_rng(0)
    n, d, pad_to = 100, 784, 250
    x = rng.random((n, d)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    params = mlp_init(jax.random.PRNGKey(0))

    plain = mlp_sgd_epoch(params, jnp.asarray(x), jnp.asarray(y), 0.1, 50)

    xp = np.zeros((pad_to, d), np.float32)
    yp = np.zeros(pad_to, np.int32)
    m = np.zeros(pad_to, np.float32)
    xp[:n], yp[:n], m[:n] = x, y, 1.0
    masked = mlp_sgd_epoch_masked(params, jnp.asarray(xp), jnp.asarray(yp),
                                  jnp.asarray(m), 0.1, 50)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(masked)):
        np.testing.assert_allclose(a, b, atol=1e-7)


def test_pad_clients_layout():
    train, _ = generate(1500, 100, seed=0)
    rng = np.random.default_rng(0)
    clients = partition(train, 6, rng)
    padded = pad_clients(clients, multiple_of=50)
    assert padded.x.shape[0] == 6
    assert padded.max_samples % 50 == 0
    assert padded.max_samples >= max(c.size for c in clients)
    for k, c in enumerate(clients):
        n = c.size
        assert padded.sizes[k] == n
        np.testing.assert_array_equal(padded.x[k, :n], c.data.x)
        np.testing.assert_array_equal(padded.y[k, :n], c.data.y)
        assert padded.mask[k, :n].all()
        assert not padded.mask[k, n:].any()
        assert not padded.x[k, n:].any()


def test_cohort_eval_matches_subset_eval():
    """The vmapped masked test evaluation equals per-model subset scoring."""
    _, test = generate(200, 300, seed=1)
    params = [mlp_init(jax.random.PRNGKey(i)) for i in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params)
    masks = np.stack([np.isin(test.y, [0, 1, 2]),
                      np.isin(test.y, [5]),
                      np.ones_like(test.y, bool)]).astype(np.float32)
    got = np.asarray(cohort.cohort_eval(
        stacked, jnp.asarray(test.x), jnp.asarray(test.y),
        jnp.asarray(masks)))
    for i, p in enumerate(params):
        m = masks[i].astype(bool)
        want = float(mlp_accuracy(p, jnp.asarray(test.x[m]),
                                  jnp.asarray(test.y[m])))
        assert got[i] == pytest.approx(want, abs=1e-6)


# ---------------------------------------------------------------------- #
# Degenerate-schedule fallback (satellite): the log must describe the
# forced participant set, not the empty schedule.
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("engine", ["loop", "vectorized"])
def test_degenerate_schedule_log_reflects_forced_participant(engine):
    train, test = generate(800, 150, seed=2)
    rng = np.random.default_rng(2)
    cfg = FeelConfig(n_ues=4, n_malicious=0, rounds=1)
    clients = partition(train, cfg.n_ues, rng)
    server = FeelServer(cfg, clients, test, rng, engine=engine)
    # all-infeasible channel draw: every UE costs more than the K-fraction
    # budget, so the scheduler returns the empty schedule
    server.wireless.cost = lambda gains, t_train: np.full(
        cfg.n_ues, cfg.n_ues + 1, float)

    before = server.reputation.values.copy()
    params_before = jax.tree.map(np.asarray, server.params)
    log = server.run_round(0)

    assert log.selected.size == 1
    k = int(log.selected[0])
    assert k == int(np.argmax(log.values))
    # the logged objective describes the actual (forced) participant set
    assert log.objective == pytest.approx(float(log.values[k]))
    # the forced UE really trained: the global model moved
    moved = any(np.abs(np.asarray(a) - b).max() > 0
                for a, b in zip(jax.tree.leaves(server.params),
                                jax.tree.leaves(params_before)))
    assert moved
    # only the forced participant's reputation was touched
    np.testing.assert_array_equal(np.delete(log.reputations, k),
                                  np.delete(before, k))


# ---------------------------------------------------------------------- #
# Eq. 1 reputation ordering (satellite audit): honest UEs must end above
# a poisoner even though the beta1 term penalises above-average reports.
# ---------------------------------------------------------------------- #
def test_reputation_orders_honest_above_poisoner():
    cfg = FeelConfig(n_ues=4)
    tracker = ReputationTracker(cfg)
    everyone = np.arange(4)
    # honest UEs report what the server then measures (acc_local==acc_test);
    # UE 3 is a label-flip poisoner: high self-report, poor test accuracy
    acc_local = np.array([0.85, 0.70, 0.75, 0.90])
    acc_test = np.array([0.85, 0.70, 0.75, 0.30])
    for _ in range(5):
        tracker.update(everyone, acc_local, acc_test)
    assert tracker.values[3] < tracker.values[:3].min()
    # the best honest UE (above-average report, beta1 penalty applies)
    # still outranks the poisoner by a wide margin
    assert tracker.values[0] - tracker.values[3] > 0.5


def test_reputation_beta1_penalises_above_average_reports():
    """Documented Eq. 1 behaviour (see core/reputation.py): with beta2
    silent (report == test), the relative beta1 term alone moves
    above-average reporters down and below-average reporters up."""
    cfg = FeelConfig(n_ues=2, eta=1.0)
    tracker = ReputationTracker(cfg)
    tracker.values[:] = 0.5
    acc = np.array([0.9, 0.5])           # both honest: report == test
    tracker.update(np.arange(2), acc, acc)
    assert tracker.values[0] < 0.5 < tracker.values[1]
