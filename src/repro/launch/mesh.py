"""Production mesh builders. v5e pod = 16x16 = 256 chips; multi-pod = 2 pods.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state — required because the
dry-run launcher must set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (~4 links usable/chip)

# 398B/671B configs need a factored-moment optimizer to fit 16 GB/chip
ADAFACTOR_ARCHS = {"deepseek-v3-671b", "jamba-1.5-large-398b"}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small ("data", "model") mesh over whatever devices exist.

    Live consumers: the population plane (``core/population.py``) shards
    the N-candidate axis over this mesh's data axis (DESIGN.md §12), and
    tests / CPU examples use it as the stand-in production mesh. When
    ``model_parallel`` does not divide the device count the remainder
    devices are left out of the mesh (n // mp data slices).
    """
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))
