"""Partition rules: params / optimizer state / batches / decode caches.

Baseline layout (single pod 16x16, axes ("data", "model")):
  * Megatron-style tensor parallelism over ``model``: attention head
    projections and MLP hidden dims are column/row sharded.
  * Batch (and MoE dispatch) over ``data``; multi-pod adds a leading ``pod``
    axis that extends the batch sharding.
  * MoE experts: ``(data x model)``-sharded when E divides the full mesh
    (DeepSeek's 256), else expert dim over ``model`` with the expert FFN dim
    over ``data`` (Jamba's 16 x 24576, Moonlight/Qwen's 6x/15x 1408) — this is
    what fits the 398B/671B configs in 16 GB/chip.
  * Optimizer moments: ZeRO-style — the first unsharded, divisible dim is
    additionally sharded over ``data``.
  * Decode caches: batch over ``data`` when divisible, sequence over
    ``model`` (GQA kv-head counts are below 16, so head-sharding the cache is
    not viable); batch=1 long-context shards sequence over the whole mesh.

All rules return PartitionSpecs; GSPMD pads non-divisible dims (e.g. Qwen's 60
experts, vocab 50280) — correctness is unaffected, the dry-run prices it.

``data_axes`` + ``named`` are also the sharding primitives of the
population plane (``core/population.py``, DESIGN.md §12): the (R, N)
control arrays shard their N-candidate trailing axis over the mesh's
data axes with ``named(mesh, PartitionSpec(None, data_axes(mesh)))``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

# parameter-name rule tables (trailing dims, before the scan-stack prefix)
_COL = {"wq", "wk", "wv", "wg", "wu", "in_proj", "wuq", "wuk", "wuv", "wdq",
        "proj", "src_proj", "embed", "lm_head", "conv_w"}
_ROW = {"wo", "wd", "out_proj"}
_VEC_MODEL = {"bq", "bk", "bv", "conv_b", "A_log", "D", "dt_bias"}
_REPL = {"router", "wkr", "wdkv", "norm1", "norm2", "norm_x", "final_norm",
         "enc_norm", "q_norm", "k_norm", "kv_norm", "norm_h", "norm_e"}


def _path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(int(p.idx))
    return out


def _is_stacked(names) -> bool:
    return any(n in ("blocks", "enc_blocks", "dec_blocks") for n in names
               if isinstance(n, str))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _expert_spec(name: str, shape, mesh: Mesh) -> P:
    """(E, d, f) / (E, f, d) expert tensors."""
    E = shape[0]
    total = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    dax = data_axes(mesh)
    if E % total == 0:
        return P((*dax, "model"), None, None)
    if name in ("wg", "wu"):
        return P("model", None, dax)
    return P("model", dax, None)          # wd: (E, f, d)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    n = 1
    for a in (entry if isinstance(entry, tuple) else (entry,)):
        n *= mesh.shape[a]
    return n


def _fix(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries that do not evenly divide the dim (NamedSharding on
    inputs requires exact divisibility); if a 2D+ weight loses its only
    sharded dim, fall back to sharding the first divisible dim over model."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    fixed = [s if shape[i] % _axis_size(mesh, s) == 0 else None
             for i, s in enumerate(parts)]
    if any(fixed) or not any(parts):
        return P(*fixed)
    for i, dim in enumerate(shape):              # fallback: row-shard
        if dim % mesh.shape["model"] == 0 and dim >= mesh.shape["model"]:
            fixed[i] = "model"
            break
    return P(*fixed)


def param_rule(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    names = _path_names(path)
    name = next((n for n in reversed(names) if isinstance(n, str)), "")
    stacked = _is_stacked(names)
    shape = leaf.shape
    core = shape[1:] if stacked else shape
    nd = len(core)

    if name in ("wg", "wu", "wd") and nd == 3:       # routed experts
        spec = _expert_spec(name, core, mesh)
    elif name == "norm" and nd == 1:                 # ssm gated norm (d_in,)
        spec = P("model")
    elif name in _VEC_MODEL:
        spec = P("model") if nd == 1 else P(None, "model")
    elif name in _ROW:
        spec = P("model", *([None] * (nd - 1)))
    elif name in _COL:
        spec = P(*([None] * (nd - 1)), "model")
    elif name in _REPL or nd == 0:
        spec = P(*([None] * nd))
    else:
        spec = P(*([None] * nd))
    if stacked:
        spec = P(None, *spec)
    return _fix(spec, shape, mesh)


def param_specs(cfg: ModelConfig, params, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_rule(path, leaf, cfg, mesh), params)


# ---------------------------------------------------------------------- #
# Optimizer state: ZeRO the first unsharded divisible dim over data
# ---------------------------------------------------------------------- #
def _zero_shard(spec: P, shape, mesh: Mesh) -> P:
    dax = data_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in dax]))
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for s in parts:
        for a in (s if isinstance(s, tuple) else (s,)):
            used.add(a)
    if used & set(dax):               # expert tensors already span data
        return P(*parts)
    for i, (s, dim) in enumerate(zip(parts, shape)):
        if s is None and dim % n == 0 and dim >= n:
            parts[i] = dax if len(dax) > 1 else dax[0]
            break
    return P(*parts)


def opt_state_specs(opt_name: str, params, pspecs, mesh: Mesh):
    def like(p, spec):
        return _zero_shard(spec, p.shape, mesh)

    if opt_name in ("sgd",):
        return {}
    if opt_name in ("momentum",):
        return {"m": jax.tree.map(like, params, pspecs)}
    if opt_name in ("adam", "adamw"):
        m = jax.tree.map(like, params, pspecs)
        return {"m": m, "v": m}
    if opt_name == "adafactor":
        def fact(p, spec):
            parts = list(spec) + [None] * (p.ndim - len(spec))
            if p.ndim >= 2:
                return {"vr": P(*parts[:-1]), "vc": P(*parts[:-2], parts[-1])}
            return {"v": P(*parts)}
        return {"s": jax.tree.map(fact, params, pspecs)}
    raise KeyError(opt_name)


# ---------------------------------------------------------------------- #
# Batch / cache specs
# ---------------------------------------------------------------------- #
def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    dax = data_axes(mesh)
    bax = dax if len(dax) > 1 else dax[0]
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": P(bax, None)}
        if cfg.is_encoder_decoder:
            specs["src"] = P(bax, None, None)
        return specs
    # decode: cache + token
    nd = int(np.prod([mesh.shape[a] for a in dax]))
    batch_shardable = shape.global_batch % nd == 0 and shape.global_batch >= nd
    b = bax if batch_shardable else None
    seq = "model" if batch_shardable else ("model", *dax)

    def cache_spec(path, leaf):
        names = _path_names(path)
        name = next((n for n in reversed(names) if isinstance(n, str)), "")
        stacked = _is_stacked(names) or "layers" in names or "head_layers" in names
        core = leaf.shape[1:] if _is_stacked(names) else leaf.shape
        pre = (None,) if _is_stacked(names) else ()
        if name in ("k", "v"):        # (B, C, Hkv, hd)
            return P(*pre, b, seq, None, None)
        if name in ("xk", "xv"):      # cross-attn (B, S_src, Hkv, hd)
            return P(*pre, b, None, None, None)
        if name in ("ckv", "kr"):     # MLA (B, C, r)
            return P(*pre, b, seq, None)
        if name == "conv":            # (B, K-1, ch)
            return P(*pre, b, None, "model")
        if name == "state":           # (B, H, N, P)
            return P(*pre, b, "model", None, None)
        if name in ("index", "slot_pos"):
            return P() if leaf.ndim == 0 else P(None)
        return P(*([None] * leaf.ndim))

    def checked(path, leaf):
        return _fix(cache_spec(path, leaf), leaf.shape, mesh)

    cache = jax.tree_util.tree_map_with_path(checked,
                                             _cache_shape_tree(cfg, shape))
    return {"cache": cache, "token": P(b, None)}


def _cache_shape_tree(cfg, shape):
    from repro.models import api
    return jax.eval_shape(
        lambda: api.cache_init(cfg, shape.global_batch, shape.seq_len))


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
