"""Roofline extraction unit tests: HLO collective parsing + term math."""
import pytest

from repro.launch import roofline as rl
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

HLO = """
ENTRY %main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[1024,256]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[64,64]{1,0} all-reduce(%x), to_apply=%sum
  %ars = (f32[32,32]{1,0}, f32[32,32]{1,0}) all-reduce-start(%a, %b)
  %rs = bf16[16,256]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[8,8]{1,0} all-to-all(%z), dimensions={1}
  %cp = bf16[4,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_a_collective = f32[999,999]{1,0} add(%a, %b)
}
"""


def test_collective_bytes_parsing():
    out = rl.collective_bytes(HLO)
    assert out["all-gather"] == 1024 * 256 * 2
    assert out["all-reduce"] == 64 * 64 * 4 + 2 * 32 * 32 * 4  # incl. -start tuple
    assert out["reduce-scatter"] == 16 * 256 * 2
    assert out["all-to-all"] == 8 * 8 * 4
    assert out["collective-permute"] == 4 * 4 * 2


def test_terms_math():
    coll = {"all-gather": ICI_BW, "all-reduce": ICI_BW,
            "reduce-scatter": 0, "all-to-all": 0, "collective-permute": 0}
    t = rl.roofline_terms(PEAK_FLOPS_BF16, HBM_BW, coll)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(3.0)   # AR counts 2x
    assert rl.dominant(t) == "collective_s"


def test_model_flops_moe_counts_active_only():
    from repro.configs import get
    dense = rl.model_flops(get("yi-34b"), 1000, train=True)
    assert dense == pytest.approx(
        6.0 * (get("yi-34b").param_count(True)
               - get("yi-34b").vocab_size * get("yi-34b").d_model) * 1000)
    moe_cfg = get("deepseek-v3-671b")
    active = moe_cfg.param_count(active_only=True)
    total = moe_cfg.param_count(active_only=False)
    assert active < 0.15 * total     # 671B total, ~37B active
