from repro.data.partition import (ClientData, GROUP_SIZE, label_histogram,
                                  partition)
from repro.data.synthetic_mnist import Dataset, N_CLASSES, generate
from repro.data.tokens import batches, make_stream, zipf_probs

__all__ = ["ClientData", "GROUP_SIZE", "label_histogram", "partition",
           "Dataset", "N_CLASSES", "generate", "batches", "make_stream",
           "zipf_probs"]
