"""Counter / gauge / observation registry for the telemetry plane.

Three primitive kinds, all host-side Python scalars (no device
traffic, no RNG draws — the zero-semantic-footprint contract of
DESIGN.md §14):

* **counter** — monotone accumulator (``prefilter escalations``,
  ``compile cache misses``).
* **gauge**   — last-written value plus its running max (``async heap
  depth``, ``population nbytes``, ``compile-cache entries``).
* **observation** — streaming summary of a value series
  (count/sum/min/max plus a bounded reservoir of the most recent
  values for percentile reporting): ``padding-waste ratio``, ``bucket
  occupancy``, ``upload ages``.

The registry is owned by the tracer singleton; every mutating helper
on the tracer early-returns when tracing is disabled, so the metrics
layer costs nothing by default.
"""
from __future__ import annotations

from typing import Dict, List

_RESERVOIR = 4096  # most-recent values kept per observation series


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value", "max")

    def __init__(self) -> None:
        self.value = 0.0
        self.max = float("-inf")

    def set(self, v: float) -> None:
        self.value = v
        if v > self.max:
            self.max = v


class Observation:
    __slots__ = ("count", "total", "min", "max", "recent")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.recent: List[float] = []

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self.recent) >= _RESERVOIR:
            del self.recent[: _RESERVOIR // 2]
        self.recent.append(v)


class MetricRegistry:
    """Name -> metric maps with get-or-create access and one snapshot."""

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.observations: Dict[str, Observation] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def observation(self, name: str) -> Observation:
        o = self.observations.get(name)
        if o is None:
            o = self.observations[name] = Observation()
        return o

    def snapshot(self) -> Dict:
        """JSON-ready view of every metric (for the JSONL sink)."""
        out: Dict = {"counters": {}, "gauges": {}, "observations": {}}
        for k, c in sorted(self.counters.items()):
            out["counters"][k] = c.value
        for k, g in sorted(self.gauges.items()):
            out["gauges"][k] = {"value": g.value, "max": g.max}
        for k, o in sorted(self.observations.items()):
            mean = o.total / o.count if o.count else 0.0
            out["observations"][k] = {"count": o.count, "sum": o.total,
                                      "min": o.min if o.count else 0.0,
                                      "max": o.max if o.count else 0.0,
                                      "mean": mean}
        return out

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.observations.clear()
