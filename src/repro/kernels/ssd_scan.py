"""Pallas TPU SSD (Mamba2) chunked scan.

Grid (B, H, n_chunks), chunk dimension innermost and sequential; the running
inter-chunk state (N x P, fp32) lives in VMEM scratch — it is never
materialised in HBM, and neither is the (Q x Q) intra-chunk decay matrix
(built in VMEM per chunk). This is precisely the memory-traffic hot spot the
XLA path pays for (fp32 L-matrices in HBM; see EXPERIMENTS.md §Perf) and the
reason this kernel exists.

Per chunk (math identical to models.ssm.ssd_chunked / kernels.ref.ssd_ref):
    cum   = cumsum(dt * A)                       (Q,)
    Lmat  = tril(exp(cum_i - cum_j))             (Q, Q)
    y     = ((C B^T) * Lmat) @ (x dt)  +  (C exp(cum)) @ state
    state = exp(cum_Q) * state + (B exp(cum_Q - cum))^T @ (x dt)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # (Q,)
    B_ = b_ref[0, :, 0].astype(jnp.float32)              # (Q, N)
    C_ = c_ref[0, :, 0].astype(jnp.float32)              # (Q, N)
    A = a_ref[pl.program_id(1)]                          # per-head scalar

    cum = jnp.cumsum(dt * A)                             # (Q,) <= 0
    seg = cum[:, None] - cum[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(iq >= jq, jnp.exp(seg), 0.0)        # (Q, Q)

    xdt = x * dt[:, None]                                # (Q, P)
    g = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, Q)
    y = jax.lax.dot_general(g * lmat, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    state = state_ref[...]                               # (N, P)
    y += jax.lax.dot_general(C_ * jnp.exp(cum)[:, None], state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    decay_end = jnp.exp(cum[-1] - cum)                   # (Q,)
    s_local = jax.lax.dot_general(B_ * decay_end[:, None], xdt,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (N,P)
    state_ref[...] = state * jnp.exp(cum[-1]) + s_local
    y_ref[0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B_, C_, *, chunk=128, interpret=False):
    """x (B,L,H,P); dt (B,L,H) fp32; A (H,); B_/C_ (B,L,H,N) -> y (B,L,H,P).

    Head-broadcast of grouped B/C is done by the caller (ops.py)."""
    Bb, L, H, P = x.shape
    N = B_.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # A (H,)... sliced below
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, L, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt.astype(jnp.float32),
      B_, C_)
