"""Registry–test cross-referencing (DESIGN.md §11b).

Every entry of a behaviour registry (attack SCENARIOS, DEFENSES, TASKS,
scheduler POLICY_IDS) must be exercised by the parity matrix tests, and
every public Pallas kernel wrapper must ship a ``*_ref`` oracle twin
plus a test. Coverage is established by *test AST evidence*, not by
running the tests:

- a ``pytest.mark.parametrize`` whose argvalues expression mentions the
  registry symbol itself (e.g. ``sorted(atk.SCENARIOS)``) covers every
  entry by construction — the matrix can never lag the registry;
- otherwise each entry name must appear as a string literal somewhere
  in the designated test module.

The checkers import the live registries, so registering a new scenario
/ defense / task / policy without matrix coverage fails tier-1 at
``tests/test_check.py`` — before any parity test would have had a
chance to miss it.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Sequence

from repro.check.common import (CheckContext, Violation, dotted_name)


def _string_literals(tree: ast.AST) -> set:
    return {n.value for n in ast.walk(tree)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def _parametrizes_over(tree: ast.AST, symbol: str) -> bool:
    """True if some ``parametrize(...)`` call's argument expression
    references ``symbol`` (as a bare name or attribute tail)."""
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and (dotted_name(node.func) or "").endswith("parametrize")):
            continue
        for arg in list(node.args) + [k.value for k in node.keywords]:
            for sub in ast.walk(arg):
                name = dotted_name(sub)
                if name and name.split(".")[-1] == symbol:
                    return True
    return False


def registry_coverage(entries: Iterable[str], symbol: str,
                      test_tree: ast.AST, test_rel: str,
                      extra_trees: Sequence[ast.AST] = ()
                      ) -> List[Violation]:
    """Violations for registry entries with no test evidence.

    ``entries`` — the live registry's keys; ``symbol`` — the registry's
    attribute name (``SCENARIOS``, ``DEFENSES``, ...); ``test_tree`` —
    the designated matrix test module's AST; ``extra_trees`` — further
    modules whose string literals also count as evidence.
    """
    trees = [test_tree, *extra_trees]
    if any(_parametrizes_over(t, symbol) for t in trees):
        return []
    literals = set()
    for t in trees:
        literals |= _string_literals(t)
    return [Violation(
        rule="registry-coverage", path=test_rel, line=1,
        message=f"registry entry `{name}` of `{symbol}` has no "
                f"coverage in {test_rel} — parametrize the matrix over "
                f"the registry (e.g. `sorted({symbol})`) or reference "
                "the entry explicitly")
        for name in sorted(entries) if name not in literals]


def kernel_ref_twins(kernels: Iterable[str], ref_module,
                     test_tree: Optional[ast.AST], test_rel: str
                     ) -> List[Violation]:
    """Every public kernel wrapper needs a ``<name>_ref`` oracle in
    ``kernels/ref.py`` and a reference to BOTH names in the kernel test
    module."""
    out: List[Violation] = []
    names_in_test = set()
    if test_tree is not None:
        for node in ast.walk(test_tree):
            name = dotted_name(node)
            if name:
                names_in_test.add(name.split(".")[-1])
        names_in_test |= _string_literals(test_tree)
    for k in sorted(kernels):
        twin = f"{k}_ref"
        if not hasattr(ref_module, twin):
            out.append(Violation(
                rule="kernel-ref-twin", path="src/repro/kernels/ref.py",
                line=1,
                message=f"kernel `{k}` has no `{twin}` oracle twin in "
                        "kernels/ref.py — every Pallas kernel ships a "
                        "pure-jnp reference"))
            continue
        if test_tree is not None and not (
                k in names_in_test and twin in names_in_test):
            missing = [n for n in (k, twin) if n not in names_in_test]
            out.append(Violation(
                rule="kernel-ref-twin", path=test_rel, line=1,
                message=f"kernel `{k}`: {', '.join(missing)} never "
                        f"referenced in {test_rel} — the kernel/ref "
                        "pair must be pinned by a parity test"))
    return out


# --------------------------------------------------------------------- #
# repo wiring
# --------------------------------------------------------------------- #
def _test_tree(ctx: CheckContext, name: str):
    path = ctx.tests_root / name
    if not path.exists():
        return None
    return ast.parse(path.read_text(), filename=str(path))


def check_registries(ctx: CheckContext) -> List[Violation]:
    from repro.core import attacks as atk
    from repro.core import defenses as dfs
    from repro.core.scheduler import POLICY_IDS
    from repro.federated.task import TASKS

    out: List[Violation] = []
    specs = [
        (atk.SCENARIOS, "SCENARIOS", "test_attacks.py", ()),
        (dfs.DEFENSES, "DEFENSES", "test_defenses.py", ()),
        (TASKS, "TASKS", "test_task_lm.py", ()),
        # policies have no single matrix file; any sweep/control test
        # referencing the name (or a parametrize over POLICY_IDS) counts
        (POLICY_IDS, "POLICY_IDS", "test_scheduler.py",
         ("test_sweep.py", "test_control.py", "test_simulation.py")),
    ]
    for entries, symbol, test_name, extra in specs:
        tree = _test_tree(ctx, test_name)
        if tree is None:
            out.append(Violation(
                rule="registry-coverage", path=f"tests/{test_name}",
                line=1,
                message=f"matrix test module for `{symbol}` not found"))
            continue
        extra_trees = [t for t in (_test_tree(ctx, e) for e in extra)
                       if t is not None]
        out.extend(registry_coverage(entries, symbol, tree,
                                     f"tests/{test_name}", extra_trees))
    return out


def check_kernel_twins(ctx: CheckContext) -> List[Violation]:
    from repro.kernels import ops, ref

    kernels = [n for n in ops.__all__
               if n not in ("use_pallas", "ref")]
    return kernel_ref_twins(kernels, ref,
                            _test_tree(ctx, "test_kernels.py"),
                            "tests/test_kernels.py")
