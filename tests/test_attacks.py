"""Threat-model plane (core/attacks.py): the masked batched application vs
the per-client oracle, a parity matrix over every registered scenario x
both engines x both control planes, attack-invariant property tests, and
the legacy-knob (model_poison_scale x no_attack) contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.core.poisoning import pick_malicious
from repro.data.partition import pad_clients, partition
from repro.data.synthetic_mnist import generate
from repro.federated.server import FeelServer
from repro.federated.simulation import run_experiment, run_sweep
from repro.models.mlp import mlp_init

KW = dict(n_train=1200, n_test=300, rounds=2)


def _cfg():
    return FeelConfig(n_ues=8, n_malicious=2, min_selected=3)


# ---------------------------------------------------------------------- #
# Tentpole acceptance: EVERY registered scenario, batched == oracle under
# both engines and both control planes (K=10-style parity runs).
# ---------------------------------------------------------------------- #
_REFS = {}


def _matrix_args(name):
    """Token-space scenarios run the matrix on the LM task (their data
    attack refuses feature/label datasets loudly); everything else on
    the default MNIST task."""
    scn = atk.as_scenario(name)
    if scn.data is not None and hasattr(scn.data, "poison_tokens"):
        return (FeelConfig(n_ues=8, n_malicious=2, min_selected=3,
                           task="lm_tiny"),
                dict(n_train=960, n_test=240, rounds=2))
    return _cfg(), KW


def _reference(name):
    """(loop, host) oracle run for a scenario — cached across the matrix."""
    if name not in _REFS:
        cfg, kw = _matrix_args(name)
        _REFS[name] = run_experiment("dqs", scenario=name, cfg=cfg,
                                     seed=0, engine="loop",
                                     control="host", **kw)
    return _REFS[name]


@pytest.mark.parametrize("engine,control", [("vectorized", "batched"),
                                            ("vectorized", "host"),
                                            ("loop", "batched")])
@pytest.mark.parametrize("name", sorted(atk.SCENARIOS))
def test_scenario_parity_matrix(name, engine, control):
    """Batched jnp attack application == host oracle for every registered
    scenario, under both cohort engines and both control planes."""
    ref = _reference(name)
    cfg, kw = _matrix_args(name)
    got = run_experiment("dqs", scenario=name, cfg=cfg, seed=0,
                         engine=engine, control=control, **kw)
    np.testing.assert_allclose(got["acc"], ref["acc"], atol=1e-5)
    np.testing.assert_allclose(got["source_acc"], ref["source_acc"],
                               atol=1e-5)
    np.testing.assert_allclose(got["attack_success"],
                               ref["attack_success"], atol=1e-5)
    assert got["malicious_selected"] == ref["malicious_selected"]
    np.testing.assert_allclose(got["rep_gap"], ref["rep_gap"], atol=1e-6)
    assert got["recovery_rounds"] == ref["recovery_rounds"]


def test_heterogeneous_scenario_sweep():
    """Acceptance: >= 4 distinct threat models (label flip, noise,
    free-rider, model poison) run in ONE stacked sweep, each reproducing
    its sequential oracle."""
    scns = ["flip_6to2", "noise_0.8", "free_rider", "sign_flip"]
    res = run_sweep(["dqs"], seeds=[0], scenarios=scns, cfg=_cfg(), **KW)
    seq = run_sweep(["dqs"], seeds=[0], scenarios=scns, cfg=_cfg(),
                    stack_runs=False, **KW)
    assert [r["scenario"] for r in res.runs] == scns
    for a, b in zip(res.runs, seq.runs):
        np.testing.assert_allclose(a["acc"], b["acc"], atol=1e-7)
        np.testing.assert_allclose(a["attack_success"],
                                   b["attack_success"], atol=1e-6)
        assert a["malicious_selected"] == b["malicious_selected"]
    # scenario key threads through rows and select()
    assert {r["scenario"] for r in res.rows} == set(scns)
    assert len(res.select(scenario="free_rider")) == 1
    # the two partition families are shared: both pure-model-attack runs
    # report the same malicious set as the noise run's seed
    assert (res.select(scenario="free_rider")[0]["malicious"]
            == res.select(scenario="sign_flip")[0]["malicious"])


# ---------------------------------------------------------------------- #
# Satellite: masked _apply_attacks == the per-client .at[i].set oracle,
# bit for bit.
# ---------------------------------------------------------------------- #
def _random_stack(key, n):
    params = mlp_init(jax.random.PRNGKey(key))
    leaves, treedef = jax.tree.flatten(params)
    rng = np.random.default_rng(key)
    stacked = [jnp.asarray(rng.normal(size=(n,) + l.shape)
                           .astype(np.float32)) for l in leaves]
    return params, jax.tree.unflatten(treedef, stacked)


@pytest.mark.parametrize("scale", [-1.0, 0.0, 3.0])
def test_masked_apply_stacked_matches_per_client_loop(scale):
    """ONE masked tree_map == the replaced O(n_malicious) dispatch loop,
    bitwise, for sign-flip / free-rider / boosted scales."""
    g, stacked = _random_stack(0, 6)
    mal = np.array([True, False, True, True, False, False])
    attack = atk.ModelAttack(scale=scale)

    got = attack.apply_stacked(stacked, g, mal)

    want = stacked
    for i in np.flatnonzero(mal):
        poisoned = attack.apply_loop(
            g, jax.tree.map(lambda l, i=int(i): l[i], stacked))
        want = jax.tree.map(lambda l, p, i=int(i): l.at[i].set(p),
                            want, poisoned)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_server_masked_apply_matches_oracle_end_to_end():
    """A full vectorized experiment with the masked ``_apply_attacks``
    must equal the same experiment routed through the kept per-client
    twin (``_apply_attacks_loop``) — bit-for-bit global params."""
    cfg = _cfg()
    train, test = generate(1200, 300, seed=3)

    def build():
        rng = np.random.default_rng(3)
        malicious = pick_malicious(cfg.n_ues, cfg.n_malicious, rng)
        clients = partition(train, cfg.n_ues, rng, malicious)
        return FeelServer(cfg, clients, test, rng,
                          scenario=atk.model_poison(-1.0))

    a, b = build(), build()
    b._apply_attacks = b._apply_attacks_loop
    for t in range(2):
        a.run_round(t)
        b.run_round(t)
        for la, lb in zip(jax.tree.leaves(a.params),
                          jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------- #
# Satellite: property tests for attack invariants (hypothesis_compat).
# ---------------------------------------------------------------------- #
@given(st.integers(0, 1000), st.floats(0.05, 1.0))
@settings(max_examples=15, deadline=None)
def test_label_flip_touches_only_source_rows_exact_count(seed, frac):
    """Label flip touches only source-class rows and flips exactly
    round(flip_fraction * n_source) of them; features untouched."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 200))
    y = rng.integers(0, 10, n).astype(np.int32)
    x = rng.random((n, 4)).astype(np.float32)
    attack = atk.LabelFlip(((6, 2),), flip_fraction=frac)
    x2, y2 = attack.poison(x, y, rng)
    changed = np.flatnonzero(y2 != y)
    assert (y[changed] == 6).all()                    # only source rows
    assert (y2[changed] == 2).all()                   # flipped to target
    n_src = int((y == 6).sum())
    want = n_src if frac >= 1.0 else int(np.round(frac * n_src))
    assert changed.size == want
    np.testing.assert_array_equal(x2, x)              # labels only


@given(st.integers(0, 1000), st.floats(0.1, 0.9))
@settings(max_examples=10, deadline=None)
def test_label_flip_batched_twin_matches_host(seed, frac):
    """The jnp twin applied to the stacked padded layout == the per-client
    host oracle, given the same float32 draws."""
    rng = np.random.default_rng(seed)
    train, _ = generate(800, 50, seed=seed % 7)
    clients = partition(train, 4, rng)
    padded = pad_clients(clients, multiple_of=50)
    mal = np.array([True, False, True, False])
    attack = atk.LabelFlip(((6, 2), (8, 4)), flip_fraction=frac)
    u = np.zeros(padded.y.shape, np.float32)
    want = padded.y.copy()
    for i, c in enumerate(clients):
        ui = attack.draw(rng, c.data.x, c.data.y)
        u[i, :c.size] = ui
        if mal[i]:
            _, yi = attack.apply_host(c.data.x, c.data.y, ui)
            want[i, :c.size] = yi
    _, got = attack.apply_rows(padded.x, padded.y, padded.mask, mal, u)
    np.testing.assert_array_equal(np.asarray(got), want)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_free_rider_update_equals_global_params(seed):
    """scale=0: the uploaded update IS the (reference) global model."""
    g, stacked = _random_stack(seed, 3)
    out = atk.ModelAttack(scale=0.0).apply_loop(
        g, jax.tree.map(lambda l: l[0], stacked))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_sign_flip_is_involution(seed):
    """Applying the scale=-1 attack twice recovers the local model (up to
    float rounding of g + (g - l))."""
    g, stacked = _random_stack(seed, 1)
    l = jax.tree.map(lambda x: x[0], stacked)
    attack = atk.ModelAttack(scale=-1.0)
    twice = attack.apply_loop(g, attack.apply_loop(g, l))
    for a, b in zip(jax.tree.leaves(twice), jax.tree.leaves(l)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@given(st.integers(0, 1000), st.floats(0.1, 2.0))
@settings(max_examples=10, deadline=None)
def test_noise_attack_preserves_labels_and_shapes(seed, sigma):
    rng = np.random.default_rng(seed)
    x = rng.random((30, 8)).astype(np.float32)
    y = rng.integers(0, 10, 30).astype(np.int32)
    attack = atk.FeatureNoise(sigma=sigma)
    x2, y2 = attack.poison(x, y, np.random.default_rng(seed + 1))
    assert x2.shape == x.shape and x2.dtype == x.dtype
    np.testing.assert_array_equal(y2, y)              # labels preserved
    assert (x2 >= 0.0).all() and (x2 <= 1.0).all()    # stays in-domain
    assert np.any(x2 != x)
    # batched twin: noise lands only on malicious rows' REAL samples
    K, S = 3, 40
    xs = rng.random((K, S, 8)).astype(np.float32)
    valid = np.zeros((K, S), np.float32)
    valid[:, :25] = 1.0
    xs[:, 25:] = 0.0                                  # padding is zero
    eps = attack.draw(np.random.default_rng(seed + 2), xs, None)
    mal = np.array([True, False, True])
    got, _ = attack.apply_rows(xs, np.zeros((K, S), np.int32), valid,
                               mal, eps)
    got = np.asarray(got)
    np.testing.assert_array_equal(got[1], xs[1])      # honest untouched
    np.testing.assert_array_equal(got[:, 25:], xs[:, 25:])  # padding zero
    assert np.any(got[0, :25] != xs[0, :25])


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_pick_malicious_rng_determinism(seed):
    a = pick_malicious(50, 5, np.random.default_rng(seed))
    b = pick_malicious(50, 5, np.random.default_rng(seed))
    np.testing.assert_array_equal(a, b)
    assert a.size == 5 and np.unique(a).size == 5
    assert (a >= 0).all() and (a < 50).all()


def test_malicious_schedules():
    """Intermittent gates whole rounds; the colluding round-robin
    partitions the malicious set across a period."""
    mal = np.array([True, True, False, True, False])
    rank = np.array([0, 1, -1, 2, -1])
    inter = atk.MaliciousSchedule("intermittent", period=3, duty=1)
    assert (inter.active(0, mal, rank) == mal).all()
    assert not inter.active(1, mal, rank).any()
    assert not inter.active(2, mal, rank).any()
    rr = atk.MaliciousSchedule("roundrobin", period=2, duty=2)
    a0, a1 = rr.active(0, mal, rank), rr.active(1, mal, rank)
    assert not (a0 & a1).any()                        # disjoint groups
    np.testing.assert_array_equal(a0 | a1, mal)       # cover the set
    np.testing.assert_array_equal(rr.active(2, mal, rank), a0)  # periodic


# ---------------------------------------------------------------------- #
# Satellite: the model_poison_scale x no_attack legacy contract.
# ---------------------------------------------------------------------- #
def test_legacy_model_poison_replaces_data_attack():
    scn = atk.legacy_scenario((6, 2), False, -1.0, 0.0)
    assert scn.data is None and scn.model.scale == -1.0
    assert scn.data_key() == "mal_only"               # clean partition
    flip = atk.legacy_scenario((6, 2), False, None, 0.0)
    assert isinstance(flip.data, atk.LabelFlip) and flip.model is None


def test_legacy_no_attack_wins_over_model_poison():
    """no_attack=True disables EVERYTHING, including model poisoning: the
    run is the benign control (no malicious flags set)."""
    scn = atk.legacy_scenario((6, 2), True, -1.0, 0.5)
    assert scn.benign and scn.watch == (6, 2)
    r = run_experiment("dqs", (6, 2), cfg=_cfg(), seed=1, no_attack=True,
                       model_poison_scale=-1.0, **KW)
    clean = run_experiment("dqs", (6, 2), cfg=_cfg(), seed=1,
                           no_attack=True, **KW)
    assert r["malicious_selected"] == [0] * KW["rounds"]
    np.testing.assert_allclose(r["acc"], clean["acc"], atol=1e-7)
    # benign run: Eq. 1 never separates anyone
    assert all(np.isnan(g) for g in r["rep_gap"])


def test_legacy_model_poison_branch_equals_explicit_scenario():
    """The legacy knob path and the equivalent explicit scenario are the
    same experiment (both on run_experiment and run_sweep)."""
    legacy = run_experiment("dqs", (8, 4), cfg=_cfg(), seed=0,
                            model_poison_scale=-1.0, **KW)
    scn = dataclasses.replace(atk.model_poison(-1.0), watch=(8, 4))
    explicit = run_experiment("dqs", cfg=_cfg(), seed=0, scenario=scn,
                              **KW)
    np.testing.assert_allclose(legacy["acc"], explicit["acc"], atol=1e-7)
    np.testing.assert_allclose(legacy["source_acc"],
                               explicit["source_acc"], atol=1e-6)
    sweep = run_sweep(["dqs"], seeds=[0], attack_pairs=[(8, 4)],
                      cfg=_cfg(), model_poison_scale=-1.0, **KW)
    np.testing.assert_allclose(sweep.runs[0]["acc"], legacy["acc"],
                               atol=1e-7)


def test_scenario_supersedes_legacy_knobs():
    with pytest.raises(AssertionError):
        run_experiment("dqs", cfg=_cfg(), seed=0, scenario="sign_flip",
                       model_poison_scale=-1.0, **KW)
    with pytest.raises(AssertionError):
        run_sweep(["dqs"], seeds=[0], scenarios=["sign_flip"],
                  cfg=_cfg(), no_attack=True, **KW)
    # a conflicting pair axis fails loudly instead of being dropped
    with pytest.raises(AssertionError):
        run_experiment("dqs", (8, 4), cfg=_cfg(), seed=0,
                       scenario="sign_flip", **KW)
    with pytest.raises(AssertionError):
        run_sweep(["dqs"], seeds=[0], attack_pairs=[(8, 4)],
                  scenarios=["sign_flip"], cfg=_cfg(), **KW)
    # ... as does an explicit watch_class on a scenario-driven server
    train, test = generate(800, 150, seed=0)
    rng = np.random.default_rng(0)
    clients = partition(train, 4, rng)
    with pytest.raises(AssertionError):
        FeelServer(FeelConfig(n_ues=4, n_malicious=0), clients, test,
                   rng, scenario="sign_flip", watch_class=3)


# ---------------------------------------------------------------------- #
# Registry / shim / metric helpers.
# ---------------------------------------------------------------------- #
def test_registry_and_shim():
    assert atk.as_scenario("sign_flip") is atk.SCENARIOS["sign_flip"]
    pair = atk.as_scenario((6, 2))
    assert pair.data.pairs == ((6, 2),) and pair.watch == (6, 2)
    assert atk.as_scenario(pair) is pair
    with pytest.raises(AssertionError):
        atk.register(atk.model_poison(-1.0))          # duplicate name
    with pytest.raises(TypeError):
        atk.as_scenario(12)
    # data attacks compose with round schedules: the server's twin-array
    # gather substitutes a clean copy of the poisoned data in OFF rounds
    # (tests/test_task_lm.py pins the round-gating behaviour end to end)
    scn = atk.intermittent(atk.label_flip(6, 2), 2)
    assert scn.data is not None and scn.schedule.period == 2


def test_recovery_rounds_metric():
    assert atk.recovery_rounds([np.nan, np.nan]) == -1
    assert atk.recovery_rounds([]) == -1
    assert atk.recovery_rounds([0.9, 0.8, 0.4, 0.2]) == 2
    assert atk.recovery_rounds([0.9, 0.2, 0.6, 0.1]) == 3
    assert atk.recovery_rounds([0.1, 0.2, 0.3]) == 0
    assert atk.recovery_rounds([0.2, 0.9], threshold=0.95) == 0
    # final round still at/above threshold == not recovered within the
    # horizon: the return equals the curve length, never less
    assert atk.recovery_rounds([0.9, 0.9, 0.9]) == 3
    assert atk.recovery_rounds([0.1, 0.1, 0.9]) == 3


def test_reputation_gap_metric():
    rep = np.array([1.0, 0.2, 0.8, 0.4])
    mal = np.array([False, True, False, True])
    assert atk.reputation_gap(rep, mal) == pytest.approx(0.9 - 0.3)
    assert np.isnan(atk.reputation_gap(rep, np.zeros(4, bool)))


# ---------------------------------------------------------------------- #
# registry completeness (auto-generated from SCENARIOS — a new entry is
# exercised here with zero test edits; repro.check pins the coverage)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(atk.SCENARIOS))
def test_scenario_registry_contract(name):
    """Every registered scenario satisfies the AttackScenario interface:
    registry key == name, frozen/hashable (partition-cache identity),
    well-typed components, and a live schedule."""
    scn = atk.SCENARIOS[name]
    assert scn.name == name
    hash(scn)                                   # frozen dataclass
    assert hash(scn.data_key()) is not None     # partition-cache key
    assert scn.benign == (scn.data is None and scn.model is None
                          and scn.report is None)
    if scn.data is not None:
        assert (hasattr(scn.data, "poison")
                or hasattr(scn.data, "poison_tokens"))
    if scn.model is not None:
        assert hasattr(scn.model, "apply_stacked")
        assert hasattr(scn.model, "apply_loop")
    if scn.report is not None:
        assert hasattr(scn.report, "apply")
    mal = np.array([True, False, True, False])
    rank = np.array([0, -1, 1, -1])
    for t in range(3):
        act = scn.schedule.active(t, mal, rank)
        assert act.dtype == bool and act.shape == mal.shape
        assert not act[~mal].any()              # honest UEs never act
    if isinstance(scn.data, (atk.LabelFlip, atk.TokenFlip)):
        assert scn.watch == scn.data.pairs[0]
