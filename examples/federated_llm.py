"""DQS-scheduled federated fine-tuning of a transformer LM — the paper's
technique composed with the framework's model zoo, using the jax-native
cohort step (shard_map + masked weighted psum) from DESIGN.md §3.

    PYTHONPATH=src python examples/federated_llm.py --rounds 4

Each of N clients holds a domain-skewed synthetic token stream (non-IID);
per round the server scores clients with the data-quality value V_k
(diversity over token histograms + reputation from held-out perplexity gaps)
and schedules with the greedy knapsack. Selected clients run local SGD inside
the distributed cohort step; aggregation is the masked weighted psum.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.configs.base import FeelConfig, ModelConfig
from repro.core import (WirelessModel, data_quality_value, diversity_index,
                        dqs_schedule, gini_simpson)
from repro.data.tokens import make_stream
from repro.federated.distributed import make_cohort_step
from repro.models import api

CFG = ModelConfig(name="fed-lm", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512,
                  dtype="float32", citation="[in-repo federated-LM demo]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    n = args.clients
    rng = np.random.default_rng(0)
    feel = FeelConfig(n_ues=n, model_size_bits=5e6 * 8)
    wireless = WirelessModel(feel, rng)

    # non-IID client corpora: domain-shifted Markov streams
    streams = [make_stream(8_000, CFG.vocab_size, seed=1, domain=d)
               for d in range(n)]
    sizes = np.array([len(s) for s in streams], float)
    divs = np.array([gini_simpson(s % 10, 10) for s in streams])
    reputation = np.ones(n)
    ages = np.ones(n)

    key = jax.random.PRNGKey(0)
    params = api.init(CFG, key)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))

    def loss_fn(p, batch):
        loss, _ = api.loss(CFG, p, batch)
        return loss

    cohort = make_cohort_step(mesh, loss_fn, lr=5e-3, local_steps=4)
    held_out = make_stream(2_000, CFG.vocab_size, seed=99, domain=999)

    def ppl(p):
        tok = jnp.asarray(held_out[: 16 * args.seq].reshape(16, args.seq))
        l, _ = api.loss(CFG, p, {"tokens": tok})
        return float(l)

    base = ppl(params)
    print(f"round -: held-out loss {base:.4f}")
    for t in range(args.rounds):
        I = diversity_index(divs, sizes, ages, feel.gamma)
        V = data_quality_value(reputation, I, feel)
        tt = wireless.train_time(sizes / 64.0,
                                 rng.uniform(feel.cpu_hz_min,
                                             feel.cpu_hz_max, n))
        costs = wireless.cost(wireless.draw_channels().gains, tt)
        sched = dqs_schedule(V, costs, feel)
        select = jnp.asarray(sched.x.astype(np.float32))

        # one batch per client, stacked on the client axis
        starts = rng.integers(0, 7_000, n)
        toks = np.stack([s[i:i + args.seq + 1][None]
                         for s, i in zip(streams, starts)])  # (n,1,S+1)
        batch = {"tokens": jnp.asarray(toks[:, :, :args.seq])}
        # pad client axis up to the device count
        ndev = mesh.shape["data"]
        if n % ndev:
            pad = ndev - n % ndev
            batch = {k: jnp.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1))
                     for k, v in batch.items()}
            select = jnp.pad(select, (0, pad))
            w = jnp.pad(jnp.asarray(sizes, jnp.float32), (0, pad))
        else:
            w = jnp.asarray(sizes, jnp.float32)

        new_params = cohort(params, batch, w, select)
        l = ppl(new_params)
        ages += 1
        ages[sched.selected] = 1
        # reputation: clients whose inclusion round didn't help lose standing
        reputation[sched.selected] = np.clip(
            reputation[sched.selected] - feel.eta * 0.1 * np.sign(l - base),
            0, 1)
        base, params = l, new_params
        print(f"round {t}: held-out loss {l:.4f} "
              f"selected={sched.selected.tolist()}")
    print("done")


if __name__ == "__main__":
    main()
