"""DQS scheduler (paper Alg. 2) invariants + exact-knapsack comparison."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import FeelConfig
from repro.core.scheduler import (best_channel_schedule, brute_force_schedule,
                                  dqs_schedule, max_count_schedule,
                                  random_schedule, top_value_schedule)


def _cfg(k):
    return FeelConfig(n_ues=k)


@given(st.integers(0, 2**31 - 1), st.integers(5, 30))
@settings(max_examples=30, deadline=None)
def test_dqs_respects_budget_and_feasibility(seed, k):
    rng = np.random.default_rng(seed)
    values = rng.uniform(0, 2, k)
    costs = rng.integers(1, k + 2, k)          # k+1 == infeasible
    s = dqs_schedule(values, costs, _cfg(k))
    # (8c/8d): total bandwidth budget
    assert s.alpha.sum() <= 1.0 + 1e-9
    assert np.all((s.alpha >= 0) & (s.alpha <= 1))
    # selected UEs get exactly their cost in fractions; unselected get none
    np.testing.assert_allclose(s.alpha[s.x], costs[s.x] / k)
    assert np.all(s.alpha[~s.x] == 0)
    # infeasible UEs are never selected (deadline, 8b)
    assert not np.any(s.x[costs > k])


@given(st.integers(0, 2**31 - 1), st.integers(4, 10))
@settings(max_examples=30, deadline=None)
def test_dqs_vs_bruteforce_half_approximation(seed, k):
    """Modified greedy (density pack, then best-single-UE fallback) is a
    1/2-approximation of the exact knapsack optimum — the claim pinned in
    the scheduler module docstring. Costs include infeasible (k+1) draws."""
    rng = np.random.default_rng(seed)
    values = rng.uniform(0.1, 1.0, k)
    costs = rng.integers(1, k + 2, k)
    g = dqs_schedule(values, costs, _cfg(k))
    b = brute_force_schedule(values, costs, _cfg(k))
    assert g.objective() <= b.objective() + 1e-9
    assert g.objective() >= 0.5 * b.objective() - 1e-9


def test_dqs_prefers_value_density():
    """The greedy order is V/c: with the budget nearly full, the two dense
    cheap UEs beat swapping one of them for the expensive third."""
    values = np.array([1.0, 0.9, 0.85])
    costs = np.array([1, 1, 2])
    s = dqs_schedule(values, costs, FeelConfig(n_ues=3))
    np.testing.assert_array_equal(s.x, [True, True, False])


def test_dqs_single_item_fallback():
    """Density-greedy alone picks the cheap low-value UE and blocks the
    budget; the modified-greedy fallback must schedule the single
    high-value UE instead (this is what makes the 1/2-approximation
    bound of the module docstring hold)."""
    values = np.array([0.5, 0.9])
    costs = np.array([1, 2])          # densities 0.5 vs 0.45, budget 2
    s = dqs_schedule(values, costs, _cfg(2))
    assert not s.x[0] and s.x[1]
    assert s.objective() == pytest.approx(0.9)
    assert s.alpha[1] == pytest.approx(1.0)   # c=2 of K=2 fractions


@given(st.integers(0, 2**31 - 1), st.integers(5, 40))
@settings(max_examples=30, deadline=None)
def test_packing_policy_invariants_property(seed, k):
    """Problem (8) invariants for EVERY packing policy on random instances
    (previously only dqs had property coverage): bandwidth budget (8c/8d),
    alpha[k] == cost[k]/K for selected UEs, zero bandwidth for unselected,
    and no infeasible (c > K) UE ever selected (8b)."""
    rng = np.random.default_rng(seed)
    values = rng.uniform(0, 2, k)
    costs = rng.integers(1, k + 2, k)          # k+1 == infeasible
    gains = rng.uniform(1e-12, 1e-8, k)
    cfg = _cfg(k)
    scheds = {
        "dqs": dqs_schedule(values, costs, cfg),
        "random": random_schedule(values, costs, cfg, rng),
        "best_channel": best_channel_schedule(values, costs, cfg, gains),
        "max_count": max_count_schedule(values, costs, cfg),
    }
    for name, s in scheds.items():
        assert s.alpha.sum() <= 1.0 + 1e-9, name
        assert np.all((s.alpha >= 0) & (s.alpha <= 1)), name
        np.testing.assert_allclose(s.alpha[s.x], costs[s.x] / k,
                                   err_msg=name)
        assert np.all(s.alpha[~s.x] == 0), name
        assert not np.any(s.x[costs > k]), name
        # objective only credits selected UEs
        assert s.objective() == pytest.approx(float(values[s.x].sum()))


@given(st.integers(0, 2**31 - 1), st.integers(5, 40))
@settings(max_examples=20, deadline=None)
def test_top_value_policy_invariants_property(seed, k):
    """top_value ignores the wireless constraint by design (§V-B.1): it
    must still select exactly n UEs, split the band uniformly among them,
    and report the REAL Eq. 9 costs."""
    rng = np.random.default_rng(seed)
    values = rng.uniform(0, 2, k)
    costs = rng.integers(1, k + 2, k)
    n = int(rng.integers(1, k + 1))
    s = top_value_schedule(values, costs, _cfg(k), n)
    assert s.x.sum() == n
    assert s.alpha.sum() == pytest.approx(1.0)
    np.testing.assert_allclose(s.alpha[s.x], 1.0 / n)
    np.testing.assert_array_equal(s.cost, costs)
    assert s.objective() == pytest.approx(
        float(np.sort(values)[-n:].sum()))


def test_all_policies_feasible():
    k = 20
    rng = np.random.default_rng(0)
    values = rng.uniform(0, 1, k)
    costs = rng.integers(1, 8, k)
    gains = rng.uniform(1e-12, 1e-8, k)
    cfg = _cfg(k)
    for s in [dqs_schedule(values, costs, cfg),
              random_schedule(values, costs, cfg, rng),
              best_channel_schedule(values, costs, cfg, gains),
              max_count_schedule(values, costs, cfg)]:
        assert s.alpha.sum() <= 1 + 1e-9
        assert not np.any(s.x[costs > k])


def test_max_count_maximises_count():
    k = 10
    rng = np.random.default_rng(1)
    values = rng.uniform(0, 1, k)
    costs = rng.integers(1, 5, k)
    cfg = _cfg(k)
    mc = max_count_schedule(values, costs, cfg)
    dq = dqs_schedule(values, costs, cfg)
    assert mc.x.sum() >= dq.x.sum()


def test_top_value_selects_n():
    cfg = FeelConfig(n_ues=50, min_selected=5)
    rng = np.random.default_rng(2)
    values = rng.uniform(0, 1, 50)
    costs = rng.integers(1, 52, 50)
    s = top_value_schedule(values, costs, cfg, 5)
    assert s.x.sum() == 5
    assert set(s.selected) == set(np.argsort(-values)[:5])


def test_top_value_logs_real_costs():
    """Accounting regression: the seed fabricated ``costs = ones(K)`` so
    every top_value round log misreported the wireless costs. Selection
    still ignores the channel (UEs with infeasible cost K+1 stay eligible)
    but Schedule.cost must be the actual Eq. 9 array."""
    cfg = FeelConfig(n_ues=6, min_selected=2)
    values = np.array([0.9, 0.8, 0.1, 0.2, 0.3, 0.4])
    costs = np.array([7, 7, 1, 1, 1, 1])     # the two best are infeasible
    s = top_value_schedule(values, costs, cfg, 2)
    np.testing.assert_array_equal(s.cost, costs)
    # no-wireless-constraint semantics: top-2 by value, despite cost K+1
    assert set(s.selected) == {0, 1}
