"""Tier-1 gate for benchmarks/bench_round.py: the smoke mode runs a tiny
instance of the engine, sweep, control-plane, threat-model, defense-plane
and LM-task benchmarks with loud internal assertions — a bench
regression (engine crash, padding-waste regression, sweep/sequential
divergence, host/batched control-plane selection mismatch,
masked/per-client attack-application mismatch, host/batched robust
aggregation mismatch, LM loop/vectorized loss divergence,
prefilter/exact population-schedule divergence, async/sync
zero-latency parity break) fails here
instead of rotting silently until the next manual bench run."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_bench_round_smoke():
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_round", "--smoke"],
        capture_output=True, text=True, cwd=ROOT,
        env={**os.environ,
             "PYTHONPATH": os.path.join(ROOT, "src") + os.pathsep
             + os.environ.get("PYTHONPATH", "")},
        timeout=1200)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "smoke OK" in r.stderr
    # CSV rows for both engines + the control-plane bench made it out
    assert any(line.startswith("unbucketed,") for line in
               r.stdout.splitlines())
    assert any(line.startswith("vectorized,") for line in
               r.stdout.splitlines())
    assert any(line.startswith("control,") for line in
               r.stdout.splitlines())
    # threat-model plane: masked-vs-loop apply rows + the scenario sweep
    assert any(line.startswith("attacks,") and not line.endswith("speedup")
               for line in r.stdout.splitlines())
    assert any(line.startswith("attacks_sweep,") for line in
               r.stdout.splitlines())
    # defense plane: host-vs-batched robust-aggregator rows for all four
    # aggregators made it out (parity asserted inside the worker)
    for agg in ("trimmed_mean", "median", "norm_clip", "krum"):
        assert any(line.startswith(f"defense,{agg},") for line in
                   r.stdout.splitlines()), agg
    # LM task plane: loop + vectorized rows (loss bit-parity asserted in
    # bench_llm itself; the flash rows are manual-only — interpret mode)
    for eng in ("loop", "vectorized"):
        assert any(line.startswith(f"llm,{eng},") for line in
                   r.stdout.splitlines()), eng
    # population plane: exact-vs-prefilter scaling rows + the forced
    # 2-device mesh row (prefilter == exact asserted inside the worker)
    assert any(line.startswith("population,") for line in
               r.stdout.splitlines())
    assert any(line.startswith("population_mesh,")
               and line.split(",")[2] == "2"
               for line in r.stdout.splitlines())
    # async plane: event-driven rows (sync/buffer/deadline cells; the
    # zero-latency bit-parity gate is asserted inside the worker)
    for mode in ("sync", "async_buffer", "async_deadline"):
        assert any(line.startswith(f"async,{mode},") for line in
                   r.stdout.splitlines()), mode
    # observability plane: the traced cell's summary row made it out
    # (smoke itself asserts the report sees schedule/train phases plus
    # roofline context for both — DESIGN.md §14)
    assert any(line.startswith("trace,") for line in
               r.stdout.splitlines())
