"""Beyond-paper robustness extensions (the paper's §VI future-work items),
now a threat-model x DEFENSE matrix: every scenario family from
core/attacks.py — model poisoning (sign-flip / boosted), free-riders
(zero and stale updates), dishonest reporting on top of a label flip,
feature noise, and intermittent / colluding malicious schedules — runs
against DQS and the random baseline, each cell undefended AND under the
``trimmed_mean+validation`` defense (core/defenses.py), as ONE stacked
``run_sweep`` (scenarios and defenses are just more slices of the batched
cohort + control planes). Plus the original adaptive-omega and K=100
scale studies.

The headline question (DESIGN.md §8 -> §9): does the validation detector
turn the feature-noise rep gap positive? The summary prints it and the
JSON records per-cell ``rep_gap`` / detection precision/recall.

    PYTHONPATH=src python examples/robustness_extensions.py [--fast]

Writes results/robustness.json.
"""
import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.federated.simulation import run_experiment, run_sweep

WATCH = (8, 4)        # the hard pair: all scenario metrics watch it


def _w(scenario, tag):
    """Rename + point the scenario's metrics at the hard pair."""
    return dataclasses.replace(scenario, name=tag, watch=WATCH)


SCENARIO_MATRIX = [
    _w(atk.model_poison(-1.0), "model_poison_signflip"),
    _w(atk.model_poison(4.0), "model_poison_boost4"),
    _w(atk.free_rider(0), "free_rider"),
    _w(atk.free_rider(2), "stale_rider"),
    _w(atk.lie_boost(0.3, data=atk.LabelFlip((WATCH,))), "lying_flip"),
    _w(atk.feature_noise(0.8), "feature_noise"),
    _w(atk.intermittent(atk.model_poison(-1.0), period=2),
       "intermittent_signflip"),
    _w(atk.colluding(atk.model_poison(-1.0), period=2),
       "colluding_signflip"),
    atk.AttackScenario("control", watch=WATCH),      # benign baseline
]


def summarize(res, scenario, policy, defense):
    runs = res.select(scenario=scenario, policy=policy, defense=defense)
    curves = res.averaged(("acc", "attack_success", "det_precision",
                           "det_recall"),
                          scenario=scenario, policy=policy,
                          defense=defense)    # NaN-aware cross-seed means
    out = {
        "acc": [round(float(a), 4) for a in curves["acc"]],
        "attack_success": [round(float(a), 4)
                           for a in curves["attack_success"]],
        "recovery_rounds": [r["recovery_rounds"] for r in runs],
        "rep_gap": round(float(np.mean(
            [r["final_reputation_honest"] - r["final_reputation_malicious"]
             for r in runs])), 4),
        "malicious_selected_mean": [round(float(m), 2) for m in np.mean(
            [r["malicious_selected"] for r in runs], 0)],
    }
    if defense != "none":
        rnd = lambda p: round(float(p), 3) if np.isfinite(p) else None
        out["n_flagged"] = [int(n) for n in np.sum(
            [r["n_flagged"] for r in runs], 0)]
        out["det_precision"] = [rnd(p) for p in curves["det_precision"]]
        out["det_recall"] = [rnd(p) for p in curves["det_recall"]]
    tag = f"{scenario}_{policy}" + ("" if defense == "none"
                                    else "_defended")
    print(f"{tag:46s} acc={out['acc'][-1]:.3f} repgap={out['rep_gap']:+.3f} "
          f"malsel_last={out['malicious_selected_mean'][-1]}")
    return tag, out


def curve(tag, seeds, **kw):
    runs = [run_experiment(seed=s, **kw) for s in seeds]
    out = {
        "acc": [round(float(a), 4) for a in np.mean([r["acc"] for r in runs], 0)],
        "rep_gap": round(float(np.mean(
            [r["final_reputation_honest"] - r["final_reputation_malicious"]
             for r in runs])), 4),
        "malicious_selected_mean": [round(float(m), 2) for m in np.mean(
            [r["malicious_selected"] for r in runs], 0)],
    }
    print(f"{tag:40s} acc={out['acc'][-1]:.3f} repgap={out['rep_gap']:+.3f} "
          f"malsel_last={out['malicious_selected_mean'][-1]}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    kw = (dict(n_train=10_000, n_test=2_000, rounds=6) if args.fast
          else dict(n_train=20_000, n_test=4_000, rounds=10))
    seeds = (0, 1)
    cfg5 = FeelConfig(model_size_bits=5e6 * 8)
    results = {}
    t0 = time.time()

    # 1) the whole threat-model x defense matrix in ONE stacked sweep:
    # 9 scenarios x 2 defenses x 2 policies x 2 seeds = 72 runs,
    # scheduled by one batched control-plane call per round, trained as
    # stacked cohorts, partitions shared across the defense axis
    defenses = ["none", "trimmed_mean+validation"]
    res = run_sweep(["dqs", "random"], seeds=seeds,
                    scenarios=SCENARIO_MATRIX, defenses=defenses,
                    cfg=cfg5, **kw)
    for scn in SCENARIO_MATRIX:
        for defense in defenses:
            for policy in ("dqs", "random"):
                tag, out = summarize(res, scn.name, policy, defense)
                results[tag] = out

    # the DESIGN.md §8 -> §9 question: does the validation detector turn
    # the feature-noise rep gap positive?
    fn_un = results["feature_noise_dqs"]["rep_gap"]
    fn_def = results["feature_noise_dqs_defended"]["rep_gap"]
    print(f"\nfeature-noise rep gap: undefended {fn_un:+.3f} -> "
          f"defended {fn_def:+.3f} "
          f"({'REVERSED' if fn_un < 0 < fn_def else 'not reversed'})")

    # 2) adaptive omega vs fixed (paper §V-B.2 suggestion)
    results["fixed_omega"] = curve(
        "fixed_omega", seeds, policy="dqs", attack_pair=WATCH, cfg=cfg5,
        **kw)
    results["adaptive_omega"] = curve(
        "adaptive_omega", seeds, policy="dqs", attack_pair=WATCH, cfg=cfg5,
        adaptive_omega=True, **kw)

    # 3) scale: K=100 UEs, 10 malicious
    cfg100 = dataclasses.replace(cfg5, n_ues=100, n_malicious=10)
    results["k100_dqs"] = curve(
        "k100_dqs", seeds, policy="dqs", attack_pair=WATCH, cfg=cfg100,
        **kw)
    results["k100_random"] = curve(
        "k100_random", seeds, policy="random", attack_pair=WATCH,
        cfg=cfg100, **kw)

    os.makedirs("results", exist_ok=True)
    with open("results/robustness.json", "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote results/robustness.json ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
