"""Observability plane (src/repro/obs/, DESIGN.md §14).

The contract under test, in order of importance:

1. Zero semantic footprint — ``run_experiment`` with the tracer ON is
   BIT-EQUAL to the same run with the tracer OFF, across engines
   (vectorized, loop), control planes (batched, host), modes (sync,
   async) and tasks (mnist_mlp, lm_tiny). Telemetry that perturbs the
   RNG stream of record or the f64 accumulation order fails here.
2. The disabled path is a true no-op: the shared ``NULL_SPAN``
   singleton, an empty ring, silent metric helpers, and a near-zero
   allocation bound on the hot path.
3. Span discipline: well-formed nesting (parent interval contains the
   child, depth is parent+1), and in async mode every span inside the
   event loop carries both clocks with sim_t0 <= sim_t1.
4. Sinks round-trip: JSONL file -> (meta, spans, metrics), Chrome
   ``trace_event`` export, per-phase summaries, and the
   ``repro.obs.report`` summarizer (incl. roofline context for the
   schedule/train phases via the revived ``launch/roofline.py``).
5. ``write_bench_json`` attaches the per-phase summary to the
   BENCH_history.jsonl line when tracing is on (satellite of §14).
"""
import dataclasses
import io
import json
import os
import sys
import tracemalloc

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from repro.configs.base import FeelConfig
from repro.federated.simulation import run_experiment
from repro.launch.roofline import intensity_context
from repro.obs import report as obs_report
from repro.obs import trace
from repro.obs.metrics import MetricRegistry
from repro.obs.trace import NULL_SPAN

from benchmarks.bench_round import write_bench_json  # noqa: E402

CFG = FeelConfig(n_ues=10, n_malicious=2, min_selected=3, rounds=3)
KW = dict(n_train=1500, n_test=300, seed=0)
LM_KW = dict(n_train=960, n_test=240, seed=0)


@pytest.fixture(autouse=True)
def _tracer_off_after():
    """Every test leaves the singleton disabled and empty — the default
    (REPRO_TRACE=0) state the rest of tier-1 runs under."""
    yield
    trace.configure(enabled=False)


def _async(cfg):
    return dataclasses.replace(cfg, mode="async")


def _run(obs_on: bool, **kw):
    trace.configure(enabled=obs_on)
    try:
        return run_experiment(**kw)
    finally:
        if not obs_on:
            trace.configure(enabled=False)


def _assert_bitwise_equal(a, b):
    assert a.keys() == b.keys()
    for f in a:
        x, y = a[f], b[f]
        if isinstance(x, list) and x and isinstance(x[0], (int, float)):
            assert np.array_equal(np.asarray(x, float),
                                  np.asarray(y, float),
                                  equal_nan=True), (f, x, y)
        else:
            assert x == y, (f, x, y)


# ---------------------------------------------------------------------- #
# 1. zero semantic footprint: obs-on == obs-off, bitwise
# ---------------------------------------------------------------------- #
MATRIX = [
    ("vectorized", "batched", "sync", "mnist"),
    ("vectorized", "batched", "async", "mnist"),
    ("vectorized", "host", "async", "mnist"),
    ("loop", "host", "sync", "mnist"),
    ("vectorized", "batched", "sync", "lm"),
    ("vectorized", "batched", "async", "lm"),
]


@pytest.mark.parametrize("engine,control,mode,task", MATRIX)
def test_obs_on_off_parity(engine, control, mode, task):
    if task == "lm":
        cfg = dataclasses.replace(CFG, rounds=2)
        kw = dict(LM_KW, task="lm_tiny", scenario="token_flip_1to5")
    else:
        cfg = CFG
        kw = dict(KW, scenario="flip_6to2")
    if mode == "async":
        cfg = _async(cfg)
    kw.update(cfg=cfg, engine=engine, control=control)
    off = _run(False, **kw)
    on = _run(True, **kw)
    _assert_bitwise_equal(off, on)
    # and the traced run actually traced something
    assert trace.tracer().spans, "obs-on run recorded no spans"


# ---------------------------------------------------------------------- #
# 2. the disabled path is a true no-op
# ---------------------------------------------------------------------- #
def test_disabled_path_null_span_and_empty_ring():
    trace.configure(enabled=False)
    s1, s2 = trace.span("a"), trace.span("b")
    assert s1 is NULL_SPAN and s2 is NULL_SPAN       # shared singleton
    with trace.span("x") as sp:
        sp.set(anything=1)                           # no-op, chains
    trace.counter_inc("c")
    trace.gauge_set("g", 1.0)
    trace.observe("o", 1.0)
    trace.set_sim_clock(lambda: 0.0)
    tr = trace.tracer()
    assert tr.spans == [] and tr.sim_clock is None
    snap = tr.metrics.snapshot()
    assert (snap["counters"] == {} and snap["gauges"] == {}
            and snap["observations"] == {})


def test_disabled_path_allocation_bound():
    trace.configure(enabled=False)
    tracemalloc.start()
    try:
        base, _ = tracemalloc.get_traced_memory()
        for _ in range(10_000):
            with trace.span("hot"):
                pass
        cur, _ = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    # the shared NULL_SPAN allocates nothing per call; allow slack for
    # interpreter noise but forbid anything per-iteration
    assert cur - base < 16_384, (base, cur)


def test_traced_decorator_disabled_is_passthrough():
    trace.configure(enabled=False)
    calls = []

    @trace.traced("work")
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2 and calls == [1]
    assert trace.tracer().spans == []
    trace.configure(enabled=True)
    assert fn(2) == 3
    assert [s.name for s in trace.tracer().spans] == ["work"]


# ---------------------------------------------------------------------- #
# 3. span discipline: nesting + dual clock
# ---------------------------------------------------------------------- #
def _traced_experiment(cfg, **kw):
    trace.configure(enabled=True)
    run_experiment(cfg=cfg, **kw)
    return list(trace.tracer().spans)


def test_span_nesting_well_formed():
    spans = _traced_experiment(CFG, scenario="flip_6to2", **KW)
    by_sid = {s.sid: s for s in spans}
    names = {s.name for s in spans}
    for phase in ("experiment", "round", "schedule", "schedule.pack",
                  "schedule.finalize", "train", "train.bucket", "eval",
                  "attack.apply", "defense.aggregate", "finalize",
                  "eval.global"):
        assert phase in names, (phase, sorted(names))
    roots = 0
    for s in spans:
        assert s.t1 >= s.t0
        if s.parent == -1:
            roots += 1
            assert s.depth == 0
            continue
        p = by_sid[s.parent]                  # parent completed + kept
        assert p.depth == s.depth - 1
        assert p.t0 <= s.t0 and s.t1 <= p.t1, (p.name, s.name)
    assert roots >= 1
    assert trace.tracer()._stack == []        # all spans closed


def test_async_dual_clock():
    spans = _traced_experiment(_async(CFG), scenario="flip_6to2", **KW)
    stamped = [s for s in spans if s.sim_t0 is not None]
    assert stamped, "no span carried the simulated clock in async mode"
    assert {"async.dispatch", "async.aggregate"} <= {s.name
                                                     for s in stamped}
    for s in stamped:
        assert s.sim_t1 >= s.sim_t0 >= 0.0
        assert s.t1 >= s.t0
    # the event clock advances monotonically across aggregations
    aggs = [s for s in stamped if s.name == "async.aggregate"]
    sims = [s.sim_t1 for s in aggs]
    assert sims == sorted(sims) and sims[-1] > 0.0
    # and the engine uninstalled the sim clock on exit
    assert trace.tracer().sim_clock is None
    # async-plane metrics landed
    snap = trace.tracer().metrics.snapshot()
    assert snap["gauges"]["async.heap_depth"]["max"] >= 1
    assert snap["observations"]["async.upload_age"]["count"] >= 1


# ---------------------------------------------------------------------- #
# 4. sinks: JSONL round-trip, Perfetto export, report
# ---------------------------------------------------------------------- #
def test_jsonl_and_trace_event_round_trip(tmp_path):
    spans = _traced_experiment(CFG, scenario="flip_6to2", **KW)
    snap = trace.tracer().metrics.snapshot()
    path = str(tmp_path / "trace.jsonl")
    assert trace.flush_jsonl(path) == path
    meta, recs, metrics = trace.load_jsonl(path)
    assert meta["kind"] == "meta" and "commit" in meta
    assert len(recs) == len(spans)
    assert [r["name"] for r in recs] == [s.name for s in spans]
    for r, s in zip(recs, spans):
        assert (r["sid"], r["parent"], r["depth"]) == (s.sid, s.parent,
                                                       s.depth)
        assert r["t0"] == s.t0 and r["t1"] == s.t1
    assert metrics["counters"] == snap["counters"]
    assert metrics["gauges"] == snap["gauges"]
    # phase summary computed from the file == from the live ring
    assert trace.phase_summary(recs) == trace.phase_summary(spans)
    # Chrome trace_event export: one complete event per span, µs scale
    ev = trace.to_trace_event(recs)
    assert ev["displayTimeUnit"] == "ms"
    assert len(ev["traceEvents"]) == len(recs)
    for e in ev["traceEvents"]:
        assert e["ph"] == "X" and e["ts"] >= 0.0 and e["dur"] >= 0.0
    json.loads(json.dumps(ev))               # serializable as-is


def test_report_summarize_and_render(tmp_path):
    # an n_train no other test uses -> fresh shapes -> the jit cache is
    # cold and round 0's compile probe marks its train.bucket span
    _traced_experiment(CFG, scenario="flip_6to2",
                       **dict(KW, n_train=1230, n_test=246))
    path = str(tmp_path / "trace.jsonl")
    trace.flush_jsonl(path)
    rep = obs_report.summarize(path)
    for phase in ("round", "schedule", "train", "eval"):
        assert phase in rep["phases"], sorted(rep["phases"])
        assert rep["phases"][phase]["count"] >= CFG.rounds
    # roofline context for the phases that attach analytic estimates
    for phase in ("schedule", "train"):
        r = rep["roofline"][phase]
        assert r["intensity"] > 0 and r["bound"] in ("compute", "memory")
        assert 0 < r["time_floor_s"] < 10.0
    # compile offenders: the cold jit cache means round 0 compiled
    assert any(o["name"] == "train.bucket"
               for o in rep["compile_offenders"])
    out = io.StringIO()
    obs_report.render(rep, out=out)
    text = out.getvalue()
    assert text.startswith("# trace commit=")
    assert "phase,count,total_s,p50_s,p95_s" in text
    assert "roofline,train," in text and "roofline,schedule," in text
    # the CLI entry point agrees with the library path
    rc = obs_report.main([path, "--json"])
    assert rc == 0


def test_report_cli_module_runs(tmp_path):
    import subprocess
    _traced_experiment(CFG, scenario="none", **KW)
    path = str(tmp_path / "trace.jsonl")
    trace.flush_jsonl(path)
    r = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", path],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.path.join(ROOT, "src") + os.pathsep
             + os.environ.get("PYTHONPATH", "")})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "phase,count,total_s,p50_s,p95_s" in r.stdout


def test_roofline_intensity_context():
    # 1 FLOP/byte is far below the v5e ridge -> memory bound
    lo = intensity_context(1e9, 1e9, measured_s=1.0)
    assert lo["bound"] == "memory" and lo["intensity"] == 1.0
    assert 0 < lo["attained_frac"] <= 1.0
    hi = intensity_context(1e15, 1e9)
    assert hi["bound"] == "compute" and "attained_frac" not in hi
    assert hi["time_floor_s"] > 0


# ---------------------------------------------------------------------- #
# 5. metrics registry + bench-writer integration
# ---------------------------------------------------------------------- #
def test_metric_registry_snapshot():
    reg = MetricRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(2.0)
    reg.gauge("g").set(7.0)
    reg.gauge("g").set(3.0)
    for v in (1.0, 2.0, 3.0):
        reg.observation("o").add(v)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"] == {"value": 3.0, "max": 7.0}
    o = snap["observations"]["o"]
    assert (o["count"], o["sum"], o["min"], o["max"]) == (3, 6.0, 1.0,
                                                          3.0)
    assert o["mean"] == 2.0
    reg.reset()
    assert reg.snapshot()["counters"] == {}


def test_experiment_metrics_captured():
    trace.configure(enabled=True)
    run_experiment(cfg=CFG, scenario="flip_6to2", **KW)
    snap = trace.tracer().metrics.snapshot()
    # padding-waste + bucket occupancy ride the train phase; the jit
    # compile-cache gauges are snapshotted at end of run
    assert snap["observations"]["train.pad_waste"]["count"] >= CFG.rounds
    occ = snap["observations"]["train.bucket_occupancy"]
    assert occ["count"] >= CFG.rounds and 0.0 < occ["max"] <= 1.0
    assert snap["gauges"]["compile.cohort_train"]["value"] >= 1


def test_write_bench_json_attaches_phase_summary(tmp_path):
    trace.configure(enabled=True)
    with trace.span("round"):
        pass
    write_bench_json("obs_probe", {"bench": "obs_probe", "rows": []},
                     results_dir=str(tmp_path))
    hist = (tmp_path / "BENCH_history.jsonl").read_text().splitlines()
    rec = json.loads(hist[-1])
    assert "round" in rec["trace"] and rec["trace"]["round"]["count"] == 1
    # tracer off -> no trace block on the history line
    trace.configure(enabled=False)
    write_bench_json("obs_probe", {"bench": "obs_probe", "rows": []},
                     results_dir=str(tmp_path))
    rec = json.loads((tmp_path / "BENCH_history.jsonl")
                     .read_text().splitlines()[-1])
    assert "trace" not in rec


def test_configure_env_equivalent_and_reset(tmp_path):
    tr = trace.configure(enabled=True, ring_size=8)
    for i in range(20):
        with trace.span(f"s{i}"):
            pass
    assert len(tr.spans) <= 8                 # ring bounded
    trace.configure(enabled=False)
    assert tr.spans == [] and tr.enabled is False
