"""AST lints over ``src/repro`` — the statically checkable half of the
parity discipline (DESIGN.md §11).

Rules (kebab-case ids double as waiver names, ``common.parse_waivers``):

oracle-purity
    Functions named ``*_oracle`` / ``*_host`` are the host plane of
    record: plain numpy, bit-reproducible, importable without touching
    a device. Any reference to a ``jax``/``jnp`` alias inside one is a
    violation — a "host oracle" that silently routes through XLA can
    drift with backend/fusion choices and stops being an oracle.

tracer-leak
    Inside ``jax.jit``-decorated functions, value-dependent host
    escapes break tracing or silently constant-fold: ``float()`` /
    ``int()`` / ``bool()`` on a non-static argument, ``.item()``,
    any ``np.*(...)`` call, and Python ``if`` on a non-static argument
    (``.shape`` / ``.dtype`` / ``.ndim`` / ``.size`` accesses are
    static under tracing and exempt). Static parameters — declared via
    literal ``static_argnames`` / ``static_argnums`` — are genuinely
    Python values and may branch/convert freely.

nondeterminism
    Simulation code (core/, federated/, data/, kernels/, models/) must
    draw all randomness from explicitly seeded generators — the host
    RNG stream of record — and never from wall clocks: module-singleton
    ``np.random.<draw>()`` calls, unseeded ``default_rng()`` /
    ``RandomState()``, ``time.time()`` and friends, and
    ``datetime.now()`` are violations. Outside the simulation dirs the
    wall-clock half still applies repo-wide: direct ``time.time`` /
    ``time.perf_counter`` / ``time.monotonic`` (and the ``_ns`` /
    ``sleep`` variants) anywhere under ``src/repro`` are violations
    EXCEPT in ``obs/clock.py`` — the repo's only sanctioned wall-clock
    site (DESIGN.md §14); host tooling that wants a timer routes
    through ``repro.obs.clock.wall_clock``.

dtype-f64
    Device-side float64 belongs to the control plane only and always
    under ``jax.experimental.enable_x64`` — a ``jnp.float64``
    reference outside a ``with enable_x64():`` block either fails at
    runtime (x64 disabled) or silently forks the f32 data plane.

masked-mean-pin
    The masked-mean idiom must guard its denominator:
    ``jnp.sum(x * m) / jnp.sum(m)`` is a violation — an empty mask
    yields NaN and the unguarded form invites f64 ``.mean()``
    rewrites that fork the reputation streams (federated/task.py).
    Write ``jnp.sum(x * m) / jnp.maximum(jnp.sum(m), 1.0)``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.check.common import (CheckContext, SourceFile, Violation,
                                dotted_name, iter_functions)

# directories (relative to src/repro) holding deterministic simulation
# code; launch/ + sharding/ + checkpoint/ are host tooling where wall
# clocks and ad-hoc seeds are fine
SIM_DIRS = ("core", "federated", "data", "kernels", "models")

# np.random constructors that are deterministic WHEN given a seed
_SEEDED_CTORS = {"default_rng", "RandomState", "SeedSequence", "PCG64",
                 "Philox", "SFC64", "MT19937"}
# "sleep" rides along: a sleep in simulation code means something is
# waiting on the wall clock — the async engine's event clock
# (federated/async_engine.py) must advance ONLY through the Eq. 6/7
# latency model on seeded draws
_CLOCK_FUNCS = {"time", "perf_counter", "monotonic", "time_ns",
                "perf_counter_ns", "monotonic_ns", "sleep"}
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}


def module_aliases(tree: ast.Module) -> Dict[str, str]:
    """Top-level import alias -> dotted module path (best effort)."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _aliases_of(aliases: Dict[str, str], prefix: str) -> Set[str]:
    return {name for name, mod in aliases.items()
            if mod == prefix or mod.startswith(prefix + ".")}


def _violate(out: List[Violation], src: SourceFile, rule: str, line: int,
             msg: str) -> None:
    if not src.waived(rule, line):
        out.append(Violation(rule=rule, path=src.rel, line=line,
                             message=msg))


# --------------------------------------------------------------------- #
# oracle-purity
# --------------------------------------------------------------------- #
def lint_oracle_purity(src: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    jaxish = _aliases_of(module_aliases(src.tree), "jax")
    if not jaxish:
        return out
    for fn in iter_functions(src.tree):
        if not (fn.name.endswith("_oracle") or fn.name.endswith("_host")):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and node.id in jaxish \
                    and isinstance(node.ctx, ast.Load):
                _violate(out, src, "oracle-purity", node.lineno,
                         f"host oracle `{fn.name}` references jax alias "
                         f"`{node.id}` — oracles are numpy-only "
                         "(rename the function if it is a device-side "
                         "sequential twin, not a host oracle)")
    return out


# --------------------------------------------------------------------- #
# tracer-leak
# --------------------------------------------------------------------- #
def _jit_static_params(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """None if ``fn`` is not jit-decorated; else its static param names.

    Recognizes ``@jax.jit``, ``@jit``, and
    ``@[functools.]partial(jax.jit, static_argnames=..., static_argnums=...)``
    with literal name/num values (the static-args checker separately
    enforces that they ARE literal).
    """
    params = [a.arg for a in (fn.args.posonlyargs + fn.args.args)]
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target) or ""
        if name in ("jax.jit", "jit"):
            static: Set[str] = set()
            if isinstance(dec, ast.Call):
                static |= _literal_statics(dec, params)
            return static
        if name.endswith("partial") and isinstance(dec, ast.Call) \
                and dec.args:
            inner = dotted_name(dec.args[0]) or ""
            if inner in ("jax.jit", "jit"):
                return _literal_statics(dec, params)
    return None


def _literal_statics(call: ast.Call, params: List[str]) -> Set[str]:
    static: Set[str] = set()
    for kw in call.keywords:
        try:
            val = ast.literal_eval(kw.value)
        except (ValueError, SyntaxError):
            continue
        if kw.arg == "static_argnames":
            static |= {val} if isinstance(val, str) else set(val)
        elif kw.arg == "static_argnums":
            nums = (val,) if isinstance(val, int) else tuple(val)
            static |= {params[i] for i in nums if i < len(params)}
    return static


class _TestNames(ast.NodeVisitor):
    """Bare Name loads in an expression, NOT behind a shape-like
    attribute access (``x.shape[0] > 4`` is trace-static)."""

    def __init__(self):
        self.names: List[ast.Name] = []

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in _SHAPE_ATTRS:
            return                      # skip subtree: static under jit
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            self.names.append(node)


def lint_tracer_leak(src: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    aliases = module_aliases(src.tree)
    np_names = _aliases_of(aliases, "numpy")
    for fn in iter_functions(src.tree):
        static = _jit_static_params(fn)
        if static is None:
            continue
        all_params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)}
        traced = all_params - static
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = dotted_name(node.func) or ""
                if callee in ("float", "int", "bool"):
                    hit = _traced_names(node, traced)
                    if hit:
                        _violate(out, src, "tracer-leak", node.lineno,
                                 f"`{callee}()` on traced argument "
                                 f"`{hit}` inside jitted `{fn.name}` — "
                                 "host conversion breaks tracing")
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item":
                    _violate(out, src, "tracer-leak", node.lineno,
                             f"`.item()` inside jitted `{fn.name}` — "
                             "forces a device sync / fails under trace")
                elif callee.split(".")[0] in np_names:
                    _violate(out, src, "tracer-leak", node.lineno,
                             f"numpy call `{callee}(...)` inside jitted "
                             f"`{fn.name}` — np ops constant-fold or "
                             "fail on tracers; use jnp")
            elif isinstance(node, ast.If):
                hit = _traced_names(node.test, traced)
                if hit:
                    _violate(out, src, "tracer-leak", node.lineno,
                             f"Python `if` on traced argument `{hit}` "
                             f"inside jitted `{fn.name}` — branch on "
                             "jnp.where/lax.cond, or declare the "
                             "argument static")
    return out


def _traced_names(expr: ast.AST, traced: Set[str]) -> Optional[str]:
    v = _TestNames()
    v.visit(expr)
    for n in v.names:
        if n.id in traced:
            return n.id
    return None


# --------------------------------------------------------------------- #
# nondeterminism
# --------------------------------------------------------------------- #
def lint_nondeterminism(src: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    aliases = module_aliases(src.tree)
    np_names = _aliases_of(aliases, "numpy")
    time_mods = _aliases_of(aliases, "time") & {
        k for k, v in aliases.items() if "." not in v}
    dt_mods = {k for k, v in aliases.items() if v == "datetime"}
    clock_funcs = {k for k, v in aliases.items()
                   if v in {f"time.{f}" for f in _CLOCK_FUNCS}}
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func) or ""
        parts = callee.split(".")
        # np.random.* draws on the module singleton / unseeded ctors
        if len(parts) >= 3 and parts[0] in np_names \
                and parts[1] == "random":
            fname = parts[2]
            if fname not in _SEEDED_CTORS and fname != "Generator":
                _violate(out, src, "nondeterminism", node.lineno,
                         f"`{callee}(...)` draws from the global numpy "
                         "RNG — route through a seeded "
                         "np.random.Generator (the stream of record)")
            elif fname in _SEEDED_CTORS and not node.args:
                _violate(out, src, "nondeterminism", node.lineno,
                         f"unseeded `{callee}()` — pass an explicit "
                         "seed so the stream is reproducible")
        elif len(parts) == 2 and parts[0] in np_names \
                and parts[1] in ("default_rng", "RandomState") \
                and not node.args:
            _violate(out, src, "nondeterminism", node.lineno,
                     f"unseeded `{callee}()` — pass an explicit seed")
        # wall clocks
        elif (len(parts) == 2 and parts[0] in time_mods
                and parts[1] in _CLOCK_FUNCS) \
                or (len(parts) == 1 and parts[0] in clock_funcs):
            _violate(out, src, "nondeterminism", node.lineno,
                     f"wall clock `{callee}()` in simulation code — "
                     "results must be a function of config + seeds (the "
                     "async engine's event clock advances only through "
                     "the Eq. 6/7 latency model on seeded draws)")
        elif parts[-1] in ("now", "utcnow", "today") and (
                (len(parts) >= 2 and parts[0] in dt_mods)
                or (len(parts) >= 2
                    and aliases.get(parts[0], "") == "datetime.datetime")):
            _violate(out, src, "nondeterminism", node.lineno,
                     f"wall clock `{callee}()` in simulation code")
    return out


def lint_wall_clock(src: SourceFile) -> List[Violation]:
    """The wall-clock half of the nondeterminism rule, applied repo-wide.

    Direct ``time.<clock>()`` calls (``time``, ``perf_counter``,
    ``monotonic``, the ``_ns`` variants, ``sleep``) anywhere under
    ``src/repro`` are violations outside the one sanctioned site,
    ``obs/clock.py`` — host tooling that wants a timer routes through
    ``repro.obs.clock.wall_clock`` so the telemetry plane (DESIGN.md
    §14) owns every wall-clock read. Same rule id as the simulation
    lint, so existing ``# repro: allow nondeterminism`` waivers apply.
    """
    out: List[Violation] = []
    aliases = module_aliases(src.tree)
    time_mods = _aliases_of(aliases, "time") & {
        k for k, v in aliases.items() if "." not in v}
    clock_funcs = {k for k, v in aliases.items()
                   if v in {f"time.{f}" for f in _CLOCK_FUNCS}}
    if not time_mods and not clock_funcs:
        return out
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func) or ""
        parts = callee.split(".")
        if (len(parts) == 2 and parts[0] in time_mods
                and parts[1] in _CLOCK_FUNCS) \
                or (len(parts) == 1 and parts[0] in clock_funcs):
            _violate(out, src, "nondeterminism", node.lineno,
                     f"wall clock `{callee}()` outside repro.obs.clock — "
                     "route through `repro.obs.clock.wall_clock`, the "
                     "repo's only sanctioned wall-clock site "
                     "(DESIGN.md §14)")
    return out


# --------------------------------------------------------------------- #
# dtype-f64 / masked-mean-pin
# --------------------------------------------------------------------- #
def _x64_ranges(tree: ast.Module) -> List[Tuple[int, int]]:
    """(start, end) line ranges of ``with enable_x64():`` blocks."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.With):
            continue
        for item in node.items:
            expr = item.context_expr
            target = expr.func if isinstance(expr, ast.Call) else expr
            if (dotted_name(target) or "").endswith("enable_x64"):
                out.append((node.lineno, node.end_lineno or node.lineno))
                break
    return out


def lint_dtype_f64(src: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    jnp_names = _aliases_of(module_aliases(src.tree), "jax.numpy")
    if not jnp_names:
        return out
    ranges = _x64_ranges(src.tree)
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64" \
                and isinstance(node.value, ast.Name) \
                and node.value.id in jnp_names:
            if not any(a <= node.lineno <= b for a, b in ranges):
                _violate(out, src, "dtype-f64", node.lineno,
                         "`jnp.float64` outside a `with enable_x64():` "
                         "block — device f64 is control-plane only and "
                         "must be x64-scoped (DESIGN.md §11)")
    return out


def lint_masked_mean(src: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    jnp_names = _aliases_of(module_aliases(src.tree), "jax.numpy")
    if not jnp_names:
        return out

    def is_jnp_sum(node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and (dotted_name(node.func) or "") in
                {f"{a}.sum" for a in jnp_names})

    for node in ast.walk(src.tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div) \
                and is_jnp_sum(node.left) and is_jnp_sum(node.right):
            _violate(out, src, "masked-mean-pin", node.lineno,
                     "unguarded masked mean `jnp.sum(..)/jnp.sum(..)` — "
                     "pin the denominator: "
                     "`/ jnp.maximum(jnp.sum(mask), 1.0)`")
    return out


# --------------------------------------------------------------------- #
# checker entry points (scope filtering + dispatch)
# --------------------------------------------------------------------- #
def _in_scope(src: SourceFile, dirs=SIM_DIRS) -> bool:
    rel = src.rel
    if not rel.startswith("src/repro/"):
        return False
    sub = rel[len("src/repro/"):]
    return sub.split("/")[0] in dirs or "/" not in sub


def check_oracle_purity(ctx: CheckContext) -> List[Violation]:
    return [v for s in ctx.sources if _in_scope(s, SIM_DIRS + (
        "launch", "sharding", "checkpoint", "optim", "configs"))
            for v in lint_oracle_purity(s)]


def check_tracer_leak(ctx: CheckContext) -> List[Violation]:
    return [v for s in ctx.sources if _in_scope(s)
            for v in lint_tracer_leak(s)]


# the ONE file allowed to read the wall clock (DESIGN.md §14)
_CLOCK_SITE = "src/repro/obs/clock.py"


def check_nondeterminism(ctx: CheckContext) -> List[Violation]:
    out: List[Violation] = []
    for s in ctx.sources:
        if _in_scope(s):
            out.extend(lint_nondeterminism(s))
        elif s.rel.startswith("src/repro/") and s.rel != _CLOCK_SITE:
            out.extend(lint_wall_clock(s))
    return out


def check_dtype(ctx: CheckContext) -> List[Violation]:
    out = []
    for s in ctx.sources:
        if _in_scope(s, SIM_DIRS + ("optim", "configs")):
            out.extend(lint_dtype_f64(s))
            out.extend(lint_masked_mean(s))
    return out
