"""deepseek-v3-671b — MLA + fine-grained MoE + MTP [arXiv:2412.19437].

61L (first 3 dense, 58 MoE), d_model 7168, 128 heads with multi-head latent
attention (q_lora 1536, kv_lora 512, decoupled RoPE 64, per-head nope/v dims
128), expert d_ff 2048, 256 routed experts top-8 + 1 shared, vocab 129280,
depth-1 multi-token-prediction head.

The ``n_kv_heads=128`` of the assignment row reflects MLA's MHA-equivalent
behaviour (every head has its own K/V derived from the shared 512-dim latent);
the cache stores only the compressed latent + rope key (576/token)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18_432,                     # dense MLP width of the first 3 layers
    vocab_size=129_280,
    head_dim=128,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=256, top_k=8, d_ff_expert=2048, n_shared=1),
    first_dense_layers=3,
    mtp=True,
    long_context_window=8192,        # long_500k SWA variant (DESIGN.md)
    rope_theta=10_000.0,
    citation="[arXiv:2412.19437]",
)
