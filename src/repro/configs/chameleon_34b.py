"""chameleon-34b — early-fusion mixed-modal decoder [arXiv:2405.09818].

48L, d_model 8192, 64H GQA kv=8, d_ff 22016, vocab 65536 (VQ image codes share
the text vocabulary — early fusion means the backbone is a plain decoder over
interleaved text + image tokens). QK-norm per the Chameleon paper
(query-key RMSNorm for training stability). The VQ-GAN image tokenizer is a
frontend stub per the assignment carve-out: inputs are token ids."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,
    frontend="vlm",
    long_context_window=8192,        # long_500k SWA variant (DESIGN.md)
    citation="[arXiv:2405.09818]",
)
