"""Jit'd public wrappers around the Pallas kernels.

On this CPU-only container the kernels execute with ``interpret=True``
(`REPRO_PALLAS_INTERPRET=1`, the default off-TPU); on TPU they compile to
Mosaic. ``use_pallas()`` gates the model-level dispatch (models default to
the XLA path; tests and benchmarks exercise the kernels explicitly).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_gemm import moe_gemm as _moe_gemm
from repro.kernels.robust_aggregate import robust_aggregate as _robust
from repro.kernels.ssd_scan import ssd_scan as _ssd
from repro.kernels.weighted_aggregate import weighted_aggregate as _agg


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


def use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") not in ("0", "false")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_diff(q, k, v, causal, window, block_q, block_k):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=_interpret())


def _flash_diff_fwd(q, k, v, causal, window, block_q, block_k):
    return _flash_diff(q, k, v, causal, window, block_q, block_k), (q, k, v)


def _flash_diff_bwd(causal, window, block_q, block_k, res, g):
    # pallas_call has no autodiff rule; the backward pass differentiates
    # the jnp oracle instead (flash-attention forward is where the fused
    # kernel pays — the recomputed XLA backward is numerically the exact
    # VJP of the attention the kernel approximates bit-for-bit in tests)
    q, k, v = res
    _, vjp = jax.vjp(
        lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=causal,
                                                window=window), q, k, v)
    return vjp(g)


_flash_diff.defvjp(_flash_diff_fwd, _flash_diff_bwd)


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_k=128):
    """Differentiable wrapper: Pallas kernel forward, reference-VJP
    backward — model code (models/attention.py) can route training
    forwards through the kernel under ``jax.grad``."""
    return _flash_diff(q, k, v, causal, window, block_q, block_k)


def decode_attention(q, k, v, length, **kw):
    return _decode(q, k, v, length, interpret=_interpret(), **kw)


def ssd_scan(x, dt, A, B_, C_, *, chunk=128, **kw):
    """Broadcasts grouped B/C (B,L,G,N) to per-head before the kernel."""
    H = x.shape[2]
    if B_.shape[2] != H:
        rep = H // B_.shape[2]
        B_ = jnp.repeat(B_, rep, axis=2)
        C_ = jnp.repeat(C_, rep, axis=2)
    return _ssd(x, dt, A, B_, C_, chunk=chunk, interpret=_interpret(), **kw)


def moe_gemm(x, w, **kw):
    return _moe_gemm(x, w, interpret=_interpret(), **kw)


def weighted_aggregate(stacked, weights, **kw):
    return _agg(stacked, weights, interpret=_interpret(), **kw)


def robust_aggregate(stacked, n, **kw):
    """Coordinate-wise trimmed mean / median over the stacked-client axis
    (defense plane, core/defenses.py)."""
    return _robust(stacked, n, interpret=_interpret(), **kw)


def weighted_aggregate_tree(updates_stacked, weights, **kw):
    """Apply the FedAvg kernel leaf-wise over a pytree of stacked updates."""
    def per(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        return weighted_aggregate(flat, weights, **kw).reshape(leaf.shape[1:])
    return jax.tree.map(per, updates_stacked)


__all__ = ["flash_attention", "decode_attention", "ssd_scan", "moe_gemm",
           "weighted_aggregate", "weighted_aggregate_tree",
           "robust_aggregate", "use_pallas", "ref"]
