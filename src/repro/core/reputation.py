"""UE reputation (paper §III-B.2, Eq. 1).

    R_k^t = R_k^{t-1} - eta * ( beta1 * (acc_local - avg(acc))
                              + beta2 * (acc_local - acc_test) )

Reputation drops when a UE uploads a bad / poisoned model (its test accuracy
trails the cohort) or when it over-reports its local accuracy versus the
server-side test-set evaluation — catching both malicious and overfitting /
dishonest UEs. Reputations start at 1 (Alg. 1 line 4) and are clipped to
[0, 1] so a long honest history cannot mask a late attack indefinitely.

Sign audit of the beta1 term (both deltas are *subtracted*, per Eq. 1):
``beta1 * (acc_local - avg(acc))`` does lower the reputation of any UE
whose *self-reported* accuracy sits above the cohort mean — including an
honest UE with genuinely good data. That is the paper's equation as
written, not a transcription error: Eq. 1 treats the report itself as the
suspect quantity, and a relative over-report is evidence of dishonesty
because the attacks the paper studies inflate exactly this number (a
label-flip UE fits its flipped labels locally and reports high accuracy; a
lying UE adds ``lie_boost``). For honest UEs the term is benign: their
report tracks the server-side measurement, so the dominant beta2 gap
(beta2 = 0.8 >> beta1 = 0.2 here) stays near zero and the small beta1
fluctuations centre on zero as the cohort mean moves with them. A poisoner
pays both terms every round it is scheduled. The property the scheduler
actually needs — honest UEs end above poisoners — is pinned by
tests/test_cohort.py::test_reputation_orders_honest_above_poisoner.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs.base import FeelConfig


def reputation_update_eq1(values, sel_mask, acc_local, acc_test,
                          eta, beta1, beta2, penalty=None):
    """Eq. 1 as a pure jnp function over (..., K) arrays (batched control
    plane; the host oracle is ``ReputationTracker.update``).

    ``sel_mask`` — {0,1} participation mask; ``acc_local`` / ``acc_test``
    — per-UE accuracies scattered to the full K axis (entries of
    unscheduled UEs are ignored). The cohort average of Eq. 1's beta1 term
    runs over the participants only, and only participants' reputations
    move (then clip to [0, 1], matching the tracker).

    ``penalty`` — optional (..., K) extra subtracted term inside the same
    clip: the defense plane's validation-detector trust penalty
    (core/defenses.py, DESIGN.md §9). Zero rows leave Eq. 1 untouched.
    """
    m = sel_mask.astype(values.dtype)
    n = m.sum(-1, keepdims=True)
    avg = (acc_local * m).sum(-1, keepdims=True) / jnp.maximum(n, 1.0)
    delta = eta * (beta1 * (acc_local - avg)
                   + beta2 * (acc_local - acc_test))
    if penalty is not None:
        delta = delta + penalty
    return jnp.where(m > 0, jnp.clip(values - delta, 0.0, 1.0), values)


class ReputationTracker:
    def __init__(self, cfg: FeelConfig):
        self.cfg = cfg
        self.values = np.ones(cfg.n_population)

    def update(self, participants: np.ndarray,
               acc_local: np.ndarray, acc_test: np.ndarray,
               penalty=None) -> np.ndarray:
        """Apply Eq. 1 to the participating UEs of this round.

        participants — indices; acc_local — self-reported accuracies
        (len == len(participants)); acc_test — server-measured accuracies of
        the uploaded models on the held-out test set; penalty — optional
        per-participant defense trust penalty, subtracted inside the same
        clip (see ``reputation_update_eq1``).
        """
        cfg = self.cfg
        if len(participants) == 0:
            return self.values
        avg_acc = float(np.mean(acc_local))
        delta = cfg.eta * (cfg.beta1 * (acc_local - avg_acc)
                           + cfg.beta2 * (acc_local - acc_test))
        if penalty is not None:
            delta = delta + penalty
        self.values[participants] = np.clip(
            self.values[participants] - delta, 0.0, 1.0)
        return self.values
