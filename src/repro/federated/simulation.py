"""End-to-end FEEL experiment driver — reproduces the paper's §V protocol.

    run_experiment(...) -> accuracy curve per round

Protocol (paper §V-A): synthetic-MNIST 50k/10k; sort-by-label groups of 50;
1-30 groups per UE; K=50 UEs, 5 random malicious with a label-flip attack
((6,2) easy / (8,4) hard); 2-layer MLP via FedAvg; 15 rounds; results
averaged over independent runs.

``engine`` selects the cohort execution path: "vectorized" (default) runs
every scheduled UE in one vmapped step; "loop" is the original sequential
per-client oracle (see federated/server.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import FeelConfig
from repro.core.poisoning import LabelFlipAttack, pick_malicious
from repro.data.partition import partition
from repro.data.synthetic_mnist import generate
from repro.federated.server import FeelServer


def run_experiment(policy: str = "dqs",
                   attack_pair: Tuple[int, int] = (6, 2),
                   cfg: Optional[FeelConfig] = None,
                   seed: int = 0,
                   n_train: int = 50_000, n_test: int = 10_000,
                   omega: Optional[Tuple[float, float]] = None,
                   adaptive_omega: bool = False,
                   rounds: Optional[int] = None,
                   no_attack: bool = False,
                   model_poison_scale: Optional[float] = None,
                   lie_boost: float = 0.0,
                   engine: str = "vectorized") -> Dict:
    cfg = cfg or FeelConfig()
    if omega is not None:
        cfg = dataclasses.replace(cfg, omega_rep=omega[0], omega_div=omega[1])
    rng = np.random.default_rng(seed)
    train, test = generate(n_train, n_test, seed=seed)
    malicious = pick_malicious(cfg.n_ues, cfg.n_malicious, rng)
    attack = None if no_attack else LabelFlipAttack(*attack_pair)
    if model_poison_scale is not None:
        attack = None        # model poisoning replaces the data attack
    clients = partition(train, cfg.n_ues, rng,
                        None if no_attack else malicious, attack)
    mp = None
    if model_poison_scale is not None and not no_attack:
        from repro.core.poisoning import ModelPoisonAttack
        mp = ModelPoisonAttack(scale=model_poison_scale)
    server = FeelServer(cfg, clients, test, rng, policy=policy,
                        adaptive_omega=adaptive_omega,
                        watch_class=attack_pair[0], model_poison=mp,
                        lie_boost=lie_boost, engine=engine)
    logs = server.run(rounds)
    return {
        "acc": [l.global_acc for l in logs],
        "source_acc": [l.source_acc for l in logs],
        "malicious_selected": [l.n_malicious_selected for l in logs],
        "objective": [l.objective for l in logs],
        "final_reputation_malicious": float(
            np.mean(server.reputation.values[malicious])),
        "final_reputation_honest": float(np.mean(np.delete(
            server.reputation.values, malicious))),
        "malicious": malicious.tolist(),
    }


def averaged(policy, attack_pair, n_runs=3, **kw) -> Dict:
    """Paper reports the average of independent runs per setting."""
    runs = [run_experiment(policy, attack_pair, seed=s, **kw)
            for s in range(n_runs)]
    acc = np.mean([r["acc"] for r in runs], axis=0)
    mal = np.mean([r["malicious_selected"] for r in runs], axis=0)
    return {"acc": acc.tolist(), "malicious_selected": mal.tolist(),
            "rep_gap": float(np.mean([r["final_reputation_honest"]
                                      - r["final_reputation_malicious"]
                                      for r in runs]))}
