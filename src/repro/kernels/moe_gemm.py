"""Pallas TPU grouped GEMM for MoE expert FFNs: (E,C,d) x (E,d,f) -> (E,C,f).

Grid (E, n_c, n_f, n_k) — classic blocked matmul per expert with a fp32 VMEM
accumulator across the contraction dimension (innermost). Block sizes default
to 128x128x128 (MXU-aligned); the per-step working set is
3 x 128x128x4B = 192 KiB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_k",
                                             "interpret"))
def moe_gemm(x, w, *, block_c=128, block_f=128, block_k=128,
             interpret=False):
    """x (E,C,d), w (E,d,f) -> (E,C,f)."""
    E, C, d = x.shape
    f = w.shape[-1]
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_k = min(block_k, d)
    assert C % block_c == 0 and f % block_f == 0 and d % block_k == 0
    grid = (E, C // block_c, f // block_f, d // block_k)

    kernel = functools.partial(_gemm_kernel, n_k=grid[3])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_k, block_f),
                         lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
