"""Async event-driven engine (federated/async_engine.py, DESIGN.md §13).

The contract under test, in order of importance:

1. Zero-latency oracle parity — ``mode="async"`` at
   ``async_latency_scale=0.0`` with per-wave triggers is BIT-EQUAL to the
   synchronous engine, across tasks (mnist_mlp, lm_tiny), engines
   (vectorized, loop) and control planes (batched, host) — the same
   oracle discipline as engine="loop" / control="host".
2. The staleness discount d(a) = decay**a: d(0) == 1.0 exactly (the
   parity above rests on it), monotone non-increasing in age.
3. Trigger semantics: buffer fill, deadline flush, drain.
4. The threat/defense planes transfer: stale-replay adversaries and the
   (scenario x defense x policy) sweep matrix run unchanged on async.
5. The CLI driver (launch/serve.py) — whose import here also keeps the
   module off the dead-inheritance inventory.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs.base import FeelConfig
from repro.core import control as ctl
from repro.federated.async_engine import AsyncFeelEngine
from repro.federated.simulation import run_experiment, run_sweep
from repro.launch import serve

CFG = FeelConfig(n_ues=10, n_malicious=2, min_selected=3, rounds=3)
KW = dict(n_train=1500, n_test=300, seed=0)
LM_KW = dict(n_train=960, n_test=240, seed=0)

PARITY_FIELDS = ("acc", "loss", "rep_gap", "objective",
                 "malicious_selected")


def _assert_parity(sync, azero):
    for f in PARITY_FIELDS:
        a = np.asarray(sync[f], float)
        b = np.asarray(azero[f], float)
        # equal_nan: the MNIST task has no loss metric (all-NaN curve)
        assert np.array_equal(a, b, equal_nan=True), \
            (f, sync[f], azero[f])


def _zero_latency(cfg):
    return dataclasses.replace(cfg, mode="async", async_buffer=None,
                               async_deadline=None,
                               async_latency_scale=0.0)


# ---------------------------------------------------------------------- #
# 1. zero-latency oracle parity
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("control", ["batched", "host"])
def test_zero_latency_parity_mnist(control):
    kw = dict(KW, cfg=CFG, scenario="flip_6to2", control=control)
    sync = run_experiment(**kw)
    azero = run_experiment(**dict(kw, cfg=_zero_latency(CFG)))
    _assert_parity(sync, azero)
    # the sim-time axis exists and is degenerate at zero latency
    assert azero["sim_time"] == [0.0] * CFG.rounds
    assert azero["trigger"] == ["wave"] * CFG.rounds


@pytest.mark.parametrize("control", ["batched", "host"])
def test_zero_latency_parity_lm(control):
    cfg = dataclasses.replace(CFG, rounds=2)
    kw = dict(LM_KW, cfg=cfg, task="lm_tiny", control=control,
              scenario="token_flip_1to5")
    sync = run_experiment(**kw)
    azero = run_experiment(**dict(kw, cfg=_zero_latency(cfg)))
    _assert_parity(sync, azero)


def test_zero_latency_parity_loop_engine():
    kw = dict(KW, cfg=CFG, scenario="stale_rider_2", engine="loop")
    sync = run_experiment(**kw)
    azero = run_experiment(**dict(kw, cfg=_zero_latency(CFG)))
    _assert_parity(sync, azero)


def test_zero_latency_parity_with_channel_corr():
    """AR(1) channel state is mode-independent: sync and async-zero see
    the same correlated draws."""
    cfg = dataclasses.replace(CFG, channel_corr=0.4)
    kw = dict(KW, cfg=cfg, scenario="flip_6to2")
    sync = run_experiment(**kw)
    azero = run_experiment(**dict(kw, cfg=_zero_latency(cfg)))
    _assert_parity(sync, azero)


def test_engine_rejects_sync_cfg():
    class _Srv:
        cfg = CFG                            # mode="sync"
    with pytest.raises(AssertionError, match="mode"):
        AsyncFeelEngine(_Srv())


# ---------------------------------------------------------------------- #
# 2. staleness discount
# ---------------------------------------------------------------------- #
def test_staleness_discount_monotone_age0_exact():
    ages = np.arange(6)
    d = ctl.staleness_discount(ages, 0.5)
    assert d.dtype == np.float64
    assert d[0] == 1.0                       # exact — the parity contract
    assert np.all(np.diff(d) < 0)            # strictly decreasing, decay<1
    w = np.array([50.0, 37.0, 123.0])
    assert np.array_equal(w * ctl.staleness_discount(np.zeros(3, int),
                                                     0.5), w)
    assert np.array_equal(ctl.staleness_discount(ages, 1.0),
                          np.ones(6))        # decay=1: plain FedAvg
    with pytest.raises(AssertionError):
        ctl.staleness_discount(ages, 0.0)
    with pytest.raises(AssertionError):
        ctl.staleness_discount(np.array([-1]), 0.5)


# ---------------------------------------------------------------------- #
# 3. trigger semantics
# ---------------------------------------------------------------------- #
def test_buffer_trigger_sizes_and_ages():
    cfg = dataclasses.replace(CFG, mode="async", async_buffer=2,
                              async_staleness=0.5, channel_corr=0.3,
                              rounds=5)
    r = run_experiment(cfg=cfg, scenario="stale_rider_2", **KW)
    assert len(r["acc"]) == 5
    assert np.isfinite(np.asarray(r["acc"], float)).all()
    assert np.isfinite(np.asarray(r["rep_gap"], float)).all()
    st = np.asarray(r["sim_time"], float)
    assert np.all(np.diff(st) >= 0) and st[-1] > 0
    for trig, n in zip(r["trigger"], r["n_uploads"]):
        if trig == "buffer":
            assert n == 2
    # a small buffer leaves stragglers behind -> some uploads age
    assert max(r["mean_age"]) > 0


def test_deadline_trigger_fires():
    # a deadline shorter than the wave's latency spread must flush
    # partial buffers at dispatch + deadline
    cfg = dataclasses.replace(CFG, mode="async", async_deadline=20.0,
                              rounds=4)
    r = run_experiment(cfg=cfg, scenario="flip_6to2", **KW)
    assert len(r["acc"]) == 4
    assert "deadline" in r["trigger"], r["trigger"]
    assert np.isfinite(np.asarray(r["acc"], float)).all()


def test_wave_trigger_is_sync_limit_shape():
    # buffer=None waits for the whole wave: n_uploads == wave size and
    # ages are all zero even at full latency
    cfg = dataclasses.replace(CFG, mode="async", rounds=3)
    r = run_experiment(cfg=cfg, scenario="flip_6to2", **KW)
    assert r["trigger"] == ["wave"] * 3
    assert r["mean_age"] == [0.0] * 3
    assert np.all(np.diff(np.asarray(r["sim_time"], float)) > 0)


# ---------------------------------------------------------------------- #
# 4. threat/defense planes transfer
# ---------------------------------------------------------------------- #
def test_async_sweep_matrix():
    """The (scenario x defense x policy) grid runs unchanged on async —
    shared caches, per-run event loops."""
    cfg = dataclasses.replace(CFG, mode="async", async_buffer=3,
                              channel_corr=0.3, rounds=2)
    res = run_sweep(["dqs"], seeds=[0], cfg=cfg,
                    scenarios=["none", "stale_rider_2"],
                    defenses=["none", "trimmed_mean"],
                    n_train=KW["n_train"], n_test=KW["n_test"])
    assert len(res.runs) == 4
    for r in res.runs:
        assert len(r["acc"]) == 2
        assert np.isfinite(np.asarray(r["acc"], float)).all()


# ---------------------------------------------------------------------- #
# 5. the CLI driver
# ---------------------------------------------------------------------- #
def test_serve_cli_driver(capsys):
    rc = serve.main(["--rounds", "2", "--ues", "10", "--malicious", "2",
                     "--n-train", "1500", "--n-test", "300",
                     "--buffer", "4", "--channel-corr", "0.3", "--json"])
    assert rc == 0
    res = json.loads(capsys.readouterr().out)
    assert len(res["acc"]) == 2 and len(res["sim_time"]) == 2
    assert res["scenario"] == "none"


def test_serve_cli_table_output(capsys):
    rc = serve.main(["--rounds", "1", "--ues", "10", "--malicious", "2",
                     "--n-train", "1500", "--n-test", "300"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "version,sim_s,acc,trigger,n_uploads,mean_age" in out
