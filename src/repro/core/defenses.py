"""Defense plane: robust aggregation + data-quality validation
(companion work arXiv:2102.09491 — validation-based detection of the
unreliable-data family; arXiv:2004.00490 — folding a trust signal back
into the scheduling objective).

PR 4's threat-model plane surfaced the hole this module closes: Eq. 1 as
written *rewards* feature-noise clients (their honestly-low self-reports
turn the beta1 term into a credit — DESIGN.md §8, the negative
`feature_noise_*` rep gaps in results/robustness.json). The paper has no
server-side defense beyond Eq. 1, so the defense is a first-class axis
mirroring ``core.attacks.AttackScenario``:

    DefensePolicy — a named bundle of two orthogonal components:
        aggregator  RobustAggregator  replaces/augments FedAvg over the
                                      stacked cohort: coordinate-wise
                                      trimmed mean, coordinate median,
                                      update-norm clipping, Krum /
                                      multi-Krum distance filtering
        detector    ValidationDetector  a held-out validation pass over
                                      the uploaded models whose anomaly
                                      score feeds a trust penalty into
                                      Eq. 1 (and therefore into the
                                      Eq. 3 value the scheduler ranks)

Every aggregator has a host numpy oracle — per-client / compressed
``(n, P)`` math, the ``engine="loop"`` path — AND a batched jnp twin
operating on the padded ``(K_pad, P)`` flattened-update layout of the
vectorized cohort engine (padding rows ride along under a validity
sentinel and weight 0). Parity contract (DESIGN.md §9,
tests/test_defenses.py): every *decision* (trim ranks, Krum selection,
clip counts) is bit-equal between the planes; float payloads are
bit-equal where the reduction order is pinned (trimmed mean / median use
an identical ascending sequential accumulation on both planes) and
documented-ulp otherwise (norm/distance reductions run in float64, where
XLA's reduce grouping may differ from numpy's in the last bit — a
selection flip needs a measure-zero tie, mirroring the control plane's
Eq. 9 log2 note).

The trimmed-mean / median reductions also exist as a Pallas TPU kernel
(``kernels/robust_aggregate.py`` — sort/select over the stacked-client
axis in a ``weighted_aggregate``-style block layout); the batched twin
routes through it under ``REPRO_USE_PALLAS=1``, and otherwise uses the
exact-parity jnp path below.

Randomness: defenses draw nothing — they are deterministic functions of
the uploaded cohort, so threading them through the sweep never perturbs
the RNG stream-of-record (DESIGN.md §2) and a defended run's schedule
diverges from its undefended twin only through the model/reputation
effects of the defense itself.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64


# ---------------------------------------------------------------------- #
# Flattened-update layout helpers (the (K_pad, P) defense layout)
# ---------------------------------------------------------------------- #
def flatten_params_np(params) -> np.ndarray:
    """One parameter pytree -> (P,) float32 numpy vector (host layout)."""
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(params)])


def flatten_stacked(stacked) -> jnp.ndarray:
    """Stacked pytree (leaves (N, ...)) -> (N, P) float32 device matrix.

    Leaf order and the per-leaf reshape match ``fedavg_stacked``'s kernel
    route, so host and batched planes index identical columns.
    """
    leaves = jax.tree.leaves(stacked)
    n = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)


def unflatten_vec(template, vec):
    """(P,) vector -> pytree shaped like ``template`` (dtype-preserving)."""
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for l in leaves:
        m = int(np.prod(l.shape, dtype=np.int64))
        out.append(jnp.asarray(vec[off:off + m]).reshape(l.shape)
                   .astype(l.dtype))
        off += m
    return jax.tree.unflatten(treedef, out)


def unflatten_stacked(stacked_template, flat):
    """(N, P) matrix -> stacked pytree shaped like ``stacked_template``."""
    leaves, treedef = jax.tree.flatten(stacked_template)
    n = leaves[0].shape[0]
    out, off = [], 0
    for l in leaves:
        m = int(np.prod(l.shape[1:], dtype=np.int64))
        out.append(jnp.asarray(flat[:, off:off + m]).reshape(l.shape)
                   .astype(l.dtype))
        off += m
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------- #
# Per-round defense statistics (RoundLog / SweepResult payload)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class DefenseStats:
    """What the defense did this round (metrics only — ground truth never
    feeds back into the defense itself)."""
    n_clipped: int = 0        # norm-clip: rows whose update was shrunk
    n_rejected: int = 0       # trim/Krum: rows excluded from aggregation
    n_flagged: int = 0        # detector: rows with positive anomaly
    det_precision: float = float("nan")   # flagged ∩ malicious / flagged
    det_recall: float = float("nan")      # flagged ∩ malicious / malicious


# ---------------------------------------------------------------------- #
# Robust aggregators
# ---------------------------------------------------------------------- #
def _seq_mean(rows, count):
    """Ascending sequential sum / count — the ONE accumulation order both
    planes use, so trimmed-mean payloads are bit-equal host vs batched
    (elementwise IEEE f32 adds; numpy and XLA round identically)."""
    acc = rows[0]
    for r in rows[1:]:
        acc = acc + r
    return acc / count


@dataclasses.dataclass(frozen=True)
class TrimmedMean:
    """Coordinate-wise trimmed mean [Yin et al., 2018]: per parameter,
    sort the n uploaded values, drop ``n_trim(n)`` from each end, average
    the rest (unweighted — robust statistics replace the size-weighted
    FedAvg entirely)."""
    trim: float = 0.2      # fraction trimmed from EACH end

    def __post_init__(self):
        assert 0.0 < self.trim < 0.5, self.trim

    def n_trim(self, n: int) -> int:
        return min(int(np.floor(self.trim * n)), max((n - 1) // 2, 0))

    def aggregate_host(self, flat: np.ndarray
                       ) -> Tuple[np.ndarray, DefenseStats]:
        """(n, P) float32 compressed matrix -> (P,) aggregate."""
        n = flat.shape[0]
        b = self.n_trim(n)
        xs = np.sort(flat, axis=0)
        agg = _seq_mean([xs[i] for i in range(b, n - b)],
                        np.float32(n - 2 * b))
        return agg, DefenseStats(n_rejected=2 * b)

    def aggregate_batched(self, flat: jnp.ndarray, n: int, kernel=None
                          ) -> Tuple[jnp.ndarray, DefenseStats]:
        """(N_pad, P) padded matrix (real rows first) -> (P,) aggregate.

        Padding rows sort to the top under a +inf sentinel and the kept
        rank window [b, n-b) never reaches them. ``kernel=True`` routes
        through the Pallas ``robust_aggregate`` kernel (None defers to
        ``ops.use_pallas()``); the default is the exact-parity jnp path
        (same ascending sequential accumulation as the host oracle).
        """
        b = self.n_trim(n)
        stats = DefenseStats(n_rejected=2 * b)
        if _use_kernel(kernel):
            from repro.kernels import ops
            return ops.robust_aggregate(flat, n, trim=b,
                                        mode="trimmed_mean"), stats
        xs = _sorted_rows(flat, n)
        agg = _seq_mean([xs[i] for i in range(b, n - b)],
                        np.float32(n - 2 * b))
        return agg, stats


@dataclasses.dataclass(frozen=True)
class Median:
    """Coordinate-wise median: rank (n-1)//2 / n//2 midpoint — exact on
    both planes (one add and one halving; no reduction order at all)."""

    def aggregate_host(self, flat: np.ndarray
                       ) -> Tuple[np.ndarray, DefenseStats]:
        n = flat.shape[0]
        xs = np.sort(flat, axis=0)
        agg = (xs[(n - 1) // 2] + xs[n // 2]) * np.float32(0.5)
        return agg, DefenseStats(n_rejected=n - 2 + (n % 2))

    def aggregate_batched(self, flat: jnp.ndarray, n: int, kernel=None
                          ) -> Tuple[jnp.ndarray, DefenseStats]:
        stats = DefenseStats(n_rejected=n - 2 + (n % 2))
        if _use_kernel(kernel):
            from repro.kernels import ops
            return ops.robust_aggregate(flat, n, mode="median"), stats
        xs = _sorted_rows(flat, n)
        agg = (xs[(n - 1) // 2] + xs[n // 2]) * np.float32(0.5)
        return agg, stats


def _mask_rows(flat: jnp.ndarray, n: int) -> jnp.ndarray:
    """+inf-sentinel the padding rows so sorts push them past rank n-1."""
    if flat.shape[0] == n:
        return flat
    row = jnp.arange(flat.shape[0])[:, None]
    return jnp.where(row < n, flat, jnp.inf)


def _sorted_rows(flat: jnp.ndarray, n: int, via: Optional[str] = None):
    """Ascending per-coordinate sort of the padded stack (+inf sentinel
    rows last). ``via`` — "numpy" | "jax" | None (backend default):
    XLA CPU's wide-matrix sort loses ~10x to numpy's (the measurement
    behind the control plane's hybrid layout, DESIGN.md §6), so the cpu
    backend stages the sort through a host copy — the sorted VALUES are
    identical either way, so the parity contract is untouched; real
    accelerators keep the device sort (or the Pallas kernel route).
    """
    masked = _mask_rows(flat, n)
    if via is None:
        via = "numpy" if jax.default_backend() == "cpu" else "jax"
    if via == "numpy":
        return np.sort(np.asarray(masked), axis=0)
    return jnp.sort(masked, axis=0)


def _use_kernel(kernel: Optional[bool]) -> bool:
    if kernel is None:
        from repro.kernels import ops
        return ops.use_pallas()
    return bool(kernel)


@dataclasses.dataclass(frozen=True)
class NormClip:
    """Update-norm clipping: Delta_k = Omega_k − g is shrunk to L2 norm
    <= tau (norms in float64 on both planes, the scale quantized to
    float32 so the elementwise clip is bit-identical), then the clipped
    uploads go through the usual size-weighted FedAvg."""
    tau: float = 1.0

    def __post_init__(self):
        assert self.tau > 0, self.tau

    def scales_host(self, flat: np.ndarray, g: np.ndarray) -> np.ndarray:
        delta = flat - g[None]
        n2 = np.sum(delta.astype(np.float64) ** 2, axis=1)
        return np.minimum(
            1.0, self.tau / np.maximum(np.sqrt(n2), 1e-12)
        ).astype(np.float32)

    def clip_host(self, flat: np.ndarray, g: np.ndarray
                  ) -> Tuple[np.ndarray, DefenseStats]:
        s = self.scales_host(flat, g)
        clipped = g[None] + s[:, None] * (flat - g[None])
        return clipped, DefenseStats(n_clipped=int((s < 1.0).sum()))

    def clip_batched(self, flat: jnp.ndarray, g: jnp.ndarray, n: int
                     ) -> Tuple[jnp.ndarray, DefenseStats]:
        delta = flat - g[None]
        with enable_x64():
            n2 = jnp.sum(delta.astype(jnp.float64) ** 2, axis=1)
            s64 = jnp.minimum(1.0,
                              self.tau / jnp.maximum(jnp.sqrt(n2), 1e-12))
        s = s64.astype(jnp.float32)
        clipped = g[None] + s[:, None] * delta
        n_clipped = int((np.asarray(s)[:n] < 1.0).sum())
        return clipped, DefenseStats(n_clipped=n_clipped)


@dataclasses.dataclass(frozen=True)
class Krum:
    """Krum / multi-Krum distance filter [Blanchard et al., 2017]: each
    upload is scored by the summed squared distance to its n−f−2 nearest
    neighbours; the ``n_select`` lowest-score uploads survive and go
    through the usual size-weighted FedAvg. Distances/scores run in
    float64 on both planes (documented-ulp residue; a selection flip
    needs a measure-zero score tie). Degrades to plain FedAvg (nothing
    rejected) when the cohort is too small for the bound (n < f + 3).
    """
    n_select: Optional[int] = None    # None -> n - f (multi-Krum)
    f: Optional[int] = None           # assumed Byzantine count;
    #                                   None -> the server's cfg.n_malicious

    def _resolve(self, n: int, n_byz: int) -> Tuple[int, int]:
        f = self.f if self.f is not None else n_byz
        m = self.n_select if self.n_select is not None else max(n - f, 1)
        return f, min(max(m, 1), n)

    def select_host(self, flat: np.ndarray, n_byz: int) -> np.ndarray:
        """(n, P) -> sorted indices of the selected uploads. Pairwise
        squared distances via the float64 gram matrix (one BLAS gemm
        instead of an O(n) loop of (n, P) temporaries)."""
        n = flat.shape[0]
        f, m = self._resolve(n, n_byz)
        if n - f - 2 < 1:
            return np.arange(n)
        X = flat.astype(np.float64)
        sq = np.einsum("ij,ij->i", X, X)
        d2 = np.maximum(sq[:, None] + sq[None, :] - 2.0 * (X @ X.T), 0.0)
        np.fill_diagonal(d2, 0.0)             # exact self term
        ds = np.sort(d2, axis=1)              # ds[:, 0] is the self term
        scores = ds[:, 1:n - f - 1].sum(axis=1)
        return np.sort(np.argsort(scores, kind="stable")[:m])

    def select_batched(self, flat: jnp.ndarray, n: int,
                       n_byz: int) -> np.ndarray:
        """Padded (N_pad, P) twin — scores only the n real rows; returns
        the same sorted index array as the host oracle (float64 gram
        formulation on both planes; gemm-implementation ulps could flip
        a selection only on a measure-zero score tie)."""
        f, m = self._resolve(n, n_byz)
        if n - f - 2 < 1:
            return np.arange(n)
        with enable_x64():
            X = flat[:n].astype(jnp.float64)
            sq = jnp.einsum("ij,ij->i", X, X)
            d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (X @ X.T),
                             0.0)
            d2 = d2.at[jnp.arange(n), jnp.arange(n)].set(0.0)
            ds = jnp.sort(d2, axis=1)
            scores = np.asarray(ds[:, 1:n - f - 1].sum(axis=1))
        return np.sort(np.argsort(scores, kind="stable")[:m])


RobustAggregator = Union[TrimmedMean, Median, NormClip, Krum]


# ---------------------------------------------------------------------- #
# Aggregation entry points (the two engines route through these)
# ---------------------------------------------------------------------- #
def aggregate_host(agg: RobustAggregator, params_list: List,
                   weights: np.ndarray, global_params, n_byz: int):
    """Host oracle over a compressed list of uploaded pytrees — the
    ``engine="loop"`` defense path. Returns (new global params, stats).

    The final combine of the filtering/clipping aggregators reuses the
    stock ``fedavg`` (lazy import — federated imports core), so the
    defended combine inherits the float64-normalise / float32-accumulate
    contract the engines are already pinned on.
    """
    from repro.federated.aggregation import fedavg
    weights = np.asarray(weights, float)
    if isinstance(agg, (TrimmedMean, Median)):
        flat = np.stack([flatten_params_np(p) for p in params_list])
        vec, stats = agg.aggregate_host(flat)
        return unflatten_vec(global_params, vec), stats
    if isinstance(agg, NormClip):
        flat = np.stack([flatten_params_np(p) for p in params_list])
        clipped, stats = agg.clip_host(flat,
                                       flatten_params_np(global_params))
        rows = [unflatten_vec(global_params, clipped[i])
                for i in range(clipped.shape[0])]
        return fedavg(rows, weights), stats
    assert isinstance(agg, Krum), agg
    flat = np.stack([flatten_params_np(p) for p in params_list])
    sel = agg.select_host(flat, n_byz)
    stats = DefenseStats(n_rejected=len(params_list) - sel.size)
    return fedavg([params_list[i] for i in sel], weights[sel]), stats


def aggregate_stacked(agg: RobustAggregator, stacked, weights: np.ndarray,
                      global_params, n: int, n_byz: int, kernel=None):
    """Batched twin over the padded stacked cohort (leaves (N_pad, ...),
    real rows first, padding weight 0) — the vectorized engine's defense
    path. Returns (new global params, stats)."""
    from repro.federated.aggregation import fedavg_stacked
    weights = np.asarray(weights, float)
    if isinstance(agg, (TrimmedMean, Median)):
        vec, stats = agg.aggregate_batched(flatten_stacked(stacked), n,
                                           kernel=kernel)
        return unflatten_vec(global_params, vec), stats
    if isinstance(agg, NormClip):
        flat = flatten_stacked(stacked)
        clipped, stats = agg.clip_batched(
            flat, jnp.asarray(flatten_params_np(global_params)), n)
        return fedavg_stacked(unflatten_stacked(stacked, clipped),
                              weights), stats
    assert isinstance(agg, Krum), agg
    sel = agg.select_batched(flatten_stacked(stacked), n, n_byz)
    stats = DefenseStats(n_rejected=n - sel.size)
    w = np.zeros_like(weights)
    w[sel] = weights[sel]
    return fedavg_stacked(stacked, w), stats


# ---------------------------------------------------------------------- #
# Validation detector (the unreliable-data family, arXiv:2102.09491)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ValidationDetector:
    """Server-side validation pass over the uploaded models: every
    scheduled UE's upload is scored on a held-out validation split — the
    first ``n_val`` rows of the server's public test set, clamped to the
    set size, restricted to the classes the UE claims to hold (the same
    masking argument as Eq. 1's ``acc_test``, DESIGN.md §2: unmasked, an
    honest non-IID UE is indistinguishable from a noise UE) — alongside
    the start-of-round GLOBAL model on the same per-UE masks, in one
    extra vmapped ``cohort_eval``. The anomaly score is the upload's
    degradation of its own claimed classes relative to the global model:

        a_k = max(0, v_global,k − v_k − tol)

    (the per-UE global baseline also cancels the class-count bias a raw
    accuracy level carries: a single-class UE scores ~1 on its own mask
    whatever it uploads). ``weight * a_k`` enters Eq. 1 as a trust
    penalty (an extra subtracted term inside the same clip), so it flows
    into the Eq. 3 value the scheduler ranks. This is what closes the
    feature-noise reward hole: Eq. 1 only ever compares a UE's *report*
    against measurements, and a noise UE's honestly-low report keeps
    those gaps small — the detector instead reads the measured quality of
    the upload itself: local training on clean data improves (or holds)
    the UE's own classes, while fitting noise-corrupted features drags
    them below the global baseline, no matter what the UE reports. Flags
    (a_k > 0) are metrics-only; ground truth never feeds back.
    """
    # defaults tuned on the §V-scale feature-noise matrix
    # (examples/robustness_extensions.py, DESIGN.md §9): tol=0.1 keeps
    # honest skewed UEs out of the flag set once the global model is
    # trained; weight=5.0 makes one confident detection decisive (a
    # flagged noise UE's anomaly ~0.2 wipes its reputation) so malicious
    # UEs that are only scheduled a few times still end below honest
    n_val: int = 1000
    tol: float = 0.1
    weight: float = 5.0

    def __post_init__(self):
        assert self.n_val >= 1 and self.tol >= 0 and self.weight >= 0

    def anomaly(self, acc_val: np.ndarray) -> np.ndarray:
        """acc_val (2, n): row 0 = per-upload masked validation accuracy,
        row 1 = the global model's accuracy on the same masks."""
        v, g = np.asarray(acc_val, float)
        return np.maximum(g - v - self.tol, 0.0)

    def penalties(self, acc_val: np.ndarray) -> np.ndarray:
        return self.weight * self.anomaly(acc_val)


def detection_stats(flags: np.ndarray, truth: np.ndarray) -> Tuple[float,
                                                                   float]:
    """(precision, recall) of the flagged set against the ground-truth
    malicious mask over the round's cohort (NaN when undefined)."""
    flags = np.asarray(flags, bool)
    truth = np.asarray(truth, bool)
    tp = float((flags & truth).sum())
    prec = tp / flags.sum() if flags.any() else float("nan")
    rec = tp / truth.sum() if truth.any() else float("nan")
    return prec, rec


# ---------------------------------------------------------------------- #
# DefensePolicy: the composite defense + registry
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class DefensePolicy:
    """A named defense: robust aggregator + validation detector. Either
    may be None; all-None is the undefended control (``"none"``)."""
    name: str
    aggregator: Optional[RobustAggregator] = None
    detector: Optional[ValidationDetector] = None

    @property
    def benign(self) -> bool:
        return self.aggregator is None and self.detector is None


DEFENSES: Dict[str, DefensePolicy] = {}


def register(defense: DefensePolicy) -> DefensePolicy:
    assert defense.name not in DEFENSES, \
        f"defense {defense.name!r} already registered"
    DEFENSES[defense.name] = defense
    return defense


def trimmed_mean(trim: float = 0.2,
                 name: Optional[str] = None) -> DefensePolicy:
    name = name or ("trimmed_mean" if trim == 0.2
                    else f"trimmed_mean_{int(round(trim * 100))}")
    return DefensePolicy(name, aggregator=TrimmedMean(trim))


def median(name: Optional[str] = None) -> DefensePolicy:
    return DefensePolicy(name or "median", aggregator=Median())


def norm_clip(tau: float = 1.0,
              name: Optional[str] = None) -> DefensePolicy:
    name = name or ("norm_clip" if tau == 1.0 else f"norm_clip_{tau:g}")
    return DefensePolicy(name, aggregator=NormClip(tau))


def krum(n_select: Optional[int] = None, f: Optional[int] = None,
         name: Optional[str] = None) -> DefensePolicy:
    return DefensePolicy(name or "krum", aggregator=Krum(n_select, f))


def validation(n_val: int = 1000, tol: float = 0.1, weight: float = 5.0,
               name: Optional[str] = None) -> DefensePolicy:
    return DefensePolicy(name or "validation",
                         detector=ValidationDetector(n_val, tol, weight))


def with_validation(base: DefensePolicy,
                    det: Optional[ValidationDetector] = None,
                    name: Optional[str] = None) -> DefensePolicy:
    """Compose a detector onto an aggregator-only defense."""
    return dataclasses.replace(
        base, name=name or f"{base.name}+validation",
        detector=det or ValidationDetector())


NO_DEFENSE = register(DefensePolicy("none"))
register(trimmed_mean(0.2))
register(median())
register(norm_clip(1.0))
register(krum())
register(validation())
register(with_validation(trimmed_mean(0.2)))


def as_defense(spec) -> DefensePolicy:
    """Coerce a defense spec: DefensePolicy passes through, str looks up
    the registry, None is the undefended control."""
    if spec is None:
        return NO_DEFENSE
    if isinstance(spec, DefensePolicy):
        return spec
    if isinstance(spec, str):
        return DEFENSES[spec]
    raise TypeError(f"not a defense policy spec: {spec!r}")
