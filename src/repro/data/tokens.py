"""Synthetic LM token streams (offline container): structured pseudo-text with
learnable bigram statistics, for the end-to-end LM training driver and the
federated-LLM example. A Zipfian unigram base plus a class-conditioned Markov
kernel gives each "domain" (client) its own distribution — mirroring non-IID
federated text."""
from __future__ import annotations

import numpy as np


def zipf_probs(vocab: int, s: float = 1.1) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** s
    return p / p.sum()


def make_stream(n_tokens: int, vocab: int, seed: int = 0,
                domain: int = 0) -> np.ndarray:
    """Markov stream: next-token dist = mix(zipf, shifted-by-domain zipf)."""
    rng = np.random.default_rng(seed + 7919 * domain)
    base = zipf_probs(vocab)
    toks = np.empty(n_tokens, np.int32)
    t = int(rng.integers(vocab))
    for i in range(n_tokens):
        toks[i] = t
        if rng.uniform() < 0.6:               # bigram continuation
            t = (t * 31 + 7 + domain) % vocab
        else:
            t = int(rng.choice(vocab, p=base))
    return toks


def batches(stream: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    """Yield {tokens: (B, S)} windows forever."""
    n = len(stream) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield {"tokens": np.stack([stream[s:s + seq] for s in starts])}
