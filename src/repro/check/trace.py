"""Abstract-trace contract checks (DESIGN.md §11c).

AST lints can't see through helper calls, so the dtype-pinning contract
is additionally enforced on the *jaxprs* of the key entry points:

trace-f64
    The f32 data-plane programs — ``cohort_train``, ``cohort_eval``,
    ``fedavg_stacked``, the trimmed-mean/median defended aggregation,
    ``ModelAttack.apply_stacked`` — are traced UNDER ``enable_x64()``
    (so any stray literal f64 promotion becomes visible instead of
    being silently squashed to f32) with explicitly f32-dtyped inputs,
    and their jaxprs must contain no float64 value and no
    ``convert_element_type`` to float64. NormClip/Krum are the
    documented exception: their norm/distance reductions are f64 by
    design (core/defenses.py) and are excluded.

control-f64-pin
    The mirror contract: the control-plane kernels
    (``_schedule_kernel``, ``_finalize_kernel``) traced under
    ``enable_x64`` with f64 inputs must produce f64 outputs — Eq. 1-3
    and Eq. 9 run in double precision, matching the host oracle's
    numpy dtype, or reputation streams fork.

static-args
    Every ``static_argnames`` / ``static_argnums`` in ``src/repro``
    must be a literal (computed static specs silently change compile
    keys), and every value the repo actually passes statically — the
    ``TASKS`` registry entries — must be hashable frozen dataclasses.

Any exception while building inputs or tracing is itself reported as a
``trace-error`` violation: a trace check that cannot run must fail
loudly, not pass silently.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, List, Tuple

import numpy as np

from repro.check.common import (CheckContext, Violation, dotted_name,
                                iter_functions)


# --------------------------------------------------------------------- #
# jaxpr scanning
# --------------------------------------------------------------------- #
def _jaxpr_f64_sites(jaxpr) -> List[str]:
    """Human-readable descriptions of every f64 occurrence in a closed
    jaxpr (recursing into sub-jaxprs)."""
    sites: List[str] = []

    def strong_f64(v) -> bool:
        aval = getattr(v, "aval", None)
        if aval is None or getattr(aval, "dtype", None) is None:
            return False
        # weak-typed f64 literals (python scalars under x64) promote to
        # the array dtype at the op — only strongly-typed f64 forks f32
        if getattr(aval, "weak_type", False):
            return False
        return np.dtype(aval.dtype) == np.dtype("float64")

    def scan(jx):
        for v in list(jx.invars) + list(jx.outvars) + list(jx.constvars):
            if strong_f64(v):
                sites.append(f"f64 value {v}")
        for eqn in jx.eqns:
            for v in eqn.outvars:
                if strong_f64(v):
                    sites.append(
                        f"f64 intermediate {v} <- {eqn.primitive.name}")
            if eqn.primitive.name == "convert_element_type" \
                    and np.dtype(eqn.params.get("new_dtype")) == \
                    np.dtype("float64"):
                sites.append("convert_element_type -> float64")
            for sub in eqn.params.values():
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    scan(inner)

    scan(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return sites


def assert_no_f64(name: str, trace_fn: Callable[[], object]
                  ) -> List[Violation]:
    """Trace ``trace_fn`` (must return a jaxpr) under x64 and report
    every f64 site. Self-test entry point: any f32 program can be
    checked through this."""
    import jax
    from jax.experimental import enable_x64
    try:
        with enable_x64():
            jaxpr = trace_fn()
    except Exception as e:                          # noqa: BLE001
        return [Violation(rule="trace-error", path=name, line=0,
                          message=f"tracing `{name}` failed: {e!r}")]
    return [Violation(
        rule="trace-f64", path=name, line=0,
        message=f"f32-path `{name}`: {site} — the data plane is "
                "f32-pinned (DESIGN.md §11)")
        for site in _jaxpr_f64_sites(jaxpr)[:5]]


def assert_f64_outputs(name: str, trace_fn: Callable[[], object]
                       ) -> List[Violation]:
    import jax
    from jax.experimental import enable_x64
    try:
        with enable_x64():
            jaxpr = trace_fn()
    except Exception as e:                          # noqa: BLE001
        return [Violation(rule="trace-error", path=name, line=0,
                          message=f"tracing `{name}` failed: {e!r}")]
    bad = [str(v) for v in jaxpr.jaxpr.outvars
           if getattr(v.aval, "dtype", None) is not None
           and np.dtype(v.aval.dtype).kind == "f"
           and np.dtype(v.aval.dtype) != np.dtype("float64")]
    return [Violation(
        rule="control-f64-pin", path=name, line=0,
        message=f"control kernel `{name}` output {v} is not f64 under "
                "enable_x64 — Eq. 1-3/9 must match the host oracle's "
                "double precision") for v in bad]


# --------------------------------------------------------------------- #
# repo entry points
# --------------------------------------------------------------------- #
def check_traces(ctx: CheckContext) -> List[Violation]:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import FeelConfig
    from repro.core import control as ctl
    from repro.core import defenses as dfs
    from repro.federated import cohort
    from repro.federated.aggregation import fedavg_stacked
    from repro.federated.task import TASKS

    out: List[Violation] = []
    task = TASKS["mnist_mlp"]
    params = task.init_params(jax.random.PRNGKey(0))
    N, S, U = 2, 8, 6
    f32 = jnp.float32
    data = {"x": jnp.zeros((N, S, 784), f32),
            "y": jnp.zeros((N, S), jnp.int32)}
    mask = jnp.ones((N, S), f32)
    lr = jnp.asarray(0.1, f32)

    out += assert_no_f64(
        "cohort.cohort_train",
        lambda: jax.make_jaxpr(
            lambda p, d, m, r: cohort.cohort_train(task, p, d, m, r, 1, 4)
        )(params, data, mask, lr))

    stacked = cohort.broadcast_params(params, N)
    ei = {"x": jnp.zeros((U, 784), f32)}
    yu = jnp.zeros((U,), jnp.int32)
    masks = jnp.ones((N, U), f32)
    out += assert_no_f64(
        "cohort.cohort_eval",
        lambda: jax.make_jaxpr(
            lambda sp, e, y, m: cohort.cohort_eval(task, sp, e, y, m)
        )(stacked, ei, yu, masks))

    w = jnp.asarray(np.array([1.0, 3.0], np.float32))
    out += assert_no_f64(
        "aggregation.fedavg_stacked",
        lambda: jax.make_jaxpr(fedavg_stacked)(stacked, w))

    # the defended aggregation's batched jnp path stages its sort through
    # the host on CPU (core/defenses._sorted_rows — an eager, documented
    # perf choice), so the traceable f32 contract lives in the pure-jnp
    # oracle twin the kernel is pinned against
    from repro.kernels import ref as kref
    flat = jnp.zeros((4, 16), f32)
    for mode, trim in (("trimmed_mean", 1), ("median", 0)):
        out += assert_no_f64(
            f"kernels.robust_aggregate_ref[{mode}]",
            lambda mode=mode, trim=trim: jax.make_jaxpr(
                lambda fl: kref.robust_aggregate_ref(
                    fl, 4, trim=trim, mode=mode))(flat))
    out += assert_no_f64(
        "kernels.weighted_aggregate_ref",
        lambda: jax.make_jaxpr(kref.weighted_aggregate_ref)(
            flat, jnp.ones((4,), f32)))

    from repro.core.attacks import ModelAttack
    ma = ModelAttack(scale=-1.0)
    mal = np.array([True, False])
    out += assert_no_f64(
        "attacks.ModelAttack.apply_stacked",
        lambda: jax.make_jaxpr(
            lambda sp, gp: ma.apply_stacked(sp, gp, mal))(stacked, params))

    # control plane: f64-pinned under enable_x64
    cfg = FeelConfig()
    R, K = 2, 4
    f64 = np.float64
    out += assert_f64_outputs(
        "control._finalize_kernel",
        lambda: jax.make_jaxpr(ctl._finalize_kernel)(
            np.zeros((R, K), f64), np.zeros((R, K), f64),
            np.zeros((R, K), f64), np.zeros((R, K), f64),
            np.zeros((R, K), f64), np.zeros((R, K), f64),
            f64(cfg.eta), f64(cfg.beta1), f64(cfg.beta2)))
    out += assert_f64_outputs(
        "control._schedule_kernel",
        lambda: jax.make_jaxpr(
            lambda *a: ctl._schedule_kernel(*a, k=K, n_sel=2)[1:4]
        )(np.zeros(R, np.int32), np.zeros((R, K), f64),
          np.ones((R, K), f64), np.full((R, K), 0.5, f64),
          np.full((R, K), 100.0, f64), np.full((R, K), 1e4, f64),
          np.full((R, K), 1.0, f64),
          np.tile(np.arange(K), (R, 1)).astype(f64),
          np.full(R, 0.5, f64), np.full(R, 0.5, f64),
          f64(cfg.gamma), f64(cfg.bandwidth_hz), f64(cfg.p_watt),
          f64(cfg.n0_watt_hz)))
    return out


# --------------------------------------------------------------------- #
# static-arg discipline
# --------------------------------------------------------------------- #
def _static_spec_literal(call: ast.Call) -> List[Tuple[str, bool]]:
    """[(kwarg, is_literal)] for static_argnames/static_argnums kwargs."""
    out = []
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            try:
                ast.literal_eval(kw.value)
                out.append((kw.arg, True))
            except (ValueError, SyntaxError):
                out.append((kw.arg, False))
    return out


def check_static_args(ctx: CheckContext) -> List[Violation]:
    out: List[Violation] = []
    # (a) AST: every static spec in src is a literal
    for src in ctx.sources:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func) or ""
            if name.split(".")[-1] not in ("jit", "partial"):
                continue
            for kwarg, ok in _static_spec_literal(node):
                if not ok and not src.waived("static-args", node.lineno):
                    out.append(Violation(
                        rule="static-args", path=src.rel,
                        line=node.lineno,
                        message=f"`{kwarg}` is not a literal — computed "
                                "static specs make compile-cache keys "
                                "unauditable"))
    # (b) runtime: statically-passed registry values are hashable+frozen
    from repro.federated.task import TASKS
    for name, t in sorted(TASKS.items()):
        try:
            hash(t)
        except TypeError:
            out.append(Violation(
                rule="static-args", path="src/repro/federated/task.py",
                line=1,
                message=f"task `{name}` is unhashable — tasks pass "
                        "through jit static_argnames and must hash"))
            continue
        if not (dataclasses.is_dataclass(t)
                and type(t).__dataclass_params__.frozen):
            out.append(Violation(
                rule="static-args", path="src/repro/federated/task.py",
                line=1,
                message=f"task `{name}` is not a frozen dataclass — "
                        "mutable static args silently stale the "
                        "compile cache"))
    return out
