"""Vectorized cohort execution engine (Alg. 1, all scheduled UEs at once).

The paper trains every scheduled UE independently per round; the seed
implemented that as a sequential Python loop (`FeelServer.run_round` ->
`local_train`) that re-traced `mlp_sgd_epoch` for every distinct client
dataset size. Here the round's cohort is stacked into (N, max_samples, ...)
arrays (see ``data.partition.pad_clients`` for the padding/masking
contract) and all N local trainings run in ONE jitted, vmapped program:

    cohort_train — vmap of (masked epochs + masked local accuracy) over the
        leading client axis; global params are broadcast in, per-client
        trained params come back stacked on axis 0, ready for
        ``fedavg_stacked`` / the Pallas ``weighted_aggregate`` kernel.
    cohort_eval  — one vmapped pass scoring every uploaded model on the
        (per-UE masked) public test set, replacing the server's per-model
        evaluation loop (Alg. 1 line 14).

Shapes are cohort-size dependent, so each distinct (N, max_samples) pair
compiles once and is cached for all later rounds; padding max_samples to a
round-stable value (pad_clients pads to the global client maximum) keeps
the number of distinct shapes equal to the number of distinct cohort sizes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.mlp import (mlp_accuracy_masked, mlp_apply,
                              mlp_sgd_epoch_masked)


@partial(jax.jit, static_argnames=("epochs", "batch_size"))
def cohort_train(params, x, y, mask, lr, epochs: int, batch_size: int = 50):
    """Train the whole cohort in one vmapped step.

    params — global model (broadcast to every client);
    x (N, S, D), y (N, S), mask (N, S) — the padded, stacked cohort.
    Returns (stacked_params with leaves (N, ...), acc_local (N,)) where
    acc_local is each client's self-reported accuracy on its own (valid)
    samples after local training (Alg. 1 line 11).
    """
    def one(xi, yi, mi):
        # fori_loop (not Python unrolling) keeps the traced epoch body
        # single-copy — compile time is the cohort engine's main fixed cost
        p = jax.lax.fori_loop(
            0, epochs,
            lambda _, q: mlp_sgd_epoch_masked(q, xi, yi, mi, lr, batch_size),
            params)
        return p, mlp_accuracy_masked(p, xi, yi, mi)

    return jax.vmap(one)(x, y, mask)


@jax.jit
def cohort_eval(stacked_params, x, y, masks):
    """Score every uploaded model on the public test set in one vmap.

    stacked_params — leaves (N, ...); x (T, D), y (T,) — the full test set;
    masks (N, T) — per-UE evaluation masks (the server restricts Eq. 1's
    acc_test to the classes a UE claims to hold). Returns (N,) accuracies,
    0.0 where a mask is empty.
    """
    def one(p, m):
        correct = (jnp.argmax(mlp_apply(p, x), -1) == y).astype(jnp.float32)
        return jnp.sum(correct * m) / jnp.maximum(jnp.sum(m), 1.0)

    return jax.vmap(one)(stacked_params, masks)


def unstack(stacked_params, i: int):
    """Extract client ``i``'s parameter pytree from the stacked cohort."""
    return jax.tree.map(lambda l: l[i], stacked_params)
