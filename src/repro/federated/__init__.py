from repro.federated.aggregation import fedavg, fedavg_stacked
from repro.federated.client import ClientReport, local_train
from repro.federated.server import FeelServer, RoundLog
from repro.federated.simulation import averaged, run_experiment

__all__ = ["fedavg", "fedavg_stacked", "ClientReport", "local_train",
           "FeelServer", "RoundLog", "averaged", "run_experiment"]
