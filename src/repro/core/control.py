"""Batched control plane (paper §III-IV, all runs at once).

The paper's per-round control loop — channel draw -> Eq. 9 bandwidth costs
-> Eq. 2/3 data-quality values -> Algorithm 2 selection -> Eq. 1 reputation
update — was sequential per-server numpy: at sweep scale (policies x seeds x
attack pairs) the *scheduler*, not training, became the serial bottleneck,
and Eq. 9's dense (K, K) rate matrix capped the UE count. Here the control
state of all R runs lives in a ``ControlState`` struct-of-arrays with a
leading run axis, and round t of every run is scheduled together:

    schedule_runs — values (Eq. 2/3) -> costs (Eq. 9 monotone bisection,
        O(K log K)) -> per-policy priority key -> shared greedy packing
        -> dqs modified-greedy fallback / top-value override ->
        forced-round rewrite. One batched pass, no per-run Python.
    finalize_runs — Eq. 1 reputation update + staleness ages of every run
        in one call (reputation.reputation_update_eq1).

Two kernel layouts compute the identical schedule (tests/test_control.py
pins them equal):

    "jax"    — ONE jitted vmapped kernel (``_schedule_kernel``): the whole
        phase is a single XLA program. The right layout for accelerator
        backends, and the reference composition of the pure per-equation
        functions (wireless.cost_bisect, scheduler.greedy_pack_jnp, ...).
    "hybrid" — CPU default. XLA CPU's float64 sort is ~5x slower than
        numpy's and its elementwise math has per-op dispatch cost, while
        numpy cannot express the sequential budget-carrying pack at all
        and loses ~3x to XLA on the log2-heavy Eq. 9 probes. So the
        elementwise math and the stable argsort run as *batched numpy*
        (the same float64 ops as the host oracle, over the (R, K) block)
        and two small jitted kernels do what numpy cannot: the Eq. 9
        bisection and the lax.scan greedy pack. Still zero per-run Python.

Randomness stays on the host: each run draws its K channel gains (and, for
the ``random`` policy, its permutation) from its own numpy Generator — the
exact streams of the sequential oracle — and the kernels are deterministic
functions of those draws. Everything runs in float64 (``enable_x64``) with
the same operation order as the numpy oracle. Parity contract
(tests/test_control.py): the hybrid layout reproduces the host oracle
bit-for-bit on every output — values, keys, pack sums, Eq. 1 updates all
use the oracle's own float64 expressions and summation order; the one
theoretical residue is Eq. 9's jitted bisection, where XLA's log2 may
differ from libm's by an ulp and could flip an integer cost only on a
measure-zero comparison boundary (never observed; pinned exact on random
instances). The jax layout matches the integer outputs (selection, costs,
forced) bit-for-bit and the float outputs to ~1 ulp — XLA contracts
``a*b + c`` into FMAs and strength-reduces the divide-by-constant in
alpha, so its last bit can differ from numpy's.

The per-run path survives as ``FeelServer(..., control="host")`` — the
bit-parity oracle, mirroring the ``engine="loop"`` pattern of the data
plane.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.configs.base import FeelConfig
from repro.core.diversity import diversity_index_eq2, diversity_index_rows
from repro.core.quality import data_quality_value
from repro.core.reputation import reputation_update_eq1
from repro.core.scheduler import (POLICY_IDS, greedy_pack_jnp, pack_scan,
                                  priority_key)
from repro.core.wireless import cost_bisect
from repro.obs import trace


@dataclasses.dataclass
class ControlState:
    """Struct-of-arrays control-plane state for R runs over K UEs each.

    Static per-run fields (sizes, element diversities, Eq. 5-7 minimum
    rates, policy ids) are stacked once; the mutable fields (reputations,
    ages) are synced from/to the owning ``FeelServer`` objects around each
    round (``pull`` / ``push``) so the servers' logs and summaries keep
    reading their usual attributes.

    The trailing axis is the candidate width: K in the legacy regime,
    N = cfg.n_population under a population cut (DESIGN.md §12) — every
    kernel takes the width from the arrays and the bandwidth budget from
    ``cfg.n_ues``.
    """
    policy_id: np.ndarray     # (R,)  int32, scheduler.POLICY_IDS
    sizes: np.ndarray         # (R, K) float64 true dataset sizes
    divs: np.ndarray          # (R, K) element (Gini-Simpson) diversities
    r_min: np.ndarray         # (R, K) Eq. 9 min rates (round-invariant)
    reputations: np.ndarray   # (R, K) Eq. 1 state
    ages: np.ndarray          # (R, K) rounds since last selected
    cfg: FeelConfig           # shared scalars (asserted identical per run)

    @property
    def n_runs(self) -> int:
        return self.policy_id.shape[0]

    @classmethod
    def from_servers(cls, servers: Sequence) -> "ControlState":
        cfg = servers[0].cfg
        # the control plane never touches the data/model plane, so configs
        # differing ONLY in ``task`` are compatible — a mixed-task sweep
        # (run_sweep(tasks=[...])) schedules every run through one kernel
        assert all(dataclasses.replace(s.cfg, task=cfg.task) == cfg
                   for s in servers), \
            "batched control requires one shared FeelConfig across runs " \
            "(modulo the task field)"
        r_min = np.stack([
            s.wireless.min_rate(s.wireless.train_time(s.sizes, s.cpu_hz))
            for s in servers])
        return cls(
            policy_id=np.array([POLICY_IDS[s.policy] for s in servers],
                               np.int32),
            sizes=np.stack([s.sizes for s in servers]).astype(float),
            divs=np.stack([s.divs for s in servers]).astype(float),
            r_min=r_min,
            reputations=np.stack([s.reputation.values for s in servers]),
            ages=np.stack([s.ages for s in servers]),
            cfg=cfg)

    def pull(self, servers: Sequence) -> None:
        """Refresh the mutable rows from the servers (before a round)."""
        for i, s in enumerate(servers):
            self.reputations[i] = s.reputation.values
            self.ages[i] = s.ages

    def push(self, servers: Sequence) -> None:
        """Write the mutable rows back to the servers (after finalize)."""
        for i, s in enumerate(servers):
            s.reputation.values[:] = self.reputations[i]
            s.ages[:] = self.ages[i]


# ---------------------------------------------------------------------- #
# "jax" layout: the whole schedule phase as ONE jitted vmapped kernel
# ---------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("k", "n_sel"))
def _schedule_kernel(policy_id, rep, ages, divs, sizes, r_min, gains,
                     rand_rank, w_rep, w_div, gamma, bandwidth_hz, p_watt,
                     n0, *, k: int, n_sel: int):
    """One round of every run: (R, K) arrays in, (x, alpha, costs, values,
    forced) out. vmapped over the run axis; float64 under enable_x64."""

    def one(pid, rep, ages, divs, sizes, r_min, gains, rand_rank,
            w_rep, w_div):
        # Eq. 2/3 — data-quality values
        I = diversity_index_eq2(divs, sizes, ages, gamma)
        values = data_quality_value(rep, I, None, omega=(w_rep, w_div))
        # Eq. 9 — bandwidth costs by monotone bisection
        costs = cost_bisect(gains, r_min, k, bandwidth_hz, p_watt, n0)
        costs_f = costs.astype(values.dtype)
        # priority keys — the ONE definition in scheduler.priority_key;
        # the ascending stable argsort of each reproduces the host
        # policy's visit order
        key = jnp.where(
            pid == 0, priority_key("dqs", values, costs_f, k),
            jnp.where(pid == 1, rand_rank.astype(values.dtype),
                      jnp.where(pid == 2,
                                priority_key("best_channel", values,
                                             costs_f, k, gains=gains),
                                costs_f)))
        x, alpha = greedy_pack_jnp(key, costs, k)

        # dqs modified-greedy fallback: best single feasible UE vs the pack
        feas = costs <= k
        masked = jnp.where(feas, values, -jnp.inf)
        k_best = jnp.argmax(masked)
        use_fb = ((pid == 0) & feas.any()
                  & (masked[k_best] > (values * x).sum()))
        onehot_best = jnp.zeros_like(x).at[k_best].set(True)
        x = jnp.where(use_fb, onehot_best, x)
        alpha = jnp.where(use_fb,
                          jnp.where(onehot_best, costs_f / k, 0.0), alpha)

        # top_value override: top-n by value, no wireless constraint
        rank = jnp.argsort(jnp.argsort(-values, stable=True), stable=True)
        x = jnp.where(pid == 4, rank < n_sel, x)
        alpha = jnp.where(pid == 4,
                          jnp.where(rank < n_sel, 1.0 / max(n_sel, 1), 0.0),
                          alpha)

        # degenerate round: no UE met the deadline — force the single
        # highest-value UE (whole band); problem (8) was infeasible, the
        # caller logs objective 0.0 (DESIGN.md §2)
        forced = ~x.any()
        onehot_f = jnp.zeros_like(x).at[jnp.argmax(values)].set(True)
        x = jnp.where(forced, onehot_f, x)
        alpha = jnp.where(forced, jnp.where(onehot_f, 1.0, 0.0), alpha)
        return x, alpha, costs, values, forced

    return jax.vmap(one)(policy_id, rep, ages, divs, sizes, r_min, gains,
                         rand_rank, w_rep, w_div)


# ---------------------------------------------------------------------- #
# "hybrid" layout: batched numpy + the two kernels numpy cannot express
# ---------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("k",))
def _cost_kernel(gains, r_min, bandwidth_hz, p_watt, n0, *, k: int):
    return cost_bisect(gains, r_min, k, bandwidth_hz, p_watt, n0)


_pack_kernel = functools.partial(jax.jit, static_argnames=("k",))(pack_scan)


def _schedule_hybrid(state: ControlState, gains, rand_rank, w_rep, w_div):
    cfg = state.cfg
    K = cfg.n_ues                        # bandwidth budget (fractions)
    R = state.n_runs
    N = state.reputations.shape[1]       # candidate width (N == K legacy)
    pid = state.policy_id

    # Eq. 2/3 — batched numpy, same float64 ops as the host oracle
    I = diversity_index_rows(state.divs, state.sizes, state.ages,
                             cfg.gamma)
    values = data_quality_value(state.reputations, I, cfg,
                                omega=(w_rep[:, None], w_div[:, None]))

    # Eq. 9 — jitted bisection (XLA's f64 log2 beats numpy's ~3x here)
    with enable_x64():
        costs = np.asarray(_cost_kernel(
            gains, state.r_min, cfg.bandwidth_hz, cfg.p_watt,
            cfg.n0_watt_hz, k=K)).astype(int)
    costs_f = costs.astype(float)

    # priority keys — the ONE definition in scheduler.priority_key
    keys = np.empty((R, N))
    m = pid == 0
    keys[m] = priority_key("dqs", values[m], costs_f[m], K)
    m = pid == 1
    keys[m] = rand_rank[m]
    m = pid == 2
    keys[m] = priority_key("best_channel", values[m], costs_f[m], K,
                           gains=gains[m])
    m = (pid == 3) | (pid == 4)          # top_value rows: key unused
    keys[m] = costs_f[m]

    # shared greedy pack: numpy stable sort + the scan kernel
    order = np.argsort(keys, axis=-1, kind="stable")
    c_sorted = np.take_along_axis(costs, order, -1).astype(np.int32)
    take = np.asarray(_pack_kernel(c_sorted, k=K))
    x = np.zeros((R, N), bool)
    np.put_along_axis(x, order, take, -1)
    alpha = np.where(x, costs_f / K, 0.0)

    # dqs modified-greedy fallback. The pack-value side of the comparison
    # sums the COMPRESSED selection exactly like the host oracle
    # (values[x].sum()) — a full-K masked sum groups numpy's pairwise
    # summation differently and could flip the '>' on a ~1-ulp tie,
    # silently breaking host parity.
    feas = costs <= K
    masked = np.where(feas, values, -np.inf)
    k_best = masked.argmax(-1)
    rows = np.arange(R)
    pack_val = np.array([values[i][x[i]].sum() if pid[i] == 0 else 0.0
                         for i in range(R)])
    use_fb = ((pid == 0) & feas.any(-1)
              & (masked[rows, k_best] > pack_val))
    fb = np.flatnonzero(use_fb)
    x[fb] = False
    x[fb, k_best[fb]] = True
    alpha[fb] = 0.0
    alpha[fb, k_best[fb]] = costs_f[fb, k_best[fb]] / K

    # top_value override
    tv = np.flatnonzero(pid == 4)
    if tv.size:
        n = cfg.min_selected
        top = np.argsort(-values[tv], axis=-1, kind="stable")[:, :n]
        xt = np.zeros((tv.size, N), bool)
        np.put_along_axis(xt, top, True, -1)
        x[tv] = xt
        alpha[tv] = np.where(xt, 1.0 / max(n, 1), 0.0)

    # degenerate rounds: force the single highest-value UE
    forced = ~x.any(-1)
    fr = np.flatnonzero(forced)
    kf = values[fr].argmax(-1)
    x[fr] = False
    x[fr, kf] = True
    alpha[fr] = 0.0
    alpha[fr, kf] = 1.0
    return x, alpha, costs, values, forced


# ---------------------------------------------------------------------- #
# Host entry points
# ---------------------------------------------------------------------- #
@functools.lru_cache(maxsize=1)
def default_kernel() -> str:
    """Backend default, resolved lazily on first use — probing
    jax.default_backend() at import time would eagerly initialize XLA for
    every ``import repro.core`` and lock the platform choice.

    Single-device CPU keeps "hybrid" (numpy's sort + elementwise beat
    XLA CPU there, module docstring); accelerators and *multi-device
    meshes* default to "jax" — the hybrid layout is host-numpy and
    cannot shard, while the jitted kernel GSPMD-partitions the UE axis
    across the mesh and wins from the first extra device (re-benched on
    the forced-multi-device host mesh in results/BENCH_population.json;
    crossover recorded in DESIGN.md §12)."""
    if jax.default_backend() != "cpu":
        return "jax"
    return "jax" if jax.local_device_count() > 1 else "hybrid"


def schedule_runs(state: ControlState, gains: np.ndarray,
                  rand_rank: np.ndarray, w_rep: np.ndarray,
                  w_div: np.ndarray, kernel: Optional[str] = None):
    """Schedule round t of all R runs in one batched pass.

    gains — (R, K) per-run channel draws (host RNG, oracle streams);
    rand_rank — (R, K) inverse permutations for ``random``-policy rows
    (ignored elsewhere); w_rep / w_div — (R,) Eq. 3 weights (annealed per
    round under adaptive omega); kernel — "jax" | "hybrid" (None = the
    backend default, see module docstring; both produce the identical
    schedule). Returns numpy (x bool, alpha, costs int, values, forced).
    """
    gains = np.asarray(gains, float)
    rand_rank = np.asarray(rand_rank)
    w_rep = np.asarray(w_rep, float)
    w_div = np.asarray(w_div, float)
    kern = kernel or default_kernel()
    with trace.span("schedule.pack") as sp:
        if trace.enabled():
            sp.set(kernel=kern, runs=int(state.n_runs),
                   width=int(state.reputations.shape[1]))
        if kern == "hybrid":
            return _schedule_hybrid(state, gains, rand_rank, w_rep, w_div)
        cfg = state.cfg
        with enable_x64():
            x, alpha, costs, values, forced = _schedule_kernel(
                state.policy_id, state.reputations, state.ages, state.divs,
                state.sizes, state.r_min, gains, rand_rank, w_rep, w_div,
                np.asarray(cfg.gamma, float), cfg.bandwidth_hz, cfg.p_watt,
                cfg.n0_watt_hz, k=cfg.n_ues, n_sel=cfg.min_selected)
        return (np.asarray(x), np.asarray(alpha),
                np.asarray(costs).astype(int), np.asarray(values),
                np.asarray(forced))


@jax.jit
def _finalize_kernel(rep, ages, sel_mask, acc_local, acc_test, pen,
                     eta, beta1, beta2):
    """Eq. 1 (+ defense trust penalty) + staleness for every run."""
    rep = reputation_update_eq1(rep, sel_mask, acc_local, acc_test,
                                eta, beta1, beta2, penalty=pen)
    ages = jnp.where(sel_mask > 0, 1.0, ages + 1.0)
    return rep, ages


def finalize_runs(state: ControlState, sels: List[np.ndarray],
                  acc_locals: List[np.ndarray],
                  acc_tests: List[np.ndarray],
                  penalties: Optional[List] = None,
                  kernel: Optional[str] = None) -> None:
    """Eq. 1 reputation + staleness of all R runs in one call, written back
    into ``state`` (callers then ``push`` to the servers).

    ``penalties`` — optional per-run defense trust penalties (aligned with
    ``sels``; entries may be None): the validation detector's extra
    subtracted Eq. 1 term (core/defenses.py, DESIGN.md §9).

    The hybrid layout applies Eq. 1 as batched numpy with the cohort
    average computed exactly like the host tracker (np.mean over the
    compressed cohort) — bit-for-bit against ReputationTracker.update.
    The jax layout routes through the jitted kernel (accelerator path;
    ~1 ulp from FMA contraction).
    """
    cfg = state.cfg
    R, K = state.reputations.shape
    with trace.span("schedule.finalize") as sp:
        if trace.enabled():
            sp.set(runs=int(R), width=int(K))
        mask = np.zeros((R, K))
        al = np.zeros((R, K))
        at = np.zeros((R, K))
        pen = np.zeros((R, K))
        for i, (sel, a, t) in enumerate(zip(sels, acc_locals, acc_tests)):
            mask[i, sel] = 1.0
            al[i, sel] = a
            at[i, sel] = t
            if penalties is not None and penalties[i] is not None:
                pen[i, sel] = penalties[i]
        if (kernel or default_kernel()) == "hybrid":
            # cohort average computed exactly like the host tracker (np.mean
            # over the compressed cohort, not a full-K masked sum)
            avg = np.array([[np.mean(a) if len(a) else 0.0]
                            for a in acc_locals])
            delta = cfg.eta * (cfg.beta1 * (al - avg)
                               + cfg.beta2 * (al - at)) + pen
            new = np.clip(state.reputations - delta, 0.0, 1.0)
            state.reputations = np.where(mask > 0, new, state.reputations)
            state.ages = np.where(mask > 0, 1.0, state.ages + 1.0)
            return
        with enable_x64():
            rep, ages = _finalize_kernel(
                state.reputations, state.ages, mask, al, at, pen,
                cfg.eta, cfg.beta1, cfg.beta2)
        # np.array (not asarray): device outputs give read-only numpy views,
        # and these buffers are written in-place by the next round's pull()
        state.reputations = np.array(rep)
        state.ages = np.array(ages)


def staleness_discount(ages: np.ndarray, decay: float) -> np.ndarray:
    """Staleness discount d(a) = decay**a for the async engine (DESIGN.md §13).

    ``ages`` — integer aggregation ages (aggregation_version minus the
    model version the update was computed on), all >= 0. ``decay`` in
    (0, 1] is ``cfg.async_staleness``. Host float64 like the rest of the
    control plane. d(0) == 1.0 *exactly* (any IEEE base to the 0th power),
    so an age-0 upload's weight ``w * d(0)`` is bit-identical to the
    FedAvg weight — the zero-latency parity contract rests on this.
    """
    ages = np.asarray(ages)
    assert 0.0 < decay <= 1.0, f"async_staleness must be in (0, 1]: {decay}"
    assert np.all(ages >= 0), "negative staleness age"
    return np.asarray(decay, np.float64) ** ages.astype(np.float64)
