"""Sharding rules + a real (subprocess) mini dry-run on 8 fake devices.

The subprocess is needed because XLA_FLAGS device-count is locked at first
jax init — the main test process must keep its single CPU device.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_divisible_everywhere():
    """Every sharded dim divides exactly (NamedSharding requirement) for every
    assigned arch on the production mesh shape."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import functools, jax
from repro.configs import get, list_archs
from repro.models import api
from repro.sharding.specs import param_specs, _axis_size
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
bad = []
for arch in list_archs():
    cfg = get(arch)
    params = jax.eval_shape(functools.partial(api.init, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(cfg, params, mesh)
    def check(path, leaf, spec):
        for i, s in enumerate(spec):
            if s is not None and leaf.shape[i] % _axis_size(mesh, s):
                bad.append((arch, path, leaf.shape, tuple(spec)))
    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs,
        is_leaf=lambda x: hasattr(x, "shape"))
print("BAD" if bad else "OK", bad[:3])
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": SRC},
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.strip().startswith("OK"), r.stdout + r.stderr[-500:]


@pytest.mark.slow
def test_mini_dryrun_lowers_and_compiles(tmp_path):
    """A reduced arch lowers + compiles on a small fake mesh, proving the
    jit/shard pipeline end-to-end inside the test suite."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, functools, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import TrainConfig, get, reduced
from repro.launch.steps import make_train_step
from repro.models import api
from repro.sharding.specs import param_specs, opt_state_specs
from repro.optim import make_optimizer

cfg = dataclasses.replace(reduced(get("qwen2-moe-a2.7b")), vocab_size=1024)
mesh = jax.make_mesh((2, 4), ("data", "model"))
tcfg = TrainConfig(optimizer="adamw")
params = jax.eval_shape(functools.partial(api.init, cfg), jax.random.PRNGKey(0))
pspecs = param_specs(cfg, params, mesh)
opt = make_optimizer(tcfg)
opt_sds = jax.eval_shape(opt.init, params)
ospecs = opt_state_specs("adamw", params, pspecs, mesh)
mk = lambda t, s: jax.tree.map(
    lambda x, sp: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                       sharding=NamedSharding(mesh, sp)), t, s)
batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32,
                                        sharding=NamedSharding(mesh, P("data", None)))}
step_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
fn = make_train_step(cfg, tcfg)
with mesh:
    compiled = jax.jit(fn).lower(mk(params, pspecs), mk(opt_sds, ospecs),
                                 step_in, batch).compile()
cost = compiled.cost_analysis()
# newer JAX returns a per-device list of dicts (same logic as
# repro.launch.dryrun.cost_dict, inlined here: importing dryrun would
# clobber this subprocess's 8-device XLA_FLAGS with its 512)
if isinstance(cost, (list, tuple)):
    cost = cost[0] if cost else {}
cost = cost or {}
assert cost.get("flops", 0) > 0
print("COMPILED_OK", int(cost.get("flops", 0)))
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": SRC},
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COMPILED_OK" in r.stdout, r.stdout


def test_zero_shard_adds_data_axis():
    from repro.sharding.specs import _zero_shard
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 4, "model": 2}
        axis_names = ("data", "model")
    from jax.sharding import PartitionSpec as P
    out = _zero_shard(P(None, "model"), (16, 8), FakeMesh)
    assert out == P("data", "model")
    # refuses non-divisible
    out = _zero_shard(P(None, "model"), (3, 8), FakeMesh)
    assert out == P(None, "model")
