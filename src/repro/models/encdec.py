"""Encoder-decoder backbone (Seamless-M4T medium transformer backbone,
arXiv:2308.11596). Per the assignment carve-out the modality frontend is a
stub: the encoder consumes precomputed frame embeddings ``(B, S_src, d)``
(mel-spectrogram + conv feature extractor output), projected by one linear
layer. The decoder is a standard causal transformer with per-layer
cross-attention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import blocks as blk
from repro.models.common import (cross_entropy, dense_init, dtype_of,
                                 embed_init, ones, rms_norm)
from repro.sharding.ctx import constrain

_KIND = {"mixer": "attn", "mlp": "dense"}


def encdec_init(key, cfg):
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    d = cfg.d_model
    assert cfg.encoder_layers > 0 and cfg.is_encoder_decoder
    return {
        "src_proj": dense_init(ks[0], (d, d), dt),
        "enc_blocks": blk.stacked_blocks_init(ks[1], cfg,
                                              n_blocks=cfg.encoder_layers),
        "enc_norm": ones((d,), dt),
        "embed": embed_init(ks[2], (cfg.vocab_size, d), dt),
        "dec_blocks": blk.stacked_blocks_init(ks[3], cfg,
                                              cross_attention=True),
        "final_norm": ones((d,), dt),
        "lm_head": dense_init(ks[4], (d, cfg.vocab_size), dt),
    }


def _encode(cfg, params, src, remat=False):
    """src (B,S_src,d) frame embeddings -> encoder output (bidirectional)."""
    h = constrain(src.astype(dtype_of(cfg)) @ params["src_proj"], "act")

    def body(carry, bp):
        h = carry
        p = bp["layers"][0]
        hin = rms_norm(h, p["norm1"], cfg.norm_eps)
        q, k, v = attn._project_qkv(cfg, p["mixer"], hin)
        S = hin.shape[1]
        pos = jnp.arange(S)[None]
        from repro.models.common import apply_rope
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        mask = jnp.ones((1, 1, 1, S, S), bool)          # bidirectional
        y = attn.sdpa(q, k, v, mask) @ p["mixer"]["wo"]
        h = h + y
        h2 = rms_norm(h, p["norm2"], cfg.norm_eps)
        from repro.models.common import swiglu_apply
        h = h + swiglu_apply(p["mlp"], h2)
        return constrain(h, "act"), 0.0

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"],
                        unroll=cfg.scan_unroll)
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def encdec_forward(cfg, params, src, tokens, *, remat=False,
                   return_cache=False):
    """Teacher-forced forward. src (B,S_src,d); tokens (B,S_tgt)."""
    enc_out = _encode(cfg, params, src, remat=remat)
    h = constrain(params["embed"][tokens].astype(dtype_of(cfg)), "act")
    h, aux, caches = blk.scan_blocks(cfg, params["dec_blocks"], h,
                                     enc_out=enc_out,
                                     return_cache=return_cache, remat=remat)
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = constrain(hn @ params["lm_head"], "logits")
    return logits, aux, caches, enc_out


def encdec_loss(cfg, params, batch, *, remat=False):
    logits, aux, _, _ = encdec_forward(cfg, params, batch["src"],
                                       batch["tokens"], remat=remat)
    loss = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:]) + aux
    return loss, {"ce": loss}


def encdec_cache_init(cfg, batch: int, seq_len: int, src_len: int):
    return {
        "blocks": blk.stacked_cache_init(cfg, batch, seq_len,
                                         cross_len=src_len),
        "index": jnp.zeros((), jnp.int32),
    }


def encdec_prefill(cfg, params, src, bos_tokens, target_len: int):
    """Encode source + run decoder prefill on bos_tokens, return cache."""
    logits, _, caches, enc_out = encdec_forward(cfg, params, src, bos_tokens,
                                                return_cache=True)
    from repro.models.transformer import grow_cache
    S = bos_tokens.shape[1]
    cache = {"blocks": caches, "index": jnp.asarray(S, jnp.int32)}
    if target_len > S:
        cache = grow_cache(cache, target_len - S)
    return logits[:, -1], cache


def encdec_decode_step(cfg, params, cache, token):
    """One decoder token; cross K/V live in the cache (computed at prefill)."""
    index = cache["index"]
    h = constrain(params["embed"][token].astype(dtype_of(cfg)), "dec")
    h, new_blocks = blk.scan_blocks_decode(cfg, params["dec_blocks"], h,
                                           cache["blocks"], index)
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = hn[:, 0] @ params["lm_head"]
    return logits, {"blocks": new_blocks, "index": index + 1}
