"""Pure-JAX optimizers (no external deps): SGD, momentum, Adam, AdamW and
Adafactor. Adafactor's factored second moment is what lets the 398B/671B
configs fit v5e HBM (see DESIGN.md §5): state is O(rows + cols) per matrix
instead of O(rows * cols).

API:
    opt = make_optimizer(train_cfg)
    state = opt.init(params)
    params, state = opt.update(params, grads, state, step)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    name: str = ""


def _tree_map(f, *trees):
    return jax.tree.map(f, *trees)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return _tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                     grads), norm


# ---------------------------------------------------------------------- #
def sgd(cfg: TrainConfig) -> Optimizer:
    def init(params):
        return {}

    def update(params, grads, state, step, lr):
        new = _tree_map(lambda p, g: (p.astype(jnp.float32)
                                      - lr * g.astype(jnp.float32)
                                      ).astype(p.dtype), params, grads)
        return new, state
    return Optimizer(init, update, "sgd")


def momentum(cfg: TrainConfig) -> Optimizer:
    def init(params):
        return {"m": _tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(params, grads, state, step, lr):
        m = _tree_map(lambda m, g: cfg.beta1 * m + g.astype(jnp.float32),
                      state["m"], grads)
        new = _tree_map(lambda p, m: (p.astype(jnp.float32) - lr * m
                                      ).astype(p.dtype), params, m)
        return new, {"m": m}
    return Optimizer(init, update, "momentum")


def _adam_core(cfg: TrainConfig, decoupled_wd: float) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": _tree_map(z, params), "v": _tree_map(z, params)}

    def update(params, grads, state, step, lr):
        t = step.astype(jnp.float32) + 1.0
        b1, b2 = cfg.beta1, cfg.beta2
        m = _tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["m"], grads)
        v = _tree_map(lambda v, g: b2 * v + (1 - b2)
                      * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        mhat = _tree_map(lambda m: m / (1 - b1 ** t), m)
        vhat = _tree_map(lambda v: v / (1 - b2 ** t), v)

        def upd(p, mh, vh):
            step_ = lr * mh / (jnp.sqrt(vh) + cfg.eps)
            if decoupled_wd and p.ndim >= 2:     # no decay on norms/biases
                step_ = step_ + lr * decoupled_wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_).astype(p.dtype)

        new = _tree_map(upd, params, mhat, vhat)
        return new, {"m": m, "v": v}
    return Optimizer(init, update, "adam" if not decoupled_wd else "adamw")


def adam(cfg: TrainConfig) -> Optimizer:
    return _adam_core(cfg, 0.0)


def adamw(cfg: TrainConfig) -> Optimizer:
    return _adam_core(cfg, cfg.weight_decay)


# ---------------------------------------------------------------------- #
def adafactor(cfg: TrainConfig) -> Optimizer:
    """Factored second-moment (Shazeer & Stern 2018), no momentum,
    update clipping at 1.0, relative step off (we pass lr explicitly)."""
    eps1 = 1e-30

    def init(params):
        def per(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"s": _tree_map(per, params)}

    def update(params, grads, state, step, lr):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** -0.8

        def per(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps1
            if p.ndim >= 2:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps1)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                u = gf / jnp.sqrt(vhat + eps1)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = gf / jnp.sqrt(v + eps1)
                ns = {"v": v}
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, ns

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        out = [per(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new = tdef.unflatten([o[0] for o in out])
        ns = tdef.unflatten([o[1] for o in out])
        return new, {"s": ns}
    return Optimizer(init, update, "adafactor")


# ---------------------------------------------------------------------- #
_REGISTRY = {"sgd": sgd, "momentum": momentum, "adam": adam, "adamw": adamw,
             "adafactor": adafactor}


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer not in _REGISTRY:
        raise KeyError(f"unknown optimizer {cfg.optimizer}")
    return _REGISTRY[cfg.optimizer](cfg)
