"""Architecture registry: ``get(arch_id)``, ``reduced(cfg)`` smoke variants,
and the assigned arch x shape grid."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import SHAPES, ModelConfig, MoEConfig, SSMConfig

from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.mamba2_370m import CONFIG as _mamba2
from repro.configs.yi_34b import CONFIG as _yi
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen_moe
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.qwen2_5_32b import CONFIG as _qwen25
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in [
    _moonshot, _jamba, _mamba2, _yi, _seamless,
    _qwen_moe, _chameleon, _starcoder2, _qwen25, _deepseek,
]}


def get(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs() -> List[str]:
    return sorted(ARCHS)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512,
    <=4 experts, tiny vocab. Preserves the family's structural features
    (MoE routing, SSD scan, hybrid interleave, MLA, enc-dec, biases, norms)."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        first_dense_layers=1 if cfg.first_dense_layers else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        block_len=0,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_routed=4, top_k=2, d_ff_expert=128,
                              n_shared=min(cfg.moe.n_shared, 1))
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=32, head_dim=32, expand=2,
                              n_groups=1, chunk=32)
    if cfg.attn_layer_period:           # hybrid: 1 attn + 1 mamba
        kw["attn_layer_period"] = 2
        kw["attn_layer_offset"] = 0
        kw["moe_layer_period"] = 2 if cfg.moe is not None else 1
    if cfg.mla is not None:
        from repro.configs.base import MLAConfig
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
        kw["head_dim"] = 32
    if cfg.first_dense_layers:
        kw["n_layers"] = 3              # 1 unrolled dense + 2 scanned
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    if cfg.long_context_window:
        kw["long_context_window"] = 16
    return dataclasses.replace(cfg, **kw)


def optimized(cfg: ModelConfig, data_axis_size: int = 16) -> ModelConfig:
    """Production-recommended variant: group-local MoE dispatch aligned with
    the mesh's data axis (EXPERIMENTS.md §Perf — 7-66x lower collective term
    on MoE training). No-op for non-MoE architectures."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     dispatch_groups=data_axis_size))


def grid():
    """All assigned (arch x shape) pairs."""
    return [(a, s) for a in list_archs() for s in SHAPES]
