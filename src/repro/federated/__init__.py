from repro.federated.aggregation import (fedavg, fedavg_stacked,
                                         normalize_weights)
from repro.federated.client import ClientReport, local_train
from repro.federated.cohort import cohort_eval, cohort_train
from repro.federated.server import FeelServer, RoundLog
from repro.federated.simulation import averaged, run_experiment

__all__ = ["fedavg", "fedavg_stacked", "normalize_weights", "ClientReport",
           "local_train", "cohort_eval", "cohort_train", "FeelServer",
           "RoundLog", "averaged", "run_experiment"]
