"""Roofline-term derivation from a compiled dry-run artifact.

Three terms, in seconds (v5e constants from launch.mesh):

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = sum_ops factor(op) * output_bytes(op) / ICI_BW

``cost_analysis()`` on the SPMD-partitioned executable reports the per-chip
program, so no further division by chip count is applied (verified against
the analytic 6*N*D/chips for yi-34b in EXPERIMENTS.md §Roofline).

Collective bytes are not in cost_analysis: we parse the compiled (post-SPMD)
HLO and sum output bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops. Ring-model factors: all-reduce counts
2x (reduce-scatter + all-gather phases); everything else 1x; the (n-1)/n
ring correction (~0.94-0.99 on 16-256 participants) is folded into 1.
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective op type from post-SPMD HLO."""
    out: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped or "=" not in stripped:
            continue
        for op in _COLL_OPS:
            tok = f" {op}("
            tok_start = f" {op}-start("
            pos = stripped.find(tok)
            if pos < 0:
                pos = stripped.find(tok_start)
            if pos < 0:
                continue
            lhs = stripped[:pos]
            rhs_eq = lhs.find("=")
            shapes = _SHAPE_RE.findall(lhs[rhs_eq:])
            out[op] += sum(_shape_bytes(d, s) for d, s in shapes)
            break
    return out


def roofline_terms(flops: float, hbm_bytes: float,
                   coll: Dict[str, int]) -> Dict[str, float]:
    coll_s = sum(_FACTORS[op] * b for op, b in coll.items()) / ICI_BW
    return {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_s,
    }


def dominant(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


def model_flops(cfg, tokens: int, train: bool) -> float:
    """6*N*D (training) or 2*N*D (inference fwd) with N = active non-embedding
    params (MoE counts top_k + shared experts only)."""
    n = cfg.param_count(active_only=True) - cfg.vocab_size * cfg.d_model
    mult = 6.0 if train else 2.0
    return mult * n * tokens


def intensity_context(flops: float, hbm_bytes: float,
                      measured_s: float = 0.0) -> Dict:
    """Arithmetic-intensity context for a traced phase (repro.obs.report).

    From analytic flops/bytes estimates attached to a span, derive the
    roofline position against the v5e constants: intensity (FLOPs/byte),
    the ridge point (PEAK/HBM_BW), which side of the roof the phase sits
    on, the time floor implied by the roof, and — when a measured
    wall-time is supplied — the attained fraction of that floor."""
    assert flops >= 0 and hbm_bytes > 0
    ai = flops / hbm_bytes
    ridge = PEAK_FLOPS_BF16 / HBM_BW
    floor_s = max(flops / PEAK_FLOPS_BF16, hbm_bytes / HBM_BW)
    out = {"flops": flops, "hbm_bytes": hbm_bytes, "intensity": ai,
           "ridge": ridge,
           "bound": "compute" if ai >= ridge else "memory",
           "time_floor_s": floor_s}
    if measured_s > 0:
        out["attained_frac"] = floor_s / measured_s
    return out
