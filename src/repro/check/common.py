"""Shared plumbing for the contract checker (``python -m repro.check``).

A checker is a function ``(CheckContext) -> List[Violation]``; the
registry in ``repro.check.__init__`` runs them all. Source files are
parsed once into ``SourceFile`` records (path + text + AST + waivers)
and shared across the AST lints.

Waivers: a violation that is *intentional* (a documented contract
exception) is silenced by a ``# repro: allow(rule-name)`` comment on the
offending line or the line directly above it. Every waiver names the one
rule it silences — there is no blanket opt-out — so exceptions stay
greppable and reviewable.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract violation: ``rule`` is the checker's kebab-case id,
    ``path`` is repo-relative, ``line`` is 1-indexed."""
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


_WAIVER_RE = re.compile(r"#\s*repro:\s*allow\(([a-z0-9_-]+)\)")


def parse_waivers(text: str) -> Dict[int, Set[str]]:
    """line (1-indexed) -> set of rule names waived ON that line.

    A waiver comment covers its own line and the line below it, so both
    trailing comments and comment-above style work::

        x = jnp.float64(v)            # repro: allow(dtype-f64)

        # repro: allow(dtype-f64)
        x = jnp.float64(v)
    """
    waivers: Dict[int, Set[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        for m in _WAIVER_RE.finditer(line):
            waivers.setdefault(i, set()).add(m.group(1))
            waivers.setdefault(i + 1, set()).add(m.group(1))
    return waivers


@dataclasses.dataclass
class SourceFile:
    """One parsed python source file under the checked tree."""
    path: Path               # absolute
    rel: str                 # repo-relative, posix separators
    text: str
    tree: ast.Module
    waivers: Dict[int, Set[str]]

    @classmethod
    def parse(cls, path: Path, root: Path) -> "SourceFile":
        text = path.read_text()
        return cls(path=path, rel=path.relative_to(root).as_posix(),
                   text=text, tree=ast.parse(text, filename=str(path)),
                   waivers=parse_waivers(text))

    @classmethod
    def from_text(cls, text: str, rel: str = "<snippet>") -> "SourceFile":
        """Parse an in-memory snippet (the self-tests inject violations
        into synthetic sources through this)."""
        return cls(path=Path(rel), rel=rel, text=text,
                   tree=ast.parse(text), waivers=parse_waivers(text))

    def waived(self, rule: str, line: int) -> bool:
        return rule in self.waivers.get(line, ())


@dataclasses.dataclass
class CheckContext:
    """Everything a checker may need: the repo root, the parsed source
    set, and the test/benchmark trees for cross-referencing."""
    repo_root: Path
    sources: List[SourceFile]

    @property
    def src_root(self) -> Path:
        return self.repo_root / "src" / "repro"

    @property
    def tests_root(self) -> Path:
        return self.repo_root / "tests"

    def source(self, rel: str) -> Optional[SourceFile]:
        for s in self.sources:
            if s.rel == rel:
                return s
        return None


def load_sources(repo_root: Path,
                 subdir: str = "src/repro") -> List[SourceFile]:
    """Parse every ``.py`` under ``repo_root/subdir`` (sorted, stable)."""
    base = repo_root / subdir
    return [SourceFile.parse(p, repo_root)
            for p in sorted(base.rglob("*.py"))]


def make_context(repo_root: Path) -> CheckContext:
    return CheckContext(repo_root=Path(repo_root),
                        sources=load_sources(Path(repo_root)))


def iter_functions(tree: ast.AST):
    """Yield every (async) function definition, including nested ones."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def collect_violations(checkers: Iterable, ctx: CheckContext
                       ) -> List[Violation]:
    out: List[Violation] = []
    for chk in checkers:
        out.extend(chk(ctx))
    return out
