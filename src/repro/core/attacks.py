"""Threat-model plane: pluggable attack scenarios (paper §III-B, §V, §VI).

The paper's evaluation is entirely about how DQS behaves under data
poisoning (§V, Fig. 2-3), and its §VI future work plus the related
scheduling literature (arXiv:2102.09491 — unreliable/noisy data;
arXiv:2004.00490 — importance/channel awareness with stale clients) frame
the wider scenario families the reputation term is supposed to absorb.
The seed hard-coded ONE of them: a full label flip on a single
``(source, target)`` pair, with model poisoning bolted on as a scalar
flag. This module turns the threat model into a first-class axis:

    AttackScenario — a named bundle of four orthogonal components:
        data     DataAttack        poisons a malicious UE's raw data at
                                   partition time (label flips with pair x
                                   fraction x multi-pair, feature noise;
                                   token-space twins TokenFlip/TokenNoise
                                   for the LM task)
        model    ModelAttack       manipulates the *uploaded update*
                                   (sign-flip, boosted, free-rider,
                                   stale replay)
        report   ReportAttack      inflates the self-reported accuracy
                                   (the beta1 term's target)
        schedule MaliciousSchedule WHEN malicious UEs act: always,
                                   intermittent duty cycles, or a
                                   colluding round-robin rotation where
                                   subsets take turns so each member's
                                   reputation decays slowly

Every component has a host numpy oracle — the per-client path, used by
the ``engine="loop"`` oracle and by ``partition`` — AND a batched jnp
twin that applies to a stacked cohort through a malicious-row mask in ONE
masked ``jax.tree.map`` / ``jnp.where`` (no per-malicious-client
dispatch; ``FeelServer._apply_attacks`` routes through it, and the old
``.at[i].set`` loop survives as the pinned parity oracle).

Randomness follows DESIGN.md §2: every draw comes from a host numpy
``Generator`` (the stream of record) and the batched twins are
deterministic functions of those draws — draws are quantized to float32
so both planes sort/compare identical values, which is what makes oracle
parity exact. Scenario registry + metrics helpers (attack success rate,
recovery rounds) live at the bottom.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Pair = Tuple[int, int]


# ---------------------------------------------------------------------- #
# Data attacks (partition-time, raw client data)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class LabelFlip:
    """Label-flipping (paper §III-B.1), generalized: multiple
    ``(source, target)`` pairs and a per-class flip fraction.

    ``flip_fraction < 1`` flips exactly ``round(flip_fraction * n_source)``
    of each source class's samples — the ones with the smallest uniform
    draws (stable ranking), so the host oracle and the jnp twin pick the
    identical set from the same draws. Pairs are resolved against the
    ORIGINAL labels, so chained pairs like (6,2),(2,8) never cascade.
    """
    pairs: Tuple[Pair, ...]
    flip_fraction: float = 1.0

    def __post_init__(self):
        pairs = tuple((int(s), int(t)) for s, t in self.pairs)
        object.__setattr__(self, "pairs", pairs)
        sources = [s for s, _ in pairs]
        assert len(set(sources)) == len(sources), \
            f"duplicate source classes in {pairs}"
        assert 0.0 < self.flip_fraction <= 1.0, self.flip_fraction

    # -- host oracle ---------------------------------------------------- #
    def draw(self, rng: np.random.Generator, x: np.ndarray,
             y: np.ndarray) -> Optional[np.ndarray]:
        """Per-sample float32 uniforms; None (no stream consumed) for a
        full flip — keeps the legacy ``flip_fraction=1`` RNG stream
        identical to the seed's LabelFlipAttack."""
        if self.flip_fraction >= 1.0:
            return None
        return rng.random(len(y), dtype=np.float32)

    def _n_flip(self, n_source: int) -> int:
        return int(np.round(self.flip_fraction * float(n_source)))

    def apply_host(self, x: np.ndarray, y: np.ndarray,
                   u: Optional[np.ndarray]):
        out = y.copy()
        for s, t in self.pairs:
            src = np.flatnonzero(y == s)          # original labels
            if u is not None:
                n = self._n_flip(src.size)
                if n < src.size:
                    order = np.argsort(u[src], kind="stable")
                    src = src[order[:n]]
            out[src] = t
        return x, out

    def poison(self, x, y, rng):
        """Partition entry point: draw + apply in one call."""
        return self.apply_host(x, y, self.draw(rng, x, y))

    # -- batched jnp twin ----------------------------------------------- #
    def apply_rows(self, x, y, valid, mal, u=None):
        """Stacked twin over (K, S) padded client arrays.

        x (K, S, D); y (K, S) int; valid (K, S) {0,1} real-sample mask;
        mal (K,) bool malicious rows; u (K, S) float32 draws (row k =
        ``draw`` output for client k, zero-padded). One ``jnp.where`` per
        flip pair — no per-client dispatch.
        """
        y = jnp.asarray(y)
        y0 = y
        mal_col = jnp.asarray(mal, bool)[:, None]
        valid_b = jnp.asarray(valid) > 0
        for s, t in self.pairs:
            is_src = (y0 == s) & valid_b
            if u is None:
                flip = is_src
            else:
                # host-computed round() table keeps the f64 threshold
                # arithmetic identical between the planes
                S = y.shape[-1]
                table = jnp.asarray(np.round(
                    self.flip_fraction * np.arange(S + 1, dtype=np.float64)
                ).astype(np.int32))
                n_flip = table[is_src.sum(-1)]
                key = jnp.where(is_src, jnp.asarray(u), jnp.inf)
                order = jnp.argsort(key, axis=-1, stable=True)
                rank = jnp.argsort(order, axis=-1, stable=True)
                flip = is_src & (rank < n_flip[:, None])
            y = jnp.where(mal_col & flip, t, y)
        return jnp.asarray(x), y


@dataclasses.dataclass(frozen=True)
class FeatureNoise:
    """Unreliable-data scenario (cf. arXiv:2102.09491): additive Gaussian
    pixel noise on a malicious/faulty UE's features; labels untouched, so
    the UE's reported histogram — and Eq. 2 diversity — stay truthful and
    only the Eq. 1 test-set gap can catch it."""
    sigma: float = 0.8
    clip: Tuple[float, float] = (0.0, 1.0)   # the data domain of x

    def draw(self, rng: np.random.Generator, x: np.ndarray,
             y: np.ndarray) -> np.ndarray:
        return rng.standard_normal(x.shape).astype(np.float32)

    def apply_host(self, x, y, eps):
        noisy = np.clip(x + np.float32(self.sigma) * eps,
                        *self.clip).astype(np.float32)
        return noisy, y

    def poison(self, x, y, rng):
        return self.apply_host(x, y, self.draw(rng, x, y))

    def apply_rows(self, x, y, valid, mal, eps):
        """Stacked twin: noise lands only on malicious rows' REAL samples
        (padding stays exactly zero — the cohort engine's contract)."""
        x = jnp.asarray(x)
        m = (jnp.asarray(mal, bool)[:, None] & (jnp.asarray(valid) > 0)
             )[..., None]
        noisy = jnp.clip(x + jnp.float32(self.sigma) * jnp.asarray(eps),
                         *self.clip)
        return jnp.where(m, noisy, x), jnp.asarray(y)


@dataclasses.dataclass(frozen=True)
class TokenFlip:
    """Token substitution — the label-flip analogue for LM token streams
    (task="lm_tiny"): every occurrence of a source TOKEN in a malicious
    UE's windows is rewritten to the target token, corrupting the bigram
    statistics the model has to learn. ``flip_fraction < 1`` substitutes
    exactly ``round(fraction * n_source)`` occurrences — the ones with the
    smallest uniform draws (stable ranking), mirroring ``LabelFlip``'s
    selection rule at token granularity. Pairs resolve against the
    ORIGINAL tokens, so chained pairs never cascade."""
    pairs: Tuple[Pair, ...]
    flip_fraction: float = 1.0

    def __post_init__(self):
        pairs = tuple((int(s), int(t)) for s, t in self.pairs)
        object.__setattr__(self, "pairs", pairs)
        sources = [s for s, _ in pairs]
        assert len(set(sources)) == len(sources), \
            f"duplicate source tokens in {pairs}"
        assert 0.0 < self.flip_fraction <= 1.0, self.flip_fraction

    def poison_tokens(self, tokens: np.ndarray,
                      rng: np.random.Generator) -> np.ndarray:
        """tokens (N, seq) int -> substituted copy (same shape/dtype)."""
        flat = tokens.reshape(-1)
        u = (rng.random(flat.size, dtype=np.float32)
             if self.flip_fraction < 1.0 else None)
        out = flat.copy()
        for s, t in self.pairs:
            src = np.flatnonzero(flat == s)          # original tokens
            if u is not None:
                n = int(np.round(self.flip_fraction * float(src.size)))
                if n < src.size:
                    order = np.argsort(u[src], kind="stable")
                    src = src[order[:n]]
            out[src] = t
        return out.reshape(tokens.shape)


@dataclasses.dataclass(frozen=True)
class TokenNoise:
    """Unreliable-text scenario: each token of a malicious/faulty UE's
    windows is independently resampled uniformly over the vocabulary with
    probability ``rate`` — the LM twin of ``FeatureNoise`` (labels, i.e.
    window domain ids, untouched)."""
    rate: float = 0.3
    vocab: int = 64

    def poison_tokens(self, tokens: np.ndarray,
                      rng: np.random.Generator) -> np.ndarray:
        u = rng.random(tokens.shape, dtype=np.float32)
        repl = rng.integers(0, self.vocab,
                            size=tokens.shape).astype(tokens.dtype)
        return np.where(u < np.float32(self.rate), repl, tokens)


DataAttack = Union[LabelFlip, FeatureNoise, TokenFlip, TokenNoise]


def poison_dataset(attack, ds, rng: np.random.Generator,
                   context: str = ""):
    """Dataset-dispatching poison entry point (used by
    ``data.partition.partition``): token-space attacks rewrite a
    ``TokenDataset``'s windows, feature/label attacks rewrite a
    ``Dataset``'s ``(x, y)`` — a mismatched (attack, dataset) pairing
    fails loudly instead of silently no-opping.

    ``context`` names the offending (task, scenario) pairing in the
    failure message — a sweep crossing every scenario with every task
    hits the mismatch far from where it was configured, and "got
    Dataset" alone does not say which sweep cell to fix.
    """
    where = f" [{context}]" if context else ""
    if hasattr(attack, "poison_tokens"):
        assert hasattr(ds, "tokens"), (
            f"{type(attack).__name__} is a token-space attack and needs a "
            f"token dataset, got {type(ds).__name__}{where} (use LabelFlip/"
            "FeatureNoise for feature/label data)")
        return type(ds)(attack.poison_tokens(ds.tokens, rng), ds.y.copy())
    assert hasattr(ds, "x"), (
        f"{type(attack).__name__} poisons (x, y) arrays and needs a "
        f"feature dataset, got {type(ds).__name__}{where} (use TokenFlip/"
        "TokenNoise for token data)")
    return type(ds)(*attack.poison(ds.x, ds.y, rng))


# ---------------------------------------------------------------------- #
# Model attacks (update-time, uploaded parameters)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ModelAttack:
    """Update manipulation ``Omega' = ref + scale * (Omega - g)``.

    scale = -1 — sign-flip (gradient-ascent) [Bagdasaryan et al.];
    |scale| > 1 — boosted/backdoor-style amplification;
    scale = 0 — free-rider: the UE uploads ``ref`` untouched (it never
        trained). ``staleness = 0`` makes ref the current global model
        (zero update); ``staleness = s > 0`` replays the global model
        from s rounds earlier (stale free-rider) — the server keeps the
        short history (``FeelServer._attack_ref_params``).
    """
    scale: float = -1.0
    staleness: int = 0

    def apply_loop(self, global_params, local_params, ref_params=None):
        """Per-client sequential twin (the loop engine's path).

        Operates on device parameter pytrees — deliberately NOT named
        ``*_host``/``*_oracle``: the host-purity contract (DESIGN.md
        §11) reserves those suffixes for numpy-only code."""
        ref = global_params if ref_params is None else ref_params
        return jax.tree.map(
            lambda r, g, l: r + self.scale * (l - g),
            ref, global_params, local_params)

    def apply_stacked(self, stacked, global_params, mal, ref_params=None):
        """Batched twin: ONE masked ``jax.tree.map`` over the stacked
        cohort — malicious rows get the manipulated update, honest rows
        pass through; no per-client dispatch."""
        ref = global_params if ref_params is None else ref_params
        m = jnp.asarray(np.asarray(mal, bool))

        def leaf(l, g, r):
            mm = m.reshape(m.shape + (1,) * (l.ndim - 1))
            return jnp.where(mm, r + self.scale * (l - g), l)

        return jax.tree.map(leaf, stacked, global_params, ref)


@dataclasses.dataclass(frozen=True)
class ReportAttack:
    """Dishonest accuracy reporting: malicious UEs add ``boost`` to their
    self-reported local accuracy (clipped to 1) — the quantity Eq. 1's
    beta1 term treats as suspect."""
    boost: float = 0.3

    def apply(self, acc_local: np.ndarray, mal: np.ndarray) -> np.ndarray:
        return np.where(mal, np.minimum(acc_local + self.boost, 1.0),
                        acc_local)


# ---------------------------------------------------------------------- #
# Activity schedules (WHEN malicious UEs act)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class MaliciousSchedule:
    """Round-dependent activity of the malicious set.

    always       — every malicious UE attacks every round.
    intermittent — all attack only when ``t % period < duty`` (on-off
                   duty cycle: reputation partially recovers between
                   bursts).
    roundrobin   — colluding rotation: the malicious set splits into
                   ``period`` groups by rank and group ``t % period``
                   attacks in round t, so each member poisons only every
                   period-th round it is scheduled — the collusion
                   pattern that slows Eq. 1's separation the most.

    Applies to every component: model/report attacks are gated directly
    per round, and data attacks — poisoned once at partition time — are
    gated through the clean+poisoned twin-array gather (the server keeps
    both copies of an attacked client's data resident and selects per
    round; ``FeelServer._cohort_parts`` / the loop oracle's clean-data
    fallback), so intermittent/colluding data poisoning no longer needs a
    per-round re-partition.
    """
    kind: str = "always"      # always | intermittent | roundrobin
    period: int = 1
    duty: int = 1

    def __post_init__(self):
        assert self.kind in ("always", "intermittent", "roundrobin"), \
            self.kind
        assert self.period >= 1 and 1 <= self.duty <= self.period

    def active(self, t: int, mal_mask: np.ndarray,
               mal_rank: np.ndarray) -> np.ndarray:
        """(K,) bool — the malicious UEs acting in round ``t``.

        mal_mask — (K,) bool malicious flags; mal_rank — (K,) rank of
        each UE within the malicious set (-1 for honest UEs).
        """
        if self.kind == "always":
            return mal_mask
        if self.kind == "intermittent":
            if t % self.period < self.duty:
                return mal_mask
            return np.zeros_like(mal_mask)
        return mal_mask & (mal_rank % self.period == t % self.period)


ALWAYS = MaliciousSchedule()


# ---------------------------------------------------------------------- #
# Scenario: the composite threat model
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AttackScenario:
    """A named threat model: data/model/report components + activity
    schedule. Any subset may be None; all-None is the benign control
    (malicious flags are not even set — matching ``no_attack=True``).

    ``watch`` is the (source, target) pair the metrics track
    (``source_acc``, attack success rate); it defaults to the data
    attack's first flip pair and may be set explicitly for scenarios
    without one (e.g. a benign control curve over the would-be pair).
    """
    name: str
    data: Optional[DataAttack] = None
    model: Optional[ModelAttack] = None
    report: Optional[ReportAttack] = None
    schedule: MaliciousSchedule = ALWAYS
    watch: Optional[Pair] = None

    def __post_init__(self):
        if self.watch is None and isinstance(self.data,
                                             (LabelFlip, TokenFlip)):
            object.__setattr__(self, "watch", self.data.pairs[0])

    @property
    def benign(self) -> bool:
        return (self.data is None and self.model is None
                and self.report is None)

    def data_key(self):
        """Partition-cache identity: runs whose partitions are identical
        (same labels/features AND same malicious flags) share this key —
        the sweep builds one partition + device layout per key."""
        if self.benign:
            return "none"
        if self.data is None:
            return "mal_only"      # clean data, malicious flags set
        return self.data           # frozen dataclass -> hashable


# ---------------------------------------------------------------------- #
# Registry + builders
# ---------------------------------------------------------------------- #
SCENARIOS: Dict[str, AttackScenario] = {}


def register(scenario: AttackScenario) -> AttackScenario:
    assert scenario.name not in SCENARIOS, \
        f"scenario {scenario.name!r} already registered"
    SCENARIOS[scenario.name] = scenario
    return scenario


def label_flip(source: int, target: int, flip_fraction: float = 1.0,
               name: Optional[str] = None) -> AttackScenario:
    if name is None:
        name = f"flip_{source}to{target}"
        if flip_fraction < 1.0:
            name += f"_f{int(round(flip_fraction * 100))}"
    return AttackScenario(name, data=LabelFlip(((source, target),),
                                               flip_fraction))


def multi_flip(pairs, flip_fraction: float = 1.0,
               name: Optional[str] = None) -> AttackScenario:
    pairs = tuple(tuple(p) for p in pairs)
    name = name or ("multi_flip_" + "_".join(f"{s}to{t}"
                                             for s, t in pairs))
    return AttackScenario(name, data=LabelFlip(pairs, flip_fraction))


def feature_noise(sigma: float = 0.8,
                  name: Optional[str] = None) -> AttackScenario:
    return AttackScenario(name or f"noise_{sigma:g}",
                          data=FeatureNoise(sigma))


def token_flip(source: int, target: int, flip_fraction: float = 1.0,
               name: Optional[str] = None) -> AttackScenario:
    """LM data attack (task="lm_tiny"): substitute the source TOKEN with
    the target token in malicious UEs' windows (watch pair = the token
    pair, so attack_success reads "fraction of watched source-token
    positions predicted as the target token")."""
    if name is None:
        name = f"token_flip_{source}to{target}"
        if flip_fraction < 1.0:
            name += f"_f{int(round(flip_fraction * 100))}"
    return AttackScenario(name, data=TokenFlip(((source, target),),
                                               flip_fraction))


def token_noise(rate: float = 0.3, vocab: int = 64,
                name: Optional[str] = None) -> AttackScenario:
    return AttackScenario(name or f"token_noise_{rate:g}",
                          data=TokenNoise(rate, vocab))


def free_rider(staleness: int = 0,
               name: Optional[str] = None) -> AttackScenario:
    name = name or ("free_rider" if staleness == 0
                    else f"stale_rider_{staleness}")
    return AttackScenario(name, model=ModelAttack(0.0, staleness))


def model_poison(scale: float,
                 name: Optional[str] = None) -> AttackScenario:
    name = name or ("sign_flip" if scale == -1.0 else f"boost_{scale:g}")
    return AttackScenario(name, model=ModelAttack(scale))


def lie_boost(boost: float = 0.3, data: Optional[DataAttack] = None,
              name: Optional[str] = None) -> AttackScenario:
    return AttackScenario(name or f"lie_{boost:g}", data=data,
                          report=ReportAttack(boost))


def intermittent(base: AttackScenario, period: int, duty: int = 1,
                 name: Optional[str] = None) -> AttackScenario:
    """Wrap a scenario's model/report components in an on-off duty cycle."""
    return dataclasses.replace(
        base, name=name or f"{base.name}_int{period}d{duty}",
        schedule=MaliciousSchedule("intermittent", period, duty))


def colluding(base: AttackScenario, period: int,
              name: Optional[str] = None) -> AttackScenario:
    """Wrap a scenario in a colluding round-robin rotation."""
    return dataclasses.replace(
        base, name=name or f"{base.name}_rr{period}",
        schedule=MaliciousSchedule("roundrobin", period, period))


NO_ATTACK = register(AttackScenario("none"))
register(label_flip(6, 2))                              # easy pair, §V
register(label_flip(8, 4, flip_fraction=0.5))           # partial flip
register(multi_flip(((6, 2), (8, 4))))                  # both §V pairs
register(feature_noise(0.8))
register(free_rider(0))                                 # zero update
register(free_rider(2))                                 # stale replay
register(model_poison(-1.0))                            # sign flip
register(model_poison(3.0))                             # boosted
register(lie_boost(0.3, data=LabelFlip(((8, 4),)),
                   name="lying_flip_8to4"))
register(intermittent(model_poison(-1.0), period=2))
register(colluding(model_poison(-1.0), period=2))
register(token_flip(1, 5))                              # LM data attack
register(token_noise(0.3))
register(intermittent(label_flip(6, 2), period=2,
                      name="flip_6to2_int2"))           # twin-array gather


def as_scenario(spec) -> AttackScenario:
    """Coerce a scenario spec: an AttackScenario passes through, a str
    looks up the registry, and a legacy ``(source, target)`` pair becomes
    the full label flip the seed hard-coded (back-compat shim for
    ``run_sweep(attack_pairs=...)`` callers)."""
    if isinstance(spec, AttackScenario):
        return spec
    if isinstance(spec, str):
        return SCENARIOS[spec]
    if isinstance(spec, (tuple, list)) and len(spec) == 2:
        return label_flip(int(spec[0]), int(spec[1]))
    raise TypeError(f"not an attack scenario spec: {spec!r}")


def legacy_scenario(attack_pair: Pair, no_attack: bool = False,
                    model_poison_scale: Optional[float] = None,
                    lie_boost_val: float = 0.0) -> AttackScenario:
    """The seed's knob set as one scenario. Contract (regression-tested in
    tests/test_attacks.py):

    - ``no_attack=True`` wins over everything: no data attack, no model
      poisoning, no lie_boost, malicious flags not set;
    - otherwise ``model_poison_scale`` REPLACES the label-flip data attack
      (malicious UEs keep clean data and poison their updates instead);
    - ``lie_boost`` composes with whichever attack is active;
    - the metrics always watch ``attack_pair`` (even for the benign
      control, so control curves still report source_acc).
    """
    pair = (int(attack_pair[0]), int(attack_pair[1]))
    if no_attack:
        return AttackScenario(f"none_watch_{pair[0]}to{pair[1]}",
                              watch=pair)
    data = model = None
    if model_poison_scale is not None:
        model = ModelAttack(scale=float(model_poison_scale))
    else:
        data = LabelFlip((pair,))
    report = ReportAttack(lie_boost_val) if lie_boost_val else None
    tag = (f"mp_{model_poison_scale:g}" if model_poison_scale is not None
           else "flip")
    if lie_boost_val:
        tag += f"_lie{lie_boost_val:g}"
    return AttackScenario(f"legacy_{tag}_{pair[0]}to{pair[1]}",
                          data=data, model=model, report=report,
                          watch=pair)


# ---------------------------------------------------------------------- #
# Scenario metrics
# ---------------------------------------------------------------------- #
def recovery_rounds(attack_success, threshold: float = 0.5) -> int:
    """Rounds until the attack stays defeated: ``1 + t_last`` where
    ``t_last`` is the last round whose attack success rate is >=
    ``threshold``; 0 if the attack never reached the threshold; -1 when
    the metric is undefined (no watched source->target pair). A return
    EQUAL to ``len(attack_success)`` means the final round was still at
    or above the threshold — the attack was NOT recovered from within
    the observed horizon (no later round exists to witness recovery);
    compare against the curve length before reading it as a recovery
    time."""
    a = np.asarray(attack_success, float)
    if a.size == 0 or not np.isfinite(a).any():
        return -1
    above = np.flatnonzero(np.nan_to_num(a, nan=-np.inf) >= threshold)
    return 0 if above.size == 0 else int(above[-1]) + 1


def reputation_gap(reputations: np.ndarray, mal_mask: np.ndarray) -> float:
    """Honest-vs-malicious reputation separation: mean honest reputation
    minus mean malicious reputation (NaN when either set is empty)."""
    mal_mask = np.asarray(mal_mask, bool)
    if not mal_mask.any() or mal_mask.all():
        return float("nan")
    return float(np.mean(reputations[~mal_mask])
                 - np.mean(reputations[mal_mask]))
