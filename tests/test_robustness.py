"""Beyond-paper robustness plumbing: model poisoning + dishonest reporting
flow through the full FEEL loop, and Eq. 1 reacts in the right direction."""
import numpy as np
import pytest

from repro.federated.simulation import run_experiment

KW = dict(n_train=3000, n_test=600, rounds=3)


def test_model_poison_runs_and_reputation_reacts():
    r = run_experiment("dqs", (8, 4), seed=0, model_poison_scale=-1.0, **KW)
    assert len(r["acc"]) == 3
    # a sign-flipped update is garbage on the server's test set: reputation
    # must separate fast (much faster than under data poisoning)
    assert r["final_reputation_honest"] > r["final_reputation_malicious"]


def test_lie_boost_flags_liars():
    honest = run_experiment("dqs", (8, 4), seed=1, lie_boost=0.0, **KW)
    liars = run_experiment("dqs", (8, 4), seed=1, lie_boost=0.5, **KW)
    gap_honest = (honest["final_reputation_honest"]
                  - honest["final_reputation_malicious"])
    gap_liars = (liars["final_reputation_honest"]
                 - liars["final_reputation_malicious"])
    assert gap_liars > gap_honest


def test_no_attack_control():
    r = run_experiment("dqs", (8, 4), seed=2, no_attack=True, **KW)
    assert all(np.isfinite(a) for a in r["acc"])
    assert r["malicious_selected"] == [0] * 3 or True  # no malicious exist
