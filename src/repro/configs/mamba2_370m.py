"""mamba2-370m — attention-free SSD state-space model [arXiv:2405.21060].

48L, d_model 1024, no attention / no MLP (Mamba2 blocks only, expand=2 so
d_inner=2048, head_dim 64 -> 32 heads), ssm_state N=128, vocab 50280."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,                      # unused (attention-free)
    n_kv_heads=16,
    d_ff=0,
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, chunk=256),
    citation="[arXiv:2405.21060]",
)
