"""Mixture-of-experts MLP with sort-based dropless-with-capacity dispatch.

Tokens are argsorted by assigned expert, gathered into a dense ``(E, C, d)``
buffer (capacity ``C = top_k * T * cf / E``) and processed with grouped
einsums, so compiled FLOPs are proportional to *active* parameters — unlike a
dense all-experts formulation. Overflowing tokens are dropped (GShard-style).
Shared experts (Qwen2-MoE / DeepSeek-V3) are a single fused SwiGLU of width
``n_shared * d_ff_expert`` applied to every token.

The expert dimension E shards over the ``model`` mesh axis (and over
``data``x``model`` for the 256-expert DeepSeek config); GSPMD inserts the
dispatch all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dtype_of, swiglu_apply, swiglu_init
from repro.sharding.ctx import constrain


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_init(key, cfg, d_model=None):
    m = cfg.moe
    d = d_model or cfg.d_model
    f = m.d_ff_expert
    E = m.n_routed
    ks = jax.random.split(key, 5)
    dt = dtype_of(cfg)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wg": dense_init(ks[1], (E, d, f), dt),
        "wu": dense_init(ks[2], (E, d, f), dt),
        "wd": dense_init(ks[3], (E, f, d), dt, fan_in=f),
    }
    if m.n_shared:
        p["shared"] = swiglu_init(ks[4], d, m.n_shared * f, dt)
    return p


def capacity(T: int, cfg) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * T * m.top_k / m.n_routed)
    return max(_round_up(c, 8), 8)


def moe_apply(cfg, p, x):
    """x (..., d) -> (y (..., d), aux_loss scalar)."""
    m = cfg.moe
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    G = m.dispatch_groups
    if G > 1 and T % G == 0 and T // G >= m.top_k:
        y, aux = _moe_grouped(cfg, p, x2.reshape(G, T // G, d))
        return y.reshape(orig_shape), aux
    E, K = m.n_routed, m.top_k
    C = capacity(T, cfg)

    logits = (x2.astype(jnp.float32) @ p["router"])               # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                           # (T,K)
    if m.normalize_gates:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch/GShard) ----
    me = probs.mean(0)                                            # (E,)
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    # ---- sort-based dispatch ----
    flat_e = idx.reshape(-1)                                      # (T*K,)
    order = jnp.argsort(flat_e)                                   # stable
    se = flat_e[order]
    tok = order // K
    counts = jnp.zeros(E, jnp.int32).at[flat_e].add(1)
    offs = jnp.cumsum(counts) - counts                            # exclusive
    pos_in_e = jnp.arange(T * K) - offs[se]
    valid = pos_in_e < C
    dest = jnp.where(valid, se * C + pos_in_e, E * C)             # sentinel row

    gathered = constrain(x2[tok], "moe_gather")                   # (T*K, d)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(gathered)
    # hillclimb lever (no-op without an active sharding ctx): pin the dispatch
    # buffer to the expert layout so GSPMD routes tokens with all-to-all
    # instead of replicating the scatter (see EXPERIMENTS.md §Perf)
    buf = constrain(buf[: E * C].reshape(E, C, d), "moe_disp")

    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
         * jnp.einsum("ecd,edf->ecf", buf, p["wu"]))
    h = constrain(h, "moe_hidden")
    y = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(E * C, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], 0)       # sentinel

    w = gate.reshape(-1)[order].astype(x.dtype)
    back = constrain(y[dest], "moe_gather")                       # (T*K, d)
    out = jnp.zeros((T, d), x.dtype).at[tok].add(back * w[:, None])

    if m.n_shared:
        out = out + swiglu_apply(p["shared"], x2)
    return out.reshape(orig_shape), aux


def _moe_grouped(cfg, p, x3):
    """Group-local dispatch (§Perf iteration 3). x3 (G, T, d).

    Sort/scatter are per-group (G aligns with the data axis, so they never
    cross shards); the only cross-shard movement is resharding the dense
    (G, E, C, d) buffer to the expert layout before the grouped GEMM —
    an all-to-all, which is the textbook MoE dispatch pattern.
    """
    m = cfg.moe
    G, T, d = x3.shape
    E, K = m.n_routed, m.top_k
    C = capacity(T, cfg)

    logits = x3.astype(jnp.float32) @ p["router"]                 # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                           # (G,T,K)
    if m.normalize_gates:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    me = probs.mean((0, 1))
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (G * T * K)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    flat_e = idx.reshape(G, T * K)
    order = jnp.argsort(flat_e, axis=1)                           # per group
    se = jnp.take_along_axis(flat_e, order, 1)
    tok = order // K                                              # (G,T*K)
    counts = jax.vmap(
        lambda fe: jnp.zeros(E, jnp.int32).at[fe].add(1))(flat_e)
    offs = jnp.cumsum(counts, 1) - counts                         # (G,E)
    pos = jnp.arange(T * K)[None] - jnp.take_along_axis(offs, se, 1)
    valid = pos < C
    dest = jnp.where(valid, se * C + pos, E * C)                  # (G,T*K)

    gathered = constrain(jnp.take_along_axis(x3, tok[..., None], 1),
                         "moe_local")                             # (G,T*K,d)
    buf = jax.vmap(
        lambda dst, g: jnp.zeros((E * C + 1, d), x3.dtype).at[dst].set(g)
    )(dest, gathered)
    # scatter output stays in the group-local layout; the switch to the
    # expert layout below is then a standalone all-to-all reshard
    buf = constrain(buf, "moe_local")
    buf = buf[:, : E * C].reshape(G, E, C, d)
    # two single-axis reshards (XLA lowers each to one all-to-all; a combined
    # two-axis move degenerates to replication — see §Perf iteration log)
    buf = constrain(buf, "moe_disp4a")    # model: d -> E
    buf = constrain(buf, "moe_disp4")     # data: G -> E

    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
         * jnp.einsum("gecd,edf->gecf", buf, p["wu"]))
    h = constrain(h, "moe_hidden4")
    y = constrain(jnp.einsum("gecf,efd->gecd", h, p["wd"]), "moe_out4")
    # reshard back to the group-local layout before the local un-permute
    y = constrain(y, "moe_disp4a")
    y = constrain(y.reshape(G, E * C, d), "moe_local")
    y = jnp.concatenate([y, jnp.zeros((G, 1, d), y.dtype)], 1)    # sentinel

    back = constrain(jnp.take_along_axis(y, dest[..., None], 1),
                     "moe_local")                                 # (G,T*K,d)
    w = jnp.take_along_axis(gate.reshape(G, T * K), order, 1).astype(x3.dtype)
    out = jax.vmap(
        lambda t, b: jnp.zeros((T, d), x3.dtype).at[t].add(b)
    )(tok, back * w[..., None])

    if m.n_shared:
        out = out + swiglu_apply(p["shared"], x3)
    return out, aux
