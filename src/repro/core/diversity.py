"""Dataset diversity evaluation (paper §III-B.3, Eq. 2).

``I_k = sum_i gamma_i * v_i`` over normalised metrics
i in {elements diversity, dataset size, age}. For classification the elements
diversity is the Gini-Simpson index over label frequencies (paper §V-B.1,
following [10] arXiv:2102.09491).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def gini_simpson(labels: np.ndarray, n_classes: int) -> float:
    """1 - sum p_c^2; 0 for a single-class set, (C-1)/C for uniform."""
    if labels.size == 0:
        return 0.0
    counts = np.bincount(labels.astype(int), minlength=n_classes)
    p = counts / counts.sum()
    return float(1.0 - np.sum(p * p))


def normalize(values: np.ndarray) -> np.ndarray:
    """Min-max normalise a metric across UEs to [0, 1]."""
    values = np.asarray(values, float)
    lo, hi = values.min(), values.max()
    if hi - lo < 1e-12:
        return np.ones_like(values)
    return (values - lo) / (hi - lo)


def diversity_index(element_diversity: np.ndarray,
                    dataset_sizes: np.ndarray,
                    ages: np.ndarray,
                    gamma: Sequence[float]) -> np.ndarray:
    """Eq. 2 across all K UEs. ``ages`` = rounds since last participation
    (higher -> staler -> more valuable to refresh)."""
    v = np.stack([
        normalize(element_diversity),
        normalize(dataset_sizes),
        normalize(ages),
    ])
    g = np.asarray(gamma, float)[:, None]
    return (g * v).sum(0)
