"""Family-dispatching public model API.

    init(cfg, key)                     -> params
    loss(cfg, params, batch)           -> (loss, metrics)
    prefill(cfg, params, batch)        -> (last logits, cache)
    decode_step(cfg, params, cache, t) -> (logits, cache)
    cache_init(cfg, batch, seq_len)    -> decode cache
    input_specs(cfg, shape)            -> dict of ShapeDtypeStruct model inputs
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec as ed
from repro.models import transformer as tf


def _is_encdec(cfg):
    return cfg.is_encoder_decoder


def init(cfg: ModelConfig, key):
    if _is_encdec(cfg):
        return ed.encdec_init(key, cfg)
    return tf.lm_init(key, cfg)


def loss(cfg, params, batch, *, remat=False):
    if _is_encdec(cfg):
        return ed.encdec_loss(cfg, params, batch, remat=remat)
    return tf.lm_loss(cfg, params, batch, remat=remat)


def loss_masked(cfg, params, batch, *, remat=False):
    """Masked-batch twin of ``loss`` — the federated cohort contract
    (batch["m"] {0,1} validity; padded rows contribute exactly zero
    loss/grad). Decoder-only families only."""
    assert not _is_encdec(cfg), "masked federated loss: decoder-only models"
    return tf.lm_loss_masked(cfg, params, batch, remat=remat)


def prefill(cfg, params, batch, target_len=None):
    if _is_encdec(cfg):
        return ed.encdec_prefill(cfg, params, batch["src"], batch["tokens"],
                                 target_len or batch["tokens"].shape[1])
    return tf.lm_prefill(cfg, params, batch["tokens"], target_len=target_len)


def decode_step(cfg, params, cache, token):
    if _is_encdec(cfg):
        return ed.encdec_decode_step(cfg, params, cache, token)
    return tf.lm_decode_step(cfg, params, cache, token)


def cache_init(cfg, batch: int, seq_len: int, src_len: int = 0):
    if _is_encdec(cfg):
        return ed.encdec_cache_init(cfg, batch, seq_len,
                                    src_len or _default_src_len(cfg, seq_len))
    return tf.lm_cache_init(cfg, batch, seq_len)


def _default_src_len(cfg, seq_len: int) -> int:
    # audio: encoder frames; capped so a 500k-target dry-run doesn't imply a
    # 500k-frame utterance (the shape is skipped for enc-dec anyway).
    return min(seq_len, 4096)


def input_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input of the given shape.
    No device allocation — safe for 512-fake-device dry-run lowering."""
    f32 = jnp.dtype("float32")
    i32 = jnp.dtype("int32")
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if _is_encdec(cfg):
            # frontend stub: precomputed frame embeddings (B, S_src, d)
            return {"src": sds((B, _default_src_len(cfg, S), cfg.d_model), f32),
                    "tokens": sds((B, S), i32)}
        return {"tokens": sds((B, S), i32)}
    if shape.kind == "prefill":
        if _is_encdec(cfg):
            return {"src": sds((B, _default_src_len(cfg, S), cfg.d_model), f32),
                    "tokens": sds((B, S), i32)}
        return {"tokens": sds((B, S), i32)}
    # decode: one new token + a cache of seq_len
    cache = jax.eval_shape(lambda: cache_init(cfg, B, S))
    return {"cache": cache, "token": sds((B, 1), i32)}


def supports_shape(cfg: ModelConfig, shape: InputShape):
    """(ok, reason) — long_500k policy from DESIGN §Arch-applicability."""
    if shape.name == "long_500k":
        if _is_encdec(cfg):
            return False, "enc-dec speech decoder: 500k-token target sequence skipped (DESIGN.md)"
        if cfg.family == "ssm" or cfg.attn_layer_period:
            return True, "native sub-quadratic (SSM state / hybrid)"
        if cfg.sliding_window or cfg.long_context_window:
            return True, "sliding-window variant"
        return False, "pure full-attention arch without SWA variant"
    return True, ""
