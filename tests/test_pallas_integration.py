"""Model-level Pallas dispatch: REPRO_USE_PALLAS=1 routes full-sequence
attention through the flash kernel (interpret mode on CPU) and must agree
with the default XLA path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import dataclasses

from repro.configs import get, reduced
from repro.models import transformer as tf, api


@pytest.mark.parametrize("arch", ["yi-34b", "starcoder2-15b"])
def test_flag_dispatch_matches_oracle(arch, monkeypatch):
    cfg = dataclasses.replace(reduced(get(arch)), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    tok = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    base, _, _, _ = tf.lm_forward(cfg, params, tok, window=cfg.sliding_window)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    fused, _, _, _ = tf.lm_forward(cfg, params, tok, window=cfg.sliding_window)
    np.testing.assert_allclose(np.asarray(base), np.asarray(fused),
                               atol=2e-4, rtol=2e-4)


def test_flag_off_by_default():
    from repro.kernels import ops
    assert not ops.use_pallas() or os.environ.get("REPRO_USE_PALLAS") not in (None, "0")
