"""Async FEEL simulation driver (ROADMAP item 3, DESIGN.md §13).

This module used to be the seed's big-model decode launcher — dead code on
the ``repro.check`` dead-inheritance inventory since the FEEL reproduction
never served a model. It is now the command-line driver for the
event-driven engine (federated/async_engine.py): configure an async run
(trigger, staleness discount, latency scale, channel correlation), run it
through ``run_experiment``, and report accuracy against the SIMULATED
wall-clock — the axis the synchronous engine cannot produce.

    python -m repro.launch.serve --rounds 8 --buffer 4 --scenario \\
        stale_rider_2 --defense validation
    python -m repro.launch.serve --sync          # lockstep oracle run
    python -m repro.launch.serve --json          # machine-readable output

The clock is simulated (Eq. 6 train time + Eq. 7 upload time on seeded
draws) — the driver never reads the wall clock, so a run is a pure
function of its flags + seed.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, Optional

from repro.configs.base import FeelConfig
from repro.federated.simulation import run_experiment


def simulate(policy: str = "dqs", task: Optional[str] = None,
             scenario: str = "none", defense: str = "none",
             seed: int = 0, rounds: Optional[int] = None,
             n_train: Optional[int] = None, n_test: Optional[int] = None,
             mode: str = "async", buffer: Optional[int] = None,
             deadline: Optional[float] = None, staleness: float = 0.5,
             latency_scale: float = 1.0, channel_corr: float = 0.0,
             cfg: Optional[FeelConfig] = None, **kw) -> Dict:
    """One driver run: an async (or ``mode="sync"`` oracle) experiment
    with the trigger/staleness/latency knobs mapped onto ``FeelConfig``.
    Returns ``run_experiment``'s curves (async runs add ``sim_time`` /
    ``trigger`` / ``n_uploads`` / ``mean_age``)."""
    cfg = dataclasses.replace(
        cfg or FeelConfig(), mode=mode, async_buffer=buffer,
        async_deadline=deadline, async_staleness=staleness,
        async_latency_scale=latency_scale, channel_corr=channel_corr,
        **({"task": task} if task is not None else {}))
    return run_experiment(policy=policy, cfg=cfg, seed=seed, rounds=rounds,
                          n_train=n_train, n_test=n_test, scenario=scenario,
                          defense=defense, **kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="event-driven FEEL simulation (accuracy vs simulated "
                    "wall-clock)")
    ap.add_argument("--policy", default="dqs")
    ap.add_argument("--task", default=None,
                    help="task registry name (default: cfg.task)")
    ap.add_argument("--scenario", default="none")
    ap.add_argument("--defense", default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=None,
                    help="aggregations to run (default: cfg.rounds)")
    ap.add_argument("--n-train", type=int, default=None)
    ap.add_argument("--n-test", type=int, default=None)
    ap.add_argument("--ues", type=int, default=None,
                    help="override cfg.n_ues (bandwidth budget K)")
    ap.add_argument("--malicious", type=int, default=None,
                    help="override cfg.n_malicious")
    ap.add_argument("--sync", action="store_true",
                    help="run the lockstep oracle engine instead")
    ap.add_argument("--buffer", type=int, default=None,
                    help="aggregate once this many uploads are buffered "
                         "(default: wait for the whole wave)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="also flush the buffer at dispatch + D sim-seconds")
    ap.add_argument("--staleness", type=float, default=0.5,
                    help="staleness discount base decay**age (in (0, 1])")
    ap.add_argument("--latency-scale", type=float, default=1.0,
                    help="scale simulated upload latencies (0 = oracle limit)")
    ap.add_argument("--channel-corr", type=float, default=0.0,
                    help="AR(1) channel correlation rho (0 = memoryless)")
    ap.add_argument("--json", dest="as_json", action="store_true",
                    help="emit the full result dict as JSON on stdout")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the span tracer (DESIGN.md §14) and write "
                         "the JSONL trace to PATH; inspect with "
                         "python -m repro.obs.report PATH")
    args = ap.parse_args(argv)

    if args.trace:
        from repro.obs import trace
        trace.configure(enabled=True)

    cfg = FeelConfig()
    over = {}
    if args.ues is not None:
        over["n_ues"] = args.ues
    if args.malicious is not None:
        over["n_malicious"] = args.malicious
    if over:
        cfg = dataclasses.replace(cfg, **over)
    res = simulate(policy=args.policy, task=args.task,
                   scenario=args.scenario, defense=args.defense,
                   seed=args.seed, rounds=args.rounds,
                   n_train=args.n_train, n_test=args.n_test,
                   mode="sync" if args.sync else "async",
                   buffer=args.buffer, deadline=args.deadline,
                   staleness=args.staleness,
                   latency_scale=args.latency_scale,
                   channel_corr=args.channel_corr, cfg=cfg)
    if args.trace:
        from repro.obs import trace
        trace.flush_jsonl(args.trace)
    if args.as_json:
        print(json.dumps(res))
        return 0
    sim_t = res.get("sim_time")
    print(f"# task={res['task']} policy={args.policy} "
          f"scenario={res['scenario']} defense={res['defense']} "
          f"mode={'sync' if args.sync else 'async'}")
    if sim_t is None:
        print("round,acc")
        for t, a in enumerate(res["acc"]):
            print(f"{t},{a:.4f}")
    else:
        print("version,sim_s,acc,trigger,n_uploads,mean_age")
        for t, a in enumerate(res["acc"]):
            print(f"{t},{sim_t[t]:.1f},{a:.4f},{res['trigger'][t]},"
                  f"{res['n_uploads'][t]},{res['mean_age'][t]:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
