"""Pallas TPU flash attention (causal / sliding-window), online softmax.

Grid (B, H, n_q, n_kv) with the KV dimension innermost; running max / sum /
accumulator live in VMEM scratch across KV steps. Block shapes default to
(128, head_dim) — MXU-aligned (128 lanes) and sized so the working set
(q + k + v + acc tiles, fp32 acc) stays well under ~16 MB VMEM:
128x128x4B x 4 tiles = 256 KiB.

TPU adaptation note: this is the standard HBM->VMEM tiled online-softmax
schedule; there is no shared-memory banking / warp-level trick to port.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, block_q, block_k, n_kv, causal, window, seq_q,
                  seq_kv):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # global positions (queries right-aligned when seq_q < seq_kv)
    qi = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + (seq_kv - seq_q)
    kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "scale"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                    interpret=False):
    """q (B,H,S,D), k/v (B,H,T,D) -> (B,H,S,D)."""
    B, H, S, D = q.shape
    T = k.shape[2]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    n_q, n_kv = S // block_q, T // block_k
    scale = scale if scale is not None else D ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv=n_kv, causal=causal, window=window, seq_q=S, seq_kv=T)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
