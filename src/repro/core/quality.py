"""Data-quality value (paper §III-B.4, Eq. 3): V_k = w1 * R_k + w2 * I_k.

``data_quality_value`` is dtype-polymorphic — it is a pure elementwise
expression, so the batched control plane (core/control.py) calls it on jnp
arrays under vmap while the host oracle calls it on numpy arrays.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.configs.base import FeelConfig


def data_quality_value(reputation, diversity, cfg: FeelConfig,
                       omega: Optional[Tuple[float, float]] = None):
    """Eq. 3. ``omega = (w_rep, w_div)`` overrides the config weights —
    the adaptive-omega schedule passes the annealed pair here instead of
    allocating a replaced config every round."""
    w_rep, w_div = omega if omega is not None else (cfg.omega_rep,
                                                   cfg.omega_div)
    return w_rep * reputation + w_div * diversity


def adaptive_weights(round_t: int, total_rounds: int,
                     cfg: FeelConfig) -> Tuple[float, float]:
    """Beyond-paper extension motivated by the paper's own §V-B.2 observation:
    diversity matters early, reputation matters late. Linearly anneals
    (omega_div, omega_rep) from (1, 0)-leaning to (0, 1)-leaning over
    training. Returns the ``(w_rep, w_div)`` pair — allocation-free, the
    per-round scheduling hot path feeds it straight to
    ``data_quality_value(..., omega=...)``.
    """
    frac = round_t / max(total_rounds - 1, 1)
    total = cfg.omega_rep + cfg.omega_div
    w_rep = total * (0.25 + 0.5 * frac)
    return w_rep, total - w_rep
