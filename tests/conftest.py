# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real (single-CPU) device; only launch/dryrun.py forces 512 host devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
