"""repro.check — the static contract checker (DESIGN.md §11).

Run as ``python -m repro.check [--strict] [--json]`` (or the
``repro-check`` console script). Gated in tier-1 by
``tests/test_check.py``: the suite fails if any checker reports a
violation on the repo.

What is enforced (and why) lives in the rule modules' docstrings:

- ``lints``     — AST lints: oracle purity, tracer leaks,
                  nondeterminism, dtype discipline;
- ``registry``  — registry–test cross-referencing + kernel ``_ref``
                  twins;
- ``trace``     — abstract-trace (jaxpr) dtype pinning + static-arg
                  hashability;
- ``inventory`` — dead-inheritance report (informational, never fails).

Intentional exceptions are waived per line with
``# repro: allow(rule-name)`` (see ``common.parse_waivers``).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Dict, List

from repro.check.common import (CheckContext, SourceFile, Violation,
                                make_context)
from repro.check.inventory import build_inventory
from repro.check.lints import (check_dtype, check_nondeterminism,
                               check_oracle_purity, check_tracer_leak)
from repro.check.registry import check_kernel_twins, check_registries
from repro.check.trace import check_static_args, check_traces

# ordered: cheap AST passes first, import-the-world trace checks last
CHECKERS: Dict[str, Callable[[CheckContext], List[Violation]]] = {
    "oracle-purity": check_oracle_purity,
    "tracer-leak": check_tracer_leak,
    "nondeterminism": check_nondeterminism,
    "dtype": check_dtype,
    "registry-coverage": check_registries,
    "kernel-ref-twin": check_kernel_twins,
    "static-args": check_static_args,
    "trace": check_traces,
}


@dataclasses.dataclass
class CheckReport:
    violations: List[Violation]
    inventory: Dict
    per_checker: Dict[str, int]

    @property
    def ok(self) -> bool:
        return not self.violations


def repo_root() -> Path:
    """The repo root: three parents up from this package
    (src/repro/check -> repo)."""
    return Path(__file__).resolve().parents[3]


def run_checks(root: Path = None, skip_trace: bool = False) -> CheckReport:
    """Run every checker over ``root`` (default: this repo)."""
    ctx = make_context(root or repo_root())
    violations: List[Violation] = []
    per: Dict[str, int] = {}
    for name, chk in CHECKERS.items():
        if skip_trace and name == "trace":
            per[name] = -1
            continue
        vs = chk(ctx)
        per[name] = len(vs)
        violations.extend(vs)
    return CheckReport(violations=violations,
                       inventory=build_inventory(ctx), per_checker=per)


__all__ = ["CHECKERS", "CheckReport", "CheckContext", "SourceFile",
           "Violation", "make_context", "repo_root", "run_checks"]
