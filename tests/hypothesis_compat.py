"""Degrade gracefully when ``hypothesis`` is not installed (offline
container): property tests skip individually instead of erroring the whole
module at collection time.

Test modules import the hypothesis API from here::

    from hypothesis_compat import given, settings, st

With hypothesis installed this is a plain re-export. Without it, ``st.*``
strategy constructors become inert stubs and ``@given(...)`` replaces the
test with a zero-argument function that calls ``pytest.skip`` — so the
plain (non-property) tests in the same module still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Any ``st.<name>(...)`` call returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped
        return deco
