"""Synthetic LM token streams (offline container): structured pseudo-text with
learnable bigram statistics, for the end-to-end LM training driver and the
federated LM task (``federated/task.py::LmTask``). A Zipfian unigram base plus
a class-conditioned Markov kernel gives each "domain" (client group) its own
distribution — mirroring non-IID federated text.

Stream version 2: ``make_stream`` used to run a per-token Python loop with an
``rng.choice(vocab, p=base)`` host call per emitted token — O(n_tokens) RNG
round-trips, which the federated LM sweep pays once per client. The loop is
replaced by precomputed inverse-CDF sampling (one ``searchsorted`` over the
Zipf CDF) plus a closed form for the deterministic bigram segments: between
two Zipf draws the chain iterates the affine map ``t -> (31 t + 7 + d) mod V``
whose m-th iterate is ``A[m] t0 + (7 + d) S[m] mod V`` with ``A[m] = 31^m``
and ``S[m] = sum_{i<m} 31^i`` — both tabulated once per call. The RNG draw
ORDER necessarily changed (the old stream interleaved branch/choice draws),
so the per-seed streams are intentionally re-versioned; the new streams are
pinned by a golden regression test (tests/test_task_lm.py) and keep the same
marginal statistics (Zipf unigrams, ~0.6 bigram-continuation rate).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def zipf_probs(vocab: int, s: float = 1.1) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** s
    return p / p.sum()


def _affine_tables(n: int, vocab: int, domain: int):
    """(A, C) with A[m] = 31^m mod V and C[m] = (7+domain)*sum_{i<m} 31^i
    mod V — the m-th iterate of the bigram map is ``A[m]*t0 + C[m] mod V``.
    The power sequence is eventually periodic with period <= V, so only the
    cycle is computed in Python; the length-n tables are index lookups."""
    pows, seen = [], {}
    v = 1
    while v not in seen:
        seen[v] = len(pows)
        pows.append(v)
        v = (v * 31) % vocab
    start = seen[v]                      # cycle entry point
    period = len(pows) - start
    idx = np.arange(n)
    cyc = np.where(idx < len(pows), idx,
                   start + (idx - start) % period)
    A = np.asarray(pows, np.int64)[np.minimum(cyc, len(pows) - 1)]
    S = np.concatenate([[0], np.cumsum(A[:-1]) % vocab])
    C = ((7 + domain) % vocab) * S % vocab
    return A, C


def make_stream(n_tokens: int, vocab: int, seed: int = 0,
                domain: int = 0) -> np.ndarray:
    """Markov stream: next-token dist = mix(zipf, shifted-by-domain zipf).

    Vectorized (stream v2, see module docstring): three bulk RNG draws —
    the initial token, the per-step branch uniforms, and the per-step Zipf
    uniforms — then a closed-form evaluation of every deterministic bigram
    segment. No per-token host RNG calls.
    """
    rng = np.random.default_rng(seed + 7919 * domain)
    if n_tokens <= 0:
        return np.empty(0, np.int32)
    cdf = np.cumsum(zipf_probs(vocab))
    t0 = int(rng.integers(vocab))
    u_branch = rng.random(n_tokens)       # branch decision after token i
    u_tok = rng.random(n_tokens)          # inverse-CDF Zipf draw per step
    z = np.searchsorted(cdf, u_tok).astype(np.int64)

    # token 0 and every post-Zipf-draw position start a fresh affine segment
    is_start = np.empty(n_tokens, bool)
    is_start[0] = True
    is_start[1:] = u_branch[:-1] >= 0.6
    start_val = np.empty(n_tokens, np.int64)
    start_val[0] = t0
    start_val[1:] = z[:-1]

    pos = np.arange(n_tokens)
    seg = np.maximum.accumulate(np.where(is_start, pos, -1))
    off = pos - seg                       # iterate count within the segment
    A, C = _affine_tables(n_tokens, vocab, domain)
    toks = (A[off] * start_val[seg] + C[off]) % vocab
    return toks.astype(np.int32)


def batches(stream: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    """Yield {tokens: (B, S)} windows forever."""
    n = len(stream) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield {"tokens": np.stack([stream[s:s + seq] for s in starts])}


# ---------------------------------------------------------------------- #
# Federated token windows (the LM task's Dataset analogue)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class TokenDataset:
    """Fixed-length token windows with a per-window domain id.

    ``y`` holds the domain each window was drawn from — the LM analogue of
    the MNIST class label, so ``data.partition.partition`` (sort-by-label
    group allocation) works on token data unchanged. Quality statistics
    (histograms, Gini-Simpson) are computed over the TOKENS, not ``y``:
    the server never uses the domain ids, they only shape the non-IID
    allocation.
    """
    tokens: np.ndarray   # (N, seq) int32 windows
    y: np.ndarray        # (N,) int32 domain ids (partition sort key)

    def __len__(self):
        return self.tokens.shape[0]

    def subset(self, idx: np.ndarray) -> "TokenDataset":
        return TokenDataset(self.tokens[idx], self.y[idx])


def make_windows(n_windows: int, vocab: int, seq: int,
                 n_domains: int = 10, seed: int = 0) -> TokenDataset:
    """Cut ``n_windows`` fixed-length windows from ``n_domains`` domain
    streams, interleaved round-robin so truncation stays domain-balanced."""
    per = -(-n_windows // n_domains)
    toks = np.stack([make_stream(per * seq, vocab, seed=seed,
                                 domain=d).reshape(per, seq)
                     for d in range(n_domains)], axis=1)
    ys = np.broadcast_to(np.arange(n_domains, dtype=np.int32),
                         (per, n_domains))
    return TokenDataset(toks.reshape(per * n_domains, seq)[:n_windows],
                        ys.reshape(-1)[:n_windows].copy())
