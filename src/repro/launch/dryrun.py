"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh with 512 placeholder host devices, and extract roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
    PYTHONPATH=src python -m repro.launch.dryrun --cohort   # paper's FEEL step

Results are appended to --out (JSON), one record per (arch, shape, mesh);
already-present records are skipped unless --force.
"""
# The VERY FIRST lines — before ANY other import — jax locks the device count
# on first init.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
from repro.obs.clock import wall_clock  # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, TrainConfig, get, list_archs  # noqa: E402
from repro.launch import roofline as rl                  # noqa: E402
from repro.launch.mesh import ADAFACTOR_ARCHS, make_production_mesh  # noqa: E402,F401
from repro.launch.steps import (make_decode_step, make_prefill_step,  # noqa: E402
                                make_train_step)
from repro.models import api                             # noqa: E402
from repro.sharding import (activation_specs, batch_specs,  # noqa: E402
                            data_axes, opt_state_specs, param_specs)



def _sds_with(tree, spec_tree, mesh):
    def mk(x, s):
        return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                    sharding=NamedSharding(mesh, s))
    return jax.tree.map(mk, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _act_specs(mesh, shape_kind, batch_shardable=True):
    dax = data_axes(mesh)
    bax = dax if len(dax) > 1 else dax[0]
    if shape_kind in ("train", "prefill"):
        return {"act": P(bax, None, None), "logits": P(bax, None, "model")}
    b = bax if batch_shardable else None
    return {"dec": P(b, None, None)}


def _compile_one(cfg, shape, mesh, optimizer: str, extra_specs_fn=None):
    """Lower + compile one (cfg, shape) on mesh. Returns (compiled, t_lower,
    t_compile)."""
    t0 = wall_clock()
    params_sds = jax.eval_shape(functools.partial(api.init, cfg),
                                jax.random.PRNGKey(0))
    pspecs = param_specs(cfg, params_sds, mesh)
    params_in = _sds_with(params_sds, pspecs, mesh)
    bspecs = batch_specs(cfg, shape, mesh)

    n_data = 1
    for a in data_axes(mesh):
        n_data *= mesh.shape[a]
    batch_shardable = shape.global_batch % n_data == 0 \
        and shape.global_batch >= n_data

    if shape.kind == "train":
        tcfg = TrainConfig(optimizer=optimizer,
                           remat=os.environ.get("REPRO_REMAT_OFF",
                                                "0") == "0")
        from repro.optim import make_optimizer
        opt = make_optimizer(tcfg)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = opt_state_specs(tcfg.optimizer, params_sds, pspecs, mesh)
        opt_in = _sds_with(opt_sds, ospecs, mesh)
        step_in = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=NamedSharding(mesh, P()))
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                            sharding=NamedSharding(mesh, bspecs[k]))
                    for k, v in api.input_specs(cfg, shape).items()}
        fn = make_train_step(cfg, tcfg)
        args = (params_in, opt_in, step_in, batch_in)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                            sharding=NamedSharding(mesh, bspecs[k]))
                    for k, v in api.input_specs(cfg, shape).items()}
        args = (params_in, batch_in)
    else:  # decode
        specs = api.input_specs(cfg, shape)
        cache_in = _sds_with(specs["cache"], bspecs["cache"], mesh)
        token_in = jax.ShapeDtypeStruct(
            specs["token"].shape, specs["token"].dtype,
            sharding=NamedSharding(mesh, bspecs["token"]))
        fn = make_decode_step(cfg)
        args = (params_in, cache_in, token_in)
        if os.environ.get("REPRO_DONATE", "0") == "1":
            fn = functools.partial(fn)
            jit_fn = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
            with mesh, activation_specs(_act_specs(mesh, shape.kind,
                                                   batch_shardable)):
                lowered = jit_fn.lower(*args)
                t_lower = wall_clock() - t0
                compiled = lowered.compile()
                t_compile = wall_clock() - t0 - t_lower
            return compiled, t_lower, t_compile

    specs = _act_specs(mesh, shape.kind, batch_shardable)
    if extra_specs_fn is not None:
        specs.update(extra_specs_fn(mesh, cfg) or {})
    with mesh, activation_specs(specs):
        lowered = jax.jit(fn).lower(*args)
        t_lower = wall_clock() - t0
        compiled = lowered.compile()
        t_compile = wall_clock() - t0 - t_lower
    return compiled, t_lower, t_compile


def cost_dict(compiled) -> dict:
    """Normalise ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a flat dict; newer versions return a (per-device)
    list of dicts — one entry per addressable device, identical under SPMD,
    so the first entry is the per-chip cost either way.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _extract(compiled):
    cost = cost_dict(compiled)
    coll = rl.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _n_blocks_variant(cfg, n_blocks: int):
    """Config with the scan shortened to ``n_blocks`` super-blocks (encoder
    scan shortened in lockstep for enc-dec). Used by the scan-trip-count
    correction: XLA cost_analysis counts a while body ONCE."""
    import dataclasses
    kw = dict(n_layers=cfg.first_dense_layers + n_blocks * cfg.block_len,
              block_len=cfg.block_len,
              scan_unroll=n_blocks)     # trips=1 so cost_analysis sees all
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = n_blocks
    return dataclasses.replace(cfg, **kw)


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               extra_tags=None, cfg_override=None, label=None,
               correct_scan: bool = True, extra_specs_fn=None,
               optimizer_override=None) -> dict:
    cfg = cfg_override or get(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": label or arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           **(extra_tags or {})}
    ok, reason = api.supports_shape(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    optimizer = optimizer_override or (
        "adafactor" if arch in ADAFACTOR_ARCHS else "adamw")
    if shape.kind == "train":
        rec["optimizer"] = optimizer
    try:
        compiled, t_lower, t_compile = _compile_one(cfg, shape, mesh,
                                                    optimizer, extra_specs_fn)
        flops, hbm, coll = _extract(compiled)
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception:
            mem_rec = {}

        # ---- scan-trip-count correction (see module docstring) ----
        N = cfg.n_blocks
        if N > 1 and correct_scan:
            c1, _, _ = _compile_one(_n_blocks_variant(cfg, 1), shape, mesh,
                                    optimizer, extra_specs_fn)
            c2, _, _ = _compile_one(_n_blocks_variant(cfg, 2), shape, mesh,
                                    optimizer, extra_specs_fn)
            f1, b1, k1 = _extract(c1)
            f2, b2, k2 = _extract(c2)
            flops += (N - 1) * max(f2 - f1, 0.0)
            hbm += (N - 1) * max(b2 - b1, 0.0)
            coll = {op: coll[op] + (N - 1) * max(k2[op] - k1[op], 0)
                    for op in coll}

        terms = rl.roofline_terms(flops, hbm, coll)
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mf = rl.model_flops(cfg, tokens, train=(shape.kind == "train"))
        n_chips = mesh.devices.size
        rec.update(
            status="ok", flops_per_chip=flops, hbm_bytes_per_chip=hbm,
            collectives=coll, **terms,
            dominant=rl.dominant(terms),
            model_flops_total=mf,
            useful_flops_ratio=(mf / (flops * n_chips)) if flops else None,
            memory=mem_rec, lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def print_rec(rec):
    if rec.get("status") == "ok":
        print(f"[ok]   {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
              f"compute={rec['compute_s']:.3e}s memory={rec['memory_s']:.3e}s "
              f"collective={rec['collective_s']:.3e}s dom={rec['dominant']} "
              f"(lower {rec.get('lower_s', '-')}s "
              f"compile {rec.get('compile_s', '-')}s)")
    elif rec.get("status") == "skipped":
        print(f"[skip] {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
              f"{rec['reason']}")
    else:
        print(f"[ERR]  {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
              f"{rec.get('error')}")


def cohort_dryrun(multi_pod: bool, agg_dtype=None, label="feel-cohort-mlp") -> dict:
    """Dry-run the paper's distributed FEEL round (DESIGN.md §3):
    per-client local SGD + masked weighted psum aggregation."""
    from repro.federated.distributed import (cohort_input_specs,
                                             make_cohort_step)
    from repro.models.mlp import mlp_init, mlp_loss
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = ("pod", "data") if multi_pod else ("data",)
    n_clients = 1
    for a in axes:
        n_clients *= mesh.shape[a]
    params = jax.eval_shape(mlp_init, jax.random.PRNGKey(0))
    batch, vec, _ = cohort_input_specs(
        mesh, n_clients, {"x": ((256, 784), jnp.float32),
                          "y": ((256,), jnp.int32)}, axes)
    step = make_cohort_step(mesh, mlp_loss, lr=0.1, local_steps=5,
                            client_axes=axes, agg_dtype=agg_dtype)
    rec = {"arch": label, "shape": f"clients_{n_clients}",
           "mesh": "2x16x16" if multi_pod else "16x16"}
    try:
        with mesh:
            lowered = step.lower(params, batch, vec, vec)
            compiled = lowered.compile()
        cost = cost_dict(compiled)
        coll = rl.collective_bytes(compiled.as_text())
        terms = rl.roofline_terms(float(cost.get("flops", 0)),
                                  float(cost.get("bytes accessed", 0)), coll)
        rec.update(status="ok", collectives=coll, **terms,
                   dominant=rl.dominant(terms),
                   flops_per_chip=float(cost.get("flops", 0)),
                   hbm_bytes_per_chip=float(cost.get("bytes accessed", 0)))
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cohort", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-correction", action="store_true",
                    help="skip the scan-trip-count delta compiles (used for "
                         "the multi-pod lowering-proof pass; the roofline "
                         "table is single-pod)")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    jobs = []
    if args.cohort:
        for mp in meshes:
            jobs.append(("cohort", None, mp))
    else:
        archs = list_archs() if (args.all or not args.arch) else [args.arch]
        shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
        for a in archs:
            for s in shapes:
                for mp in meshes:
                    jobs.append((a, s, mp))

    for a, s, mp in jobs:
        if a == "cohort":
            rec = cohort_dryrun(mp)
        else:
            key = (a, s, "2x16x16" if mp else "16x16")
            if key in done and not args.force:
                continue
            rec = lower_pair(a, s, mp, correct_scan=not args.no_correction)
        print_rec(rec)
        results = [r for r in results
                   if (r["arch"], r["shape"], r["mesh"])
                   != (rec["arch"], rec["shape"], rec["mesh"])]
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_err = sum(r.get("status") == "error" for r in results)
    print(f"\n{len(results)} records, {n_err} errors -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
