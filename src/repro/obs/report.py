"""Trace-file summarizer: ``python -m repro.obs.report TRACE.jsonl``.

Reads a JSONL trace written by ``obs/trace.py`` and prints:

* the commit+env meta line the trace is keyed by;
* per-phase wall-time summary (count, total, p50, p95), sorted by
  total descending;
* top compile offenders — spans whose jit first-call probe marked a
  fresh compile-cache entry, sorted by duration;
* roofline context for phases that attached analytic ``est_flops`` /
  ``est_bytes`` attributes (train, schedule): arithmetic intensity
  against the v5e ridge point via ``launch/roofline.py`` and, from the
  measured wall time, the attained fraction of the roofline floor;
* the counter/gauge/observation snapshot.

``--json`` emits the same content as one JSON object for tooling.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.launch.roofline import intensity_context
from repro.obs.trace import load_jsonl, phase_summary


def compile_offenders(spans: List[Dict], top: int = 10) -> List[Dict]:
    """Spans that triggered a fresh jit compile, slowest first."""
    hits = [s for s in spans if (s.get("attrs") or {}).get("compiled")]
    hits.sort(key=lambda s: s["dur"], reverse=True)
    return [{"name": s["name"], "dur_s": s["dur"],
             "attrs": {k: v for k, v in (s.get("attrs") or {}).items()
                       if k != "compiled"}}
            for s in hits[:top]]


def roofline_context(spans: List[Dict]) -> Dict[str, Dict]:
    """Aggregate est_flops/est_bytes per phase and place each phase on
    the roofline."""
    agg: Dict[str, List[float]] = {}
    for s in spans:
        attrs = s.get("attrs") or {}
        if "est_flops" in attrs and "est_bytes" in attrs:
            f, b, d = agg.setdefault(s["name"], [0.0, 0.0, 0.0])
            agg[s["name"]] = [f + attrs["est_flops"],
                              b + attrs["est_bytes"], d + s["dur"]]
    out: Dict[str, Dict] = {}
    for name, (flops, nbytes, dur) in sorted(agg.items()):
        if nbytes > 0:
            out[name] = intensity_context(flops, nbytes, measured_s=dur)
    return out


def summarize(path: str, top: int = 10) -> Dict:
    """Everything the CLI prints, as one dict (used by bench smoke)."""
    meta, spans, metrics = load_jsonl(path)
    return {"meta": meta,
            "phases": phase_summary(spans),
            "compile_offenders": compile_offenders(spans, top=top),
            "roofline": roofline_context(spans),
            "metrics": metrics}


def _fmt_eng(x: float) -> str:
    for unit, scale in (("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if abs(x) >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.2f}"


def render(rep: Dict, out=None) -> None:
    out = out or sys.stdout
    w = out.write
    meta = rep["meta"]
    w(f"# trace commit={meta.get('commit', '?')} "
      f"python={meta.get('python', '?')} jax={meta.get('jax', '?')} "
      f"at={meta.get('timestamp', '?')}\n")
    w("phase,count,total_s,p50_s,p95_s\n")
    phases = sorted(rep["phases"].items(),
                    key=lambda kv: kv[1]["total_s"], reverse=True)
    for name, p in phases:
        w(f"{name},{p['count']},{p['total_s']:.6f},"
          f"{p['p50_s']:.6f},{p['p95_s']:.6f}\n")
    if rep["compile_offenders"]:
        w("# top compile offenders (fresh jit cache entries)\n")
        for o in rep["compile_offenders"]:
            extra = "".join(f" {k}={v}" for k, v in o["attrs"].items())
            w(f"compile,{o['name']},{o['dur_s']:.6f}{extra}\n")
    if rep["roofline"]:
        w("# roofline context (analytic est_flops/est_bytes vs v5e roof)\n")
        for name, r in rep["roofline"].items():
            att = (f" attained={r['attained_frac']:.2e}"
                   if "attained_frac" in r else "")
            w(f"roofline,{name},{_fmt_eng(r['flops'])}F,"
              f"{_fmt_eng(r['hbm_bytes'])}B,"
              f"AI={r['intensity']:.2f},ridge={r['ridge']:.0f},"
              f"{r['bound']}-bound,floor={r['time_floor_s']:.3e}s{att}\n")
    m = rep.get("metrics") or {}
    for kind in ("counters", "gauges", "observations"):
        for name, v in (m.get(kind) or {}).items():
            if isinstance(v, dict):
                body = ",".join(f"{k}={v[k]:.6g}" if
                                isinstance(v[k], float) else f"{k}={v[k]}"
                                for k in sorted(v))
            else:
                body = str(v)
            w(f"metric,{kind},{name},{body}\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize a repro.obs JSONL trace")
    ap.add_argument("trace", help="path to a trace .jsonl file")
    ap.add_argument("--top", type=int, default=10,
                    help="compile offenders to show")
    ap.add_argument("--json", dest="as_json", action="store_true")
    args = ap.parse_args(argv)
    rep = summarize(args.trace, top=args.top)
    if args.as_json:
        print(json.dumps(rep))
    else:
        render(rep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
