"""Span tracer for the round pipeline (DESIGN.md §14).

A ``Span`` is a named, possibly-nested phase of a round — schedule
(prefilter/pack/finalize), train (per-bucket dispatch, compile vs
execute via the jit first-call probe), attack-apply, defense
(aggregate/detect), eval — recorded on the monotonic wall clock
(``obs/clock.py``) and, when the async engine is driving, on the
simulated event clock as well (``sim_t0``/``sim_t1``).

The hard contract is **zero semantic footprint**:

* telemetry never draws from the RNG stream of record, never reorders
  f64 accumulation, never touches a traced value;
* the disabled tracer (``REPRO_TRACE=0``, the default) hands every
  call site the same shared ``_NullSpan`` singleton — no allocation,
  no clock read, no ring append;
* attributes are attached via ``span.set(key=value)`` *inside* an
  ``if trace.enabled()`` guard or on the null span (a no-op), so the
  hot path never builds kwargs dicts when tracing is off.

Sinks: the in-memory ring (``tracer().spans``), a JSONL file keyed
commit+env like ``BENCH_history.jsonl`` (``flush_jsonl``), and a
Chrome/Perfetto ``trace_event`` export (``to_trace_event``).  Set
``REPRO_TRACE=1`` to enable and ``REPRO_TRACE_FILE=/path.jsonl`` to
flush the ring at interpreter exit — that is how benchmark worker
subprocesses hand traces back to the driver.
"""
from __future__ import annotations

import atexit
import functools
import json
import os
import platform
import subprocess
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.obs.clock import utc_stamp, wall_clock
from repro.obs.metrics import MetricRegistry

_RING = 65536  # completed spans kept; oldest half dropped on overflow


class Span:
    """One timed phase. Use as a context manager; never reused."""

    __slots__ = ("name", "sid", "parent", "depth", "t0", "t1",
                 "sim_t0", "sim_t1", "attrs", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, sid: int,
                 parent: int, depth: int) -> None:
        self.name = name
        self.sid = sid
        self.parent = parent          # parent span's sid, -1 at root
        self.depth = depth
        self.t0 = self.t1 = 0.0       # wall clock (monotonic seconds)
        self.sim_t0 = self.sim_t1 = None  # simulated clock (async mode)
        self.attrs: Optional[Dict[str, Any]] = None
        self._tracer = tracer

    def set(self, **attrs: Any) -> "Span":
        if self.attrs is None:
            self.attrs = {}
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self._tracer
        tr._stack.append(self)
        if tr.sim_clock is not None:
            self.sim_t0 = tr.sim_clock()
        self.t0 = wall_clock()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = wall_clock()
        tr = self._tracer
        if tr.sim_clock is not None:
            self.sim_t1 = tr.sim_clock()
        assert tr._stack and tr._stack[-1] is self, \
            "span stack discipline broken"
        tr._stack.pop()
        ring = tr.spans
        if len(ring) >= tr.ring_size:
            del ring[: tr.ring_size // 2]
        ring.append(self)
        return False

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": "span", "name": self.name,
                             "sid": self.sid, "parent": self.parent,
                             "depth": self.depth, "t0": self.t0,
                             "t1": self.t1, "dur": self.t1 - self.t0}
        if self.sim_t0 is not None:
            d["sim_t0"] = self.sim_t0
            d["sim_t1"] = self.sim_t1
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _NullSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + ring + metric registry + optional sim clock."""

    def __init__(self, enabled: bool = False, path: Optional[str] = None,
                 ring_size: int = _RING) -> None:
        self.enabled = enabled
        self.path = path
        self.ring_size = ring_size
        self.spans: List[Span] = []
        self._stack: List[Span] = []
        self.sim_clock: Optional[Callable[[], float]] = None
        self.metrics = MetricRegistry()
        self._next_sid = 0

    def span(self, name: str) -> Union[Span, _NullSpan]:
        if not self.enabled:
            return NULL_SPAN
        sid = self._next_sid
        self._next_sid += 1
        parent = self._stack[-1].sid if self._stack else -1
        return Span(self, name, sid, parent, len(self._stack))

    def reset(self) -> None:
        self.spans.clear()
        self._stack.clear()
        self.sim_clock = None
        self.metrics.reset()
        self._next_sid = 0


# --------------------------------------------------------------------- #
# module singleton — configured from the environment at import
# --------------------------------------------------------------------- #
_TRACER = Tracer(
    enabled=os.environ.get("REPRO_TRACE", "0") not in ("", "0"),
    path=os.environ.get("REPRO_TRACE_FILE") or None)
_ATEXIT_ARMED = False


def tracer() -> Tracer:
    return _TRACER


def enabled() -> bool:
    return _TRACER.enabled


def span(name: str) -> Union[Span, _NullSpan]:
    return _TRACER.span(name)


def traced(name: str):
    """Decorator form: time every call of ``fn`` as a span ``name``."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _TRACER.enabled:
                return fn(*a, **kw)
            with _TRACER.span(name):
                return fn(*a, **kw)
        return wrapper
    return deco


def counter_inc(name: str, n: int = 1) -> None:
    if _TRACER.enabled:
        _TRACER.metrics.counter(name).inc(n)


def gauge_set(name: str, v: float) -> None:
    if _TRACER.enabled:
        _TRACER.metrics.gauge(name).set(v)


def observe(name: str, v: float) -> None:
    if _TRACER.enabled:
        _TRACER.metrics.observation(name).add(v)


def set_sim_clock(fn: Optional[Callable[[], float]]) -> None:
    """Install (or clear, with None) the simulated-clock read used to
    dual-stamp spans.  The async engine passes ``lambda: self.t_sim``
    for the duration of its event loop."""
    if _TRACER.enabled:
        _TRACER.sim_clock = fn


def jit_cache_size(fn) -> int:
    """Compile-cache entry count of a jitted callable (first-call
    probe: size grows by one exactly when a call traced a new
    specialization). -1 when the probe API is unavailable."""
    try:
        return fn._cache_size()
    except Exception:
        return -1


def configure(enabled: Optional[bool] = None, path: Optional[str] = None,
              ring_size: Optional[int] = None, reset: bool = True) -> Tracer:
    """Reconfigure the singleton (tests, drivers). Resets the ring by
    default so runs do not bleed spans into each other."""
    if enabled is not None:
        _TRACER.enabled = enabled
    if path is not None:
        _TRACER.path = path or None
    if ring_size is not None:
        _TRACER.ring_size = ring_size
    if reset:
        _TRACER.reset()
    if _TRACER.enabled and _TRACER.path:
        _arm_atexit()
    return _TRACER


# --------------------------------------------------------------------- #
# sinks
# --------------------------------------------------------------------- #
def _meta() -> Dict[str, Any]:
    """Commit+env key for a trace file — same shape as a
    ``BENCH_history.jsonl`` line's meta block."""
    meta: Dict[str, Any] = {"kind": "meta", "commit": "unknown",
                            "python": platform.python_version(),
                            "timestamp": utc_stamp()}
    try:
        r = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                           capture_output=True, text=True, timeout=10,
                           cwd=os.path.dirname(os.path.abspath(__file__)))
        if r.returncode == 0 and r.stdout.strip():
            meta["commit"] = r.stdout.strip()
    except Exception:
        pass
    for mod in ("jax", "numpy"):
        try:
            meta[mod] = __import__(mod).__version__
        except Exception:
            meta[mod] = "unknown"
    return meta


def flush_jsonl(path: Optional[str] = None) -> str:
    """Write the ring + metric snapshot as JSONL: one meta record, one
    record per span, one trailing metrics record."""
    tr = _TRACER
    path = path or tr.path
    assert path, "no trace path: pass one or set REPRO_TRACE_FILE"
    with open(path, "w") as f:
        f.write(json.dumps(_meta()) + "\n")
        for s in tr.spans:
            f.write(json.dumps(s.to_dict()) + "\n")
        f.write(json.dumps({"kind": "metrics",
                            **tr.metrics.snapshot()}) + "\n")
    return path


def load_jsonl(path: str):
    """Read a trace file back: (meta, span dicts, metrics dict)."""
    meta: Dict[str, Any] = {}
    spans: List[Dict[str, Any]] = []
    metrics: Dict[str, Any] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("kind")
            if kind == "meta":
                meta = rec
            elif kind == "span":
                spans.append(rec)
            elif kind == "metrics":
                metrics = rec
    return meta, spans, metrics


def to_trace_event(spans: Optional[Sequence[Union[Span, Dict]]] = None
                   ) -> Dict[str, Any]:
    """Chrome/Perfetto ``trace_event`` JSON (complete 'X' events, µs).
    Accepts live ``Span`` objects or span dicts from ``load_jsonl``."""
    recs = [s.to_dict() if isinstance(s, Span) else s
            for s in (_TRACER.spans if spans is None else spans)]
    base = min((r["t0"] for r in recs), default=0.0)
    evs = []
    for r in recs:
        ev: Dict[str, Any] = {"name": r["name"], "ph": "X",
                              "ts": (r["t0"] - base) * 1e6,
                              "dur": max(r["t1"] - r["t0"], 0.0) * 1e6,
                              "pid": 0, "tid": 0}
        args = dict(r.get("attrs") or {})
        if r.get("sim_t0") is not None:
            args["sim_t0"] = r["sim_t0"]
            args["sim_t1"] = r["sim_t1"]
        if args:
            ev["args"] = args
        evs.append(ev)
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def _pct(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def phase_summary(spans: Optional[Sequence[Union[Span, Dict]]] = None
                  ) -> Dict[str, Dict[str, float]]:
    """Per-phase (span name) wall-time summary: count/total/p50/p95.
    Works on the live ring or on span dicts from ``load_jsonl``."""
    recs = [s.to_dict() if isinstance(s, Span) else s
            for s in (_TRACER.spans if spans is None else spans)]
    by_name: Dict[str, List[float]] = {}
    for r in recs:
        by_name.setdefault(r["name"], []).append(r["t1"] - r["t0"])
    out: Dict[str, Dict[str, float]] = {}
    for name, durs in sorted(by_name.items()):
        durs.sort()
        out[name] = {"count": len(durs), "total_s": sum(durs),
                     "p50_s": _pct(durs, 0.50), "p95_s": _pct(durs, 0.95)}
    return out


def _flush_at_exit() -> None:
    if _TRACER.enabled and _TRACER.path:
        try:
            flush_jsonl(_TRACER.path)
        except Exception:
            pass


def _arm_atexit() -> None:
    global _ATEXIT_ARMED
    if not _ATEXIT_ARMED:
        atexit.register(_flush_at_exit)
        _ATEXIT_ARMED = True


if _TRACER.enabled and _TRACER.path:
    _arm_atexit()
