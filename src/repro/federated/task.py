"""Task abstraction: the model/data pair is a first-class sweep axis.

Before this layer the federated stack hard-coded the paper's experimental
model — ``models/mlp.py`` on synthetic-MNIST — into every plane: the
padded cohort engine trained ``(K, S, 784)/(K, S)`` feature/label arrays
through ``mlp_sgd_epoch_masked``, the server's quality statistics were
label histograms, and evaluation meant class-masked test accuracy. A
``FeelTask`` generalizes that contract so DQS scheduling (Eq. 1-3,
Alg. 1/2) runs unchanged over ANY pytree of batchable per-sample arrays
and ANY pytree model:

    data plane  — generate / partition / histogram / gini: the task owns
        its dataset type (``Dataset`` / ``TokenDataset``), the group-based
        non-IID allocation constants, and the metadata a UE reports (class
        histogram for MNIST; token histogram for the LM — quality is
        measured on what the model LEARNS, not the partition sort key).
    device plane — init_params / sgd_epoch / local_metric /
        predict_units / eval_loss: jit-static methods (tasks are frozen,
        hashable dataclasses) the cohort engine vmaps over the client
        axis. The padded/masked contract is unchanged: zero-padded rows
        with mask 0 contribute exactly zero gradient.
    eval units  — the task defines the atomic prediction "unit" the
        reputation machinery scores: MNIST units are test SAMPLES, LM
        units are the ``W x (seq-1)`` next-token TARGET POSITIONS of the
        held-out windows. Per-UE support masks (Eq. 1's class-restricted
        acc_test, DESIGN.md §2) become unit masks via each UE's claimed
        histogram; the watched (source, target) attack metrics ride on
        units too, so ``attack_success`` means "fraction of watched
        source-token positions decoded as the attack's target token" for
        the LM — the exact analogue of the MNIST definition. Masked unit
        accuracies are sums of {0,1} float32 counts (< 2^24), so subset
        and masked-full evaluations agree bit-for-bit.
    loop oracle — local_train / eval_units_loop / global_metrics: the
        sequential host paths (``engine="loop"``, ``control="host"``)
        each task keeps as its parity oracle; the MNIST task delegates to
        the exact pre-refactor code (``federated.client.local_train``,
        ``models.mlp``), which is what pins the refactor to the golden
        curves.

``TASKS`` registers the two concrete tasks:

    mnist_mlp — the paper's §V protocol, bit-parity with the
        pre-task-abstraction stack.
    lm_tiny   — federated fine-tuning of a 2-layer decoder-only
        transformer (``models/transformer.py`` through the shared blocks
        stack, so ``REPRO_USE_PALLAS=1`` routes its attention through the
        Pallas flash kernel) on synthetic domain-skewed token windows
        (``data/tokens.py``). Clients hold fixed-length windows from a
        Zipf-Markov stream; domains play the non-IID role of MNIST
        labels for the partition, while quality statistics and eval
        masks are computed over the TOKENS.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.diversity import gini_simpson, gini_simpson_hist
from repro.data.partition import (GROUP_SIZE, MAX_GROUPS, MIN_GROUPS,
                                  label_histogram, partition)
from repro.data.synthetic_mnist import N_CLASSES, generate
from repro.data.tokens import make_windows
from repro.federated.client import ClientReport, local_train
from repro.models.mlp import (mlp_accuracy, mlp_accuracy_masked, mlp_apply,
                              mlp_init, mlp_sgd_epoch_masked)
from repro.models.transformer import (lm_accuracy_masked, lm_forward,
                                      lm_init, lm_loss, lm_sgd_epoch,
                                      lm_sgd_epoch_masked)


class FeelTask:
    """Interface every task implements (see module docstring).

    Tasks are frozen dataclasses: hashable and eq-comparable, so they pass
    through ``jax.jit`` as static arguments and key compile caches — two
    servers configured with the same task share every compiled cohort
    program.

    Host/data plane:  generate_data, partition_clients, histogram, gini.
    Eval units:       unit_labels, unit_rows, eval_inputs, unit_targets.
    Device plane:     init_params, sgd_epoch, local_metric, predict_units,
                      eval_loss (None when the task has no loss metric).
    Loop oracle:      local_train, eval_units_loop, global_metrics.
    Protocol knobs:   group_size/min_groups/max_groups (partition),
                      batch_size, default_lr, default_n_train/_n_test.
    """

    name: str


@dataclasses.dataclass(frozen=True)
class MnistTask(FeelTask):
    """The paper's §V protocol: 2-layer MLP on synthetic MNIST.

    Every method delegates to the exact pre-task-abstraction code path
    (``models/mlp.py``, ``federated/client.py``, ``data/partition.py``
    defaults), which is what keeps the refactored stack bit-identical to
    the golden curves recorded before the task layer existed.
    """
    name: str = "mnist_mlp"
    n_symbols: int = N_CLASSES
    group_size: int = GROUP_SIZE
    min_groups: int = MIN_GROUPS
    max_groups: int = MAX_GROUPS
    batch_size: int = 50
    default_lr: float = 0.1
    default_n_train: int = 50_000
    default_n_test: int = 10_000

    # -- host/data plane ------------------------------------------------ #
    def generate_data(self, n_train: int, n_test: int, seed: int):
        return generate(n_train, n_test, seed=seed)

    def partition_clients(self, train, n_ues, rng, malicious=None,
                          attack=None, context=""):
        return partition(train, n_ues, rng, malicious, attack,
                         group_size=self.group_size,
                         min_groups=self.min_groups,
                         max_groups=self.max_groups,
                         context=context or f"task={self.name}")

    def histogram(self, data) -> np.ndarray:
        """What a UE reports: its label histogram (claimed class support)."""
        return label_histogram(data, self.n_symbols)

    def gini(self, data) -> float:
        """Eq. 2 elements diversity: Gini-Simpson over label frequencies."""
        return gini_simpson(data.y, self.n_symbols)

    # -- eval units (host) ----------------------------------------------- #
    def unit_labels(self, test) -> np.ndarray:
        return np.asarray(test.y)

    def unit_rows(self, test) -> np.ndarray:
        return np.arange(len(test.y))

    def eval_inputs(self, test):
        return {"x": jnp.asarray(test.x)}

    def unit_targets(self, test):
        return jnp.asarray(test.y)

    # -- device plane (static under jit) ---------------------------------- #
    def init_params(self, key):
        return mlp_init(key)

    def sgd_epoch(self, params, d, m, lr, batch_size: int):
        return mlp_sgd_epoch_masked(params, d["x"], d["y"], m, lr,
                                    batch_size)

    def local_metric(self, params, d, m):
        return mlp_accuracy_masked(params, d["x"], d["y"], m)

    def predict_units(self, params, ei):
        return jnp.argmax(mlp_apply(params, ei["x"]), -1)

    def eval_loss(self, params, ei):
        return None          # accuracy is the task's only global metric

    # -- loop oracle (host) ------------------------------------------------ #
    def local_train(self, client, global_params, epochs: int, lr: float,
                    batch_size: int) -> ClientReport:
        return local_train(client, global_params, epochs, lr,
                           batch_size=batch_size)

    def eval_units_loop(self, params, test, m: np.ndarray) -> float:
        if not m.any():
            return 0.0
        return float(mlp_accuracy(params, jnp.asarray(test.x[m]),
                                  jnp.asarray(test.y[m])))

    def global_metrics(self, params, test, ei, ey, watch_class,
                       watch_target):
        """(global_acc, global_loss, source_acc, attack_success)."""
        g_acc = float(mlp_accuracy(params, ei["x"], ey))
        src_acc = atk_succ = float("nan")
        if watch_class is not None:
            m = test.y == watch_class
            if m.any():
                xs = jnp.asarray(test.x[m])
                src_acc = float(mlp_accuracy(
                    params, xs, jnp.asarray(test.y[m])))
                if watch_target is not None:
                    tgt = jnp.full(int(m.sum()), watch_target, ey.dtype)
                    atk_succ = float(mlp_accuracy(params, xs, tgt))
        return g_acc, float("nan"), src_acc, atk_succ


# 2-layer decoder-only transformer, small enough that a full federated
# sweep runs in seconds yet large enough to learn the Zipf-Markov bigram
# structure. seq=32 is a multiple of 8, so with REPRO_USE_PALLAS=1 its
# attention dispatches to the Pallas flash kernel (models/attention.py).
LM_TINY = ModelConfig(name="lm-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=64, dtype="float32")


@partial(jax.jit, static_argnums=0)
def _lm_predict(cfg, params, tokens):
    """(W, S) tokens -> (W*(S-1),) greedy next-token predictions (units)."""
    logits, _, _, _ = lm_forward(cfg, params, tokens,
                                 window=cfg.sliding_window)
    return jnp.argmax(logits[:, :-1], -1).reshape(-1)


@dataclasses.dataclass(frozen=True)
class LmTask(FeelTask):
    """Federated LM fine-tuning on synthetic domain-skewed token windows.

    Clients hold ``(n, seq)`` int32 windows cut from per-domain Zipf-Markov
    streams (``data/tokens.py::make_windows``); the window's domain id is
    the partition sort key (the non-IID role MNIST labels play), while the
    server-visible quality metadata — histogram, Gini-Simpson diversity,
    eval support masks — is computed over the TOKENS the model actually
    learns. Evaluation units are the held-out windows' next-token target
    positions; ``eval_loss`` adds the held-out per-token cross-entropy as
    the global quality metric (RoundLog.global_loss).
    """
    name: str = "lm_tiny"
    model: ModelConfig = LM_TINY
    seq: int = 32
    n_domains: int = 10
    group_size: int = 16
    min_groups: int = 1
    max_groups: int = 8
    batch_size: int = 8
    default_lr: float = 0.3
    default_n_train: int = 2_000
    default_n_test: int = 400

    @property
    def n_symbols(self) -> int:
        return self.model.vocab_size

    # -- host/data plane ------------------------------------------------ #
    def generate_data(self, n_train: int, n_test: int, seed: int):
        ds = make_windows(n_train + n_test, self.model.vocab_size, self.seq,
                          n_domains=self.n_domains, seed=seed)
        idx = np.arange(n_train + n_test)
        # windows are domain-interleaved, so a head/tail split keeps both
        # sides domain-balanced
        return ds.subset(idx[:n_train]), ds.subset(idx[n_train:])

    def partition_clients(self, train, n_ues, rng, malicious=None,
                          attack=None, context=""):
        return partition(train, n_ues, rng, malicious, attack,
                         group_size=self.group_size,
                         min_groups=self.min_groups,
                         max_groups=self.max_groups,
                         context=context or f"task={self.name}")

    def histogram(self, data) -> np.ndarray:
        """What a UE reports: its token histogram (claimed vocab support)."""
        return np.bincount(data.tokens.reshape(-1).astype(int),
                           minlength=self.model.vocab_size)

    def gini(self, data) -> float:
        """Eq. 2 elements diversity: Gini-Simpson over token frequencies —
        a client stuck on one domain's narrow vocabulary scores low just
        like a single-class MNIST client does."""
        return gini_simpson_hist(self.histogram(data))

    # -- eval units (host) ----------------------------------------------- #
    def unit_labels(self, test) -> np.ndarray:
        return np.asarray(test.tokens[:, 1:]).reshape(-1)

    def unit_rows(self, test) -> np.ndarray:
        return np.repeat(np.arange(len(test)), self.seq - 1)

    def eval_inputs(self, test):
        return {"tokens": jnp.asarray(test.tokens)}

    def unit_targets(self, test):
        return jnp.asarray(test.tokens[:, 1:].reshape(-1))

    # -- device plane (static under jit) ---------------------------------- #
    def init_params(self, key):
        return lm_init(key, self.model)

    def sgd_epoch(self, params, d, m, lr, batch_size: int):
        return lm_sgd_epoch_masked(self.model, params, d["tokens"], m, lr,
                                   batch_size)

    def local_metric(self, params, d, m):
        return lm_accuracy_masked(self.model, params, d["tokens"], m)

    def predict_units(self, params, ei):
        logits, _, _, _ = lm_forward(self.model, params, ei["tokens"],
                                     window=self.model.sliding_window)
        return jnp.argmax(logits[:, :-1], -1).reshape(-1)

    def eval_loss(self, params, ei):
        """Held-out per-token cross-entropy (the LM quality metric)."""
        return lm_loss(self.model, params, {"tokens": ei["tokens"]})[0]

    # -- loop oracle (host) ------------------------------------------------ #
    def local_train(self, client, global_params, epochs: int, lr: float,
                    batch_size: int) -> ClientReport:
        tokens = jnp.asarray(client.data.tokens)
        params = global_params
        for _ in range(epochs):
            params = lm_sgd_epoch(self.model, params, tokens, lr,
                                  batch_size)
        m = jnp.ones(tokens.shape[0], jnp.float32)
        acc = float(lm_accuracy_masked(self.model, params, tokens, m))
        return ClientReport(ue_id=client.ue_id, params=params,
                            acc_local=acc, n_samples=client.size)

    def eval_units_loop(self, params, test, m: np.ndarray) -> float:
        if not m.any():
            return 0.0
        pred = np.asarray(_lm_predict(self.model, params,
                                      jnp.asarray(test.tokens)))
        return _f32_masked_acc(pred == self.unit_labels(test), m)

    def global_metrics(self, params, test, ei, ey, watch_class,
                       watch_target):
        """(global_acc, global_loss, source_acc, attack_success) — unit
        accuracy + held-out per-token CE; the watched pair is a (source,
        target) TOKEN pair (core.attacks.TokenFlip)."""
        pred = np.asarray(_lm_predict(self.model, params, ei["tokens"]))
        labels = self.unit_labels(test)
        ones = np.ones(labels.size, bool)
        g_acc = _f32_masked_acc(pred == labels, ones)
        g_loss = float(self.eval_loss(params, ei))
        src_acc = atk_succ = float("nan")
        if watch_class is not None:
            m = labels == watch_class
            if m.any():
                src_acc = _f32_masked_acc(pred == watch_class, m)
                if watch_target is not None:
                    atk_succ = _f32_masked_acc(pred == watch_target, m)
        return g_acc, g_loss, src_acc, atk_succ


def _f32_masked_acc(correct: np.ndarray, m: np.ndarray) -> float:
    """Masked accuracy with ``cohort.cohort_eval``'s float32 arithmetic
    (exact-integer f32 sums, f32 division) so the loop engine's host-side
    Eq. 1 inputs are BIT-equal to the vectorized engine's device evals —
    a float64 ``.mean()`` here would differ in the last mantissa bit and
    fork the reputation streams."""
    num = np.float32((correct & m).sum())
    den = np.maximum(np.float32(m.sum()), np.float32(1.0))
    return float(num / den)


TASKS = {t.name: t for t in (MnistTask(), LmTask())}


def as_task(spec) -> FeelTask:
    """Normalize a task spec: FeelTask instance (pass-through) or registry
    name. The single resolution point — server, drivers and benches all
    accept either form."""
    if isinstance(spec, FeelTask):
        return spec
    if isinstance(spec, str):
        try:
            return TASKS[spec]
        except KeyError:
            raise KeyError(f"unknown task {spec!r}; registered: "
                           f"{sorted(TASKS)}") from None
    raise TypeError(f"task spec must be a FeelTask or registry name, "
                    f"got {type(spec).__name__}")
