"""The FeelTask abstraction: LM task wiring, masked-loss contract, stream-v2
golden regression, token attacks, round-scheduled data attacks (twin-array
gather), and the mixed-task sweep grid."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.data.partition import partition
from repro.data.synthetic_mnist import generate
from repro.data.tokens import make_stream, make_windows
from repro.federated.server import build_cohort_data
from repro.federated.simulation import run_experiment, run_sweep
from repro.federated.task import (LM_TINY, TASKS, LmTask, MnistTask,
                                  as_task)
from repro.models.transformer import (lm_init, lm_loss, lm_loss_masked,
                                      lm_sgd_epoch, lm_sgd_epoch_masked)

LM_KW = dict(task="lm_tiny", n_train=960, n_test=240, rounds=2)


def _curves_equal(a, b, fields=("acc", "loss", "objective",
                                "attack_success", "malicious_selected")):
    return all(np.array_equal(np.asarray(a[f], float),
                              np.asarray(b[f], float), equal_nan=True)
               for f in fields)


# ---------------------------------------------------------------------- #
# Task registry
# ---------------------------------------------------------------------- #
def test_task_registry():
    assert as_task("mnist_mlp") is as_task("mnist_mlp")   # singleton
    lm = as_task("lm_tiny")
    assert isinstance(lm, LmTask) and lm.n_symbols == LM_TINY.vocab_size
    assert as_task(lm) is lm
    with pytest.raises(KeyError):
        as_task("nope")
    with pytest.raises(TypeError):
        as_task(7)
    # frozen dataclasses -> hashable -> usable as jit static args
    assert hash(as_task("lm_tiny")) == hash(LmTask())


# ---------------------------------------------------------------------- #
# Stream v2 golden regression (satellite: vectorized make_stream).
# The rewrite re-versioned the per-seed streams intentionally; these
# anchors pin the NEW streams so future edits can't silently drift them.
# ---------------------------------------------------------------------- #
def test_make_stream_v2_golden():
    s = make_stream(200_000, 64, seed=0)
    assert s.dtype == np.int32 and s.shape == (200_000,)
    assert int(s.sum()) == 4073655
    np.testing.assert_array_equal(
        s[:10], [54, 17, 22, 49, 17, 2, 2, 0, 7, 1])
    assert s.min() >= 0 and s.max() < 64


def test_make_stream_domain_and_determinism():
    a = make_stream(5_000, 64, seed=3, domain=1)
    assert np.array_equal(a, make_stream(5_000, 64, seed=3, domain=1))
    b = make_stream(5_000, 64, seed=3, domain=2)
    assert not np.array_equal(a, b)          # domains shift the kernel
    assert make_stream(0, 64).size == 0


def test_make_windows_balanced_and_typed():
    ds = make_windows(103, 64, seq=32, n_domains=10, seed=0)
    assert ds.tokens.shape == (103, 32) and ds.tokens.dtype == np.int32
    assert len(ds) == 103
    # round-robin interleave: truncation stays domain-balanced within 1
    counts = np.bincount(ds.y, minlength=10)
    assert counts.max() - counts.min() <= 1
    sub = ds.subset(np.arange(7))
    assert len(sub) == 7 and np.array_equal(sub.y, ds.y[:7])


# ---------------------------------------------------------------------- #
# Masked LM loss contract (satellite: lm_loss masking tests)
# ---------------------------------------------------------------------- #
def test_lm_loss_masked_invariant_to_padded_content():
    params = lm_init(jax.random.PRNGKey(0), LM_TINY)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (8, 32)).astype(np.int32)
    m = np.array([1, 1, 1, 1, 0, 0, 0, 0], np.float32)
    scrambled = toks.copy()
    scrambled[4:] = rng.integers(0, 64, (4, 32))   # junk in padded rows

    l0, _ = lm_loss_masked(LM_TINY, params, {"tokens": jnp.asarray(toks),
                                             "m": jnp.asarray(m)})
    l1, _ = lm_loss_masked(LM_TINY, params,
                           {"tokens": jnp.asarray(scrambled),
                            "m": jnp.asarray(m)})
    assert float(l0) == float(l1)
    # fully valid batch reduces to the plain lm_loss
    full, _ = lm_loss(LM_TINY, params, {"tokens": jnp.asarray(toks[:4])})
    ones = jnp.ones(4, jnp.float32)
    masked, _ = lm_loss_masked(LM_TINY, params,
                               {"tokens": jnp.asarray(toks[:4]), "m": ones})
    np.testing.assert_allclose(float(masked), float(full), rtol=1e-6)


def test_lm_masked_gradient_zero_for_padded_rows():
    """Padded rows contribute exactly zero gradient: the masked epoch over
    a padded window set bit-matches the plain epoch over the real rows."""
    params = lm_init(jax.random.PRNGKey(1), LM_TINY)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 64, (16, 32)).astype(np.int32)
    plain = lm_sgd_epoch(LM_TINY, params, jnp.asarray(toks), 0.3, 8)

    padded = np.concatenate([toks, rng.integers(0, 64, (8, 32))]).astype(
        np.int32)
    m = np.concatenate([np.ones(16), np.zeros(8)]).astype(np.float32)
    masked = lm_sgd_epoch_masked(LM_TINY, params, jnp.asarray(padded),
                                 jnp.asarray(m), 0.3, 8)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(masked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # an all-padded batch is a strict parameter no-op
    g = jax.grad(lambda p: lm_loss_masked(
        LM_TINY, p, {"tokens": jnp.asarray(toks[:8]),
                     "m": jnp.zeros(8, jnp.float32)})[0])(params)
    assert all(not np.asarray(l).any() for l in jax.tree.leaves(g))


# ---------------------------------------------------------------------- #
# LM cohort engine parity (satellite: K=8 loop vs vectorized)
# ---------------------------------------------------------------------- #
def test_lm_cohort_loop_vs_vectorized_k8():
    """The loop engine is the LM parity oracle too: a K=8 federated LM
    fine-tuning run is BIT-identical across engines."""
    cfg = FeelConfig(n_ues=8, n_malicious=2)
    a = run_experiment("dqs", cfg=cfg, seed=0, scenario="token_flip_1to5",
                       engine="loop", control="host", **LM_KW)
    b = run_experiment("dqs", cfg=cfg, seed=0, scenario="token_flip_1to5",
                       engine="vectorized", control="host", **LM_KW)
    assert _curves_equal(a, b)
    assert np.isfinite(a["loss"]).all()      # LM defines the loss metric


# ---------------------------------------------------------------------- #
# Token-space data attacks
# ---------------------------------------------------------------------- #
def test_token_flip_rewrites_source_tokens_only():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (20, 32)).astype(np.int32)
    out = atk.TokenFlip(((1, 5),)).poison_tokens(toks, rng)
    assert out.shape == toks.shape and out.dtype == toks.dtype
    assert not (out == 1).any()                       # every source rewritten
    np.testing.assert_array_equal(out[toks != 1], toks[toks != 1])
    assert (out[toks == 1] == 5).all()
    # pairs resolve on the ORIGINAL tokens: (1->5, 5->9) never cascades
    chained = atk.TokenFlip(((1, 5), (5, 9))).poison_tokens(toks, rng)
    assert (chained[toks == 1] == 5).all()
    assert (chained[toks == 5] == 9).all()


def test_token_flip_fraction_subsamples():
    rng = np.random.default_rng(0)
    toks = np.full((10, 32), 1, np.int32)
    out = atk.TokenFlip(((1, 5),), flip_fraction=0.25).poison_tokens(
        toks, np.random.default_rng(1))
    flipped = int((out == 5).sum())
    assert flipped == int(round(0.25 * toks.size))


def test_token_attack_needs_token_dataset():
    """The mismatch error names the offending sweep cell (task AND
    scenario), not just the dataset types — a task x scenario sweep hits
    this far from where the pairing was configured."""
    cfg = FeelConfig(n_ues=4, n_malicious=1)
    with pytest.raises(AssertionError, match="token-space attack") as ei:
        run_experiment("dqs", cfg=cfg, seed=0, rounds=1, task="mnist_mlp",
                       scenario="token_flip_1to5", n_train=800, n_test=200)
    assert "task=mnist_mlp" in str(ei.value)
    assert "scenario=token_flip_1to5" in str(ei.value)


def test_token_noise_rate():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (200, 32)).astype(np.int32)
    out = atk.TokenNoise(0.3, 64).poison_tokens(toks,
                                                np.random.default_rng(2))
    changed = (out != toks).mean()
    # ~rate of positions redrawn (binomial noise, and a redraw can land on
    # the original token): the changed-rate concentrates just under 0.3
    assert 0.15 < changed < 0.35


# ---------------------------------------------------------------------- #
# Round-scheduled data attacks: twin-array gather (carry-over satellite)
# ---------------------------------------------------------------------- #
def test_intermittent_data_attack_engine_parity():
    """A round-scheduled label-flip (previously REJECTED at construction)
    runs, and the vectorized twin-row gather matches the loop oracle's
    per-round data substitution bit for bit."""
    cfg = FeelConfig(n_ues=10, n_malicious=2)
    kw = dict(cfg=cfg, seed=0, scenario="flip_6to2_int2", n_train=2000,
              n_test=400, rounds=4)
    a = run_experiment("dqs", engine="loop", control="host", **kw)
    b = run_experiment("dqs", engine="vectorized", control="host", **kw)
    # MNIST engine parity is approximate by contract (the loop oracle
    # evaluates label SUBSETS, the vectorized engine masked full-test
    # passes — see test_cohort.py); the LM task's parity is bit-exact
    assert b["malicious_selected"] == a["malicious_selected"]
    for f in ("acc", "source_acc", "attack_success", "objective"):
        np.testing.assert_allclose(b[f], a[f], atol=1e-5)


def test_intermittent_period1_equals_always_on():
    """duty-cycle period 1 == always active: the scheduled scenario must
    reproduce the plain label flip exactly (the twin mapping degenerates
    to the identity)."""
    cfg = FeelConfig(n_ues=10, n_malicious=2)
    kw = dict(cfg=cfg, seed=0, n_train=2000, n_test=400, rounds=3)
    always = run_experiment("dqs", scenario="flip_6to2", **kw)
    int1 = run_experiment("dqs", scenario=atk.intermittent(
        atk.label_flip(6, 2), period=1), **kw)
    assert _curves_equal(always, int1, fields=("acc", "source_acc",
                                               "attack_success",
                                               "objective"))


def test_cohort_data_twin_layout():
    """CohortData buckets lay rows out [real | clean twins | null]:
    malicious clients (which carry a ``clean`` pre-poison copy) get a twin
    row holding the CLEAN data, mapped via ``clean_row_of``."""
    train, test = generate(2000, 200, seed=0)
    rng = np.random.default_rng(0)
    mal = np.array([1, 3])
    clients = partition(train, 6, rng, mal, atk.LabelFlip(((6, 2),)))
    task = MnistTask()
    masks = np.ones((6, len(test.y)), np.float32)
    cd = build_cohort_data(clients, masks, batch_size=50)
    for k in range(6):
        if k in mal:
            assert clients[k].clean is not None
            tw = int(cd.clean_row_of[k])
            assert tw >= 0
            b = cd.buckets[cd.bucket_of[k]]
            tw_local = tw  # row ids are bucket-local in single-bucket runs
            n = clients[k].size
            got = np.asarray(b["data"]["y"][tw_local][:n])
            np.testing.assert_array_equal(got, clients[k].clean.y)
            assert (np.asarray(b["data"]["y"][cd.row_of[k]][:n])
                    == clients[k].data.y).all()
        else:
            assert clients[k].clean is None
            assert cd.clean_row_of[k] == -1


# ---------------------------------------------------------------------- #
# The model as a sweep axis (tentpole acceptance)
# ---------------------------------------------------------------------- #
def test_mixed_task_sweep_grid():
    """ONE run_sweep invocation executes a (task x scenario x policy x
    seed) grid containing BOTH tasks, and every run matches its
    sequential ``run_experiment`` twin."""
    cfg = FeelConfig(n_ues=8, n_malicious=2)
    res = run_sweep(["dqs", "random"], seeds=[0],
                    tasks=["mnist_mlp", "lm_tiny"],
                    scenarios=["none", "sign_flip"],
                    cfg=cfg, n_train=960, n_test=240, rounds=2)
    assert {r["task"] for r in res.runs} == {"mnist_mlp", "lm_tiny"}
    assert len(res.runs) == 2 * 2 * 2
    # loss curves: finite for the LM task, NaN for the MLP task
    for r in res.runs:
        fin = np.isfinite(r["loss"])
        assert fin.all() if r["task"] == "lm_tiny" else not fin.any()
    for r in res.runs:
        twin = run_experiment(r["policy"], cfg=cfg, seed=r["seed"],
                              task=r["task"], scenario=r["scenario"],
                              n_train=960, n_test=240, rounds=2)
        for f in ("acc", "loss", "objective", "malicious_selected"):
            a, b = np.asarray(r[f], float), np.asarray(twin[f], float)
            nan = np.isnan(a)
            assert np.array_equal(nan, np.isnan(b))
            np.testing.assert_allclose(np.where(nan, 0, a),
                                       np.where(nan, 0, b), atol=1e-7)
    # the tidy table slices per task
    curve = res.mean_curve("loss", task="lm_tiny", policy="dqs",
                           scenario="none")
    assert np.isfinite(curve).all() and curve.shape == (2,)


def test_run_experiment_task_defaults():
    """n_train/n_test default per task; an unknown task name fails loudly
    before any work happens."""
    cfg = FeelConfig(n_ues=4, n_malicious=0)
    r = run_experiment("random", cfg=cfg, seed=0, rounds=1, task="lm_tiny",
                       n_train=320, n_test=80, scenario="none")
    assert r["task"] == "lm_tiny" and len(r["acc"]) == 1
    with pytest.raises(KeyError, match="unknown task"):
        run_experiment("dqs", cfg=dataclasses.replace(cfg, task="nope"),
                       seed=0, rounds=1)


# ---------------------------------------------------------------------- #
# registry completeness (auto-generated from TASKS — a new entry is
# exercised here with zero test edits; repro.check pins the coverage)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(TASKS))
def test_task_registry_contract(name):
    """Every registered task satisfies the FeelTask interface (task.py
    module docstring): registry key == name, frozen/hashable (tasks key
    jit compile caches as static args), every plane's methods present,
    and the protocol knobs sane."""
    t = TASKS[name]
    assert t.name == name and as_task(name) is t
    hash(t)                                     # static-arg contract
    assert dataclasses.is_dataclass(t)
    assert type(t).__dataclass_params__.frozen
    for method in (
            # host/data plane
            "generate_data", "partition_clients", "histogram", "gini",
            # eval units
            "unit_labels", "unit_rows", "eval_inputs", "unit_targets",
            # device plane
            "init_params", "sgd_epoch", "local_metric", "predict_units",
            # loop oracle
            "local_train", "eval_units_loop", "global_metrics"):
        assert callable(getattr(t, method)), (name, method)
    assert t.group_size >= 1
    assert 1 <= t.min_groups <= t.max_groups
    assert t.batch_size >= 1 and t.default_lr > 0
    assert t.default_n_train > 0 and t.default_n_test > 0
