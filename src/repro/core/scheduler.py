"""Joint UE selection + bandwidth allocation (paper §IV, Algorithm 2).

Problem (8) — maximise ``sum_k x_k V_k`` subject to the round deadline (8b),
total bandwidth (8c/8d) and binary selection (8e) — is knapsack-equivalent
(NP-hard). DQS solves it greedily: compute each UE's bandwidth *cost* ``c_k``
(minimum number of uniform 1/K fractions meeting its minimum rate, Eq. 9),
order by ``V_k / c_k`` decreasing, and pack into the budget of K fractions.

Approximation guarantee: density-greedy alone can be arbitrarily bad (one
expensive high-value UE displaced by a cheap low-value one that blocks the
budget), so ``dqs_schedule`` finishes with the classic modified-greedy step —
take the better of the greedy pack and the single best feasible UE — which
guarantees ``objective >= OPT / 2`` (tests/test_scheduler.py pins this
against ``brute_force_schedule`` on random instances).

Every packing policy is one *priority key* feeding one shared greedy-packing
primitive: sort ascending by the key, then walk the order consuming the
budget of K fractions, SKIPPING any UE whose cost does not fit (a later,
cheaper UE may still fit — this is not a prefix-sum take-while, see
``greedy_pack``). ``priority_key`` builds the key per policy:

    dqs          -(V_k / c_k)          (Alg. 2 density order)
    random       inverse permutation    (uniform order, Li et al. style)
    best_channel c_k*K - gains/max      (Nishio & Yonetani: good channels)
    max_count    c_k                    (Zeng et al.: cheapest first)

``top_value`` (paper §V-B.1) is the one non-packing policy: top-N by value,
no wireless constraint. ``greedy_pack_jnp`` is the jit/vmap-able twin of the
packing primitive used by the batched control plane (core/control.py); a
lax.scan carries the remaining budget so the skipping semantics match the
host loop exactly.

Baseline policies used by the paper's comparison figures are provided too,
plus a brute-force exact solver for small K (test oracle for the NP-hard
claim).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FeelConfig
from repro.core.wireless import WirelessModel


@dataclasses.dataclass
class Schedule:
    x: np.ndarray          # (K,) bool selection
    alpha: np.ndarray      # (K,) bandwidth fractions, sum <= 1
    cost: np.ndarray       # (K,) c_k in fractions (K+1 = infeasible)
    value: np.ndarray      # (K,) V_k used for the decision

    @property
    def selected(self) -> np.ndarray:
        return np.flatnonzero(self.x)

    def objective(self) -> float:
        return float(self.value[self.x].sum())


# ---------------------------------------------------------------------- #
# The shared packing primitive + per-policy priority keys
# ---------------------------------------------------------------------- #
def greedy_pack(order: np.ndarray, costs: np.ndarray, k: int):
    """Walk ``order`` packing UEs into a budget of ``k`` fractions.

    A UE whose cost exceeds the *remaining* budget (or the deadline, c > K)
    is skipped and the walk continues — later cheaper UEs may still fit.
    The selection width is ``len(costs)`` (the candidate population N);
    ``k`` is only the budget of fractions — N == k in the legacy regime,
    N > k under a population cut (DESIGN.md §12).
    Returns (x bool (N,), alpha (N,)).
    """
    x = np.zeros(len(costs), bool)
    alpha = np.zeros(len(costs))
    budget = k
    for u in order:
        c = int(costs[u])
        if c <= k and budget - c >= 0:
            x[u] = True
            alpha[u] = c / k
            budget -= c
    return x, alpha


def priority_key(policy: str, values, costs, k: int,
                 gains=None, rand_rank=None):
    """Ascending-sort key whose stable argsort reproduces each packing
    policy's visit order (see module docstring).

    Pure elementwise expressions over the LAST (UE) axis, polymorphic in
    numpy/jnp and in leading batch (run) axes — the ONE definition of
    every policy's order, evaluated identically by the host oracle and
    both batched control-plane kernel layouts (core/control.py).
    ``rand_rank`` is the inverse permutation of the ``random`` policy's
    visit order (sorting it ascending reproduces the permutation).
    """
    if policy == "dqs":
        return -(values / costs)
    if policy == "random":
        return rand_rank
    if policy == "best_channel":
        return costs * k - gains / (gains.max(-1, keepdims=True) + 1e-12)
    if policy == "max_count":
        return costs
    raise KeyError(policy)


def pack_scan(c_sorted, k: int):
    """Take-mask of the skipping greedy over PRE-SORTED costs (..., K).

    NOT a masked prefix-sum take-while: the host oracle SKIPS a UE that
    does not fit the remaining budget and keeps walking, so whether
    position i is packed depends on every prior decision — a lax.scan
    carries the remaining budget through the K sorted positions (O(K)
    sequential steps, all leading batch axes advancing together).
    """
    init = jnp.full(c_sorted.shape[:-1], k, c_sorted.dtype)

    def step(budget, c):
        take = (c <= k) & (c <= budget)
        return budget - jnp.where(take, c, 0), take

    _, take = jax.lax.scan(step, init, jnp.moveaxis(c_sorted, -1, 0))
    return jnp.moveaxis(take, 0, -1)


def greedy_pack_jnp(sort_key, costs, k: int):
    """jit/vmap-able twin of ``greedy_pack`` for the batched control plane:
    stable argsort of the priority key, then the ``pack_scan`` budget walk.
    Width-polymorphic like ``greedy_pack``: selection width is
    ``costs.shape[-1]`` (N), ``k`` is only the budget.
    ``costs`` int32; returns (x bool (N,), alpha float (N,))."""
    order = jnp.argsort(sort_key, stable=True)
    take = pack_scan(jnp.take(costs, order), k)
    x = jnp.zeros(costs.shape, bool).at[order].set(take)
    alpha = jnp.where(x, costs.astype(sort_key.dtype) / k, 0.0)
    return x, alpha


def dqs_schedule(values: np.ndarray, costs: np.ndarray,
                 cfg: FeelConfig) -> Schedule:
    """Algorithm 2: greedy knapsack by V_k / c_k over a budget of K fractions,
    then the modified-greedy fallback (see module docstring): if the single
    best feasible UE beats the whole greedy pack, schedule it alone — this is
    what makes the 1/2-approximation bound hold."""
    K = cfg.n_ues
    order = np.argsort(priority_key("dqs", values, costs, K), kind="stable")
    x, alpha = greedy_pack(order, costs, K)
    feas = costs <= K
    if feas.any():
        k_best = int(np.flatnonzero(feas)[np.argmax(values[feas])])
        if values[k_best] > values[x].sum():
            x = np.zeros(len(values), bool)
            x[k_best] = True
            alpha = np.zeros(len(values))
            alpha[k_best] = costs[k_best] / K
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def brute_force_schedule(values: np.ndarray, costs: np.ndarray,
                         cfg: FeelConfig, max_k: int = 16) -> Schedule:
    """Exact knapsack by enumeration — oracle for tests (K <= max_k).

    Same semantics as the greedy path: the fraction budget comes from
    ``cfg.n_ues`` (the seed ignored ``cfg`` and used ``len(values)``, which
    silently changed the budget whenever the two disagreed); the candidate
    width is ``len(values)`` — N of a population cut, K otherwise."""
    K = cfg.n_ues
    N = len(values)
    assert N >= K, (N, K)
    assert N <= max_k, "brute force limited to small instances"
    best, best_x = -1.0, np.zeros(N, bool)
    feas = [k for k in range(N) if costs[k] <= K]
    for r in range(len(feas) + 1):
        for combo in itertools.combinations(feas, r):
            c = sum(int(costs[k]) for k in combo)
            if c <= K:
                v = float(values[list(combo)].sum()) if combo else 0.0
                if v > best:
                    best = v
                    best_x = np.zeros(N, bool)
                    best_x[list(combo)] = True
    alpha = np.where(best_x, costs / K, 0.0)
    return Schedule(x=best_x, alpha=alpha, cost=costs, value=values)


# ---------------------------------------------------------------------- #
# Baseline policies (paper §II / §V comparisons)
# ---------------------------------------------------------------------- #
def random_schedule(values, costs, cfg, rng) -> Schedule:
    """Random feasible packing (ignores data quality)."""
    K = cfg.n_ues
    x, alpha = greedy_pack(rng.permutation(len(values)), costs, K)
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def best_channel_schedule(values, costs, cfg, gains) -> Schedule:
    """Nishio & Yonetani-style: prioritise good channels (min cost first)."""
    K = cfg.n_ues
    order = np.argsort(priority_key("best_channel", values, costs, K,
                                    gains=gains), kind="stable")
    x, alpha = greedy_pack(order, costs, K)
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def max_count_schedule(values, costs, cfg) -> Schedule:
    """Zeng et al.-style: maximise the number of scheduled UEs."""
    K = cfg.n_ues
    order = np.argsort(priority_key("max_count", values, costs, K),
                       kind="stable")
    x, alpha = greedy_pack(order, costs, K)
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def top_value_schedule(values, costs, cfg, n: int) -> Schedule:
    """Paper §V-B.1: pick the n highest-V_k UEs (no wireless constraint).

    Selection ignores the channel entirely, but the round log must still
    report the UEs' *real* wireless costs — the seed fabricated
    ``costs = ones(K)``, so every ``top_value`` Schedule.cost misreported
    the channel state (``FeelServer._schedule`` now threads the actual
    Eq. 9 costs through)."""
    order = np.argsort(-values, kind="stable")[:n]
    x = np.zeros(len(values), bool)
    x[order] = True
    alpha = np.where(x, 1.0 / max(n, 1), 0.0)
    return Schedule(x=x, alpha=alpha, cost=np.asarray(costs), value=values)


POLICIES = {
    "dqs": dqs_schedule,
    "random": random_schedule,
    "best_channel": best_channel_schedule,
    "max_count": max_count_schedule,
}

# Integer ids used by the batched control plane (core/control.py) to select
# a run's priority key inside the vmapped kernel.
POLICY_IDS = {"dqs": 0, "random": 1, "best_channel": 2, "max_count": 3,
              "top_value": 4}
