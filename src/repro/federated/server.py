"""FEEL server (Alg. 1): per-round schedule -> local train -> evaluate ->
reputation update -> FedAvg aggregate.

The server sees only what the paper allows it to see: dataset *metadata*
(size, symbol histogram for the diversity index, staleness), self-reported
local accuracies, uploaded models evaluated on the public test set, and
channel state. It never touches raw client data.

The model/data pair is a pluggable ``FeelTask`` (federated/task.py): the
server orchestrates Alg. 1 over the task's jit-static train/eval steps and
quality metadata, so the paper's MNIST MLP and the federated LM task run
through the exact same scheduling, threat-model and defense planes.

Two execution engines implement Alg. 1 lines 9-14:

    "vectorized" (default) — the cohort engine (federated/cohort.py): the
        round's scheduled UEs are split into ``n_buckets`` size buckets
        (``data.partition.bucket_levels`` — each bucket padded only to its
        own quantized max_samples level, reclaiming the ~2x padding waste
        of a single global pad), each bucket trains in one jitted vmapped
        step, the per-bucket stacks are merged back into selection order,
        and evaluation + aggregation run once on the merged stack — a
        single ``fedavg_stacked`` call whose weights span all buckets.
        Per-round padding overhead is recorded in ``FeelServer.pad_waste``
        (padded train slots / real samples).
    "loop" — the original sequential per-client loop, kept as the
        correctness oracle (tests/test_cohort.py pins the engines to the
        same accuracy curve).

The padded device-resident client arrays live in a ``CohortData`` that can
be shared by several servers running on the same (dataset, partition) —
the batched sweep runner (federated/simulation.py::run_sweep) builds it
once per (seed, data-attack) and fans it out across policies and across
the scenarios that share the same poisoned data.

Threat model: the server takes an ``core.attacks.AttackScenario``; its
model/report components apply to the merged cohort stack through ONE
masked ``tree_map`` (``_apply_attacks``) on the scenario's activity
schedule — the pre-refactor per-malicious-client dispatch loop survives
as ``_apply_attacks_loop``, pinned bit-equal (DESIGN.md §8).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.core import defenses as dfs
from repro.core import (ReputationTracker, WirelessModel, adaptive_weights,
                        data_quality_value, diversity_index, dqs_schedule,
                        top_value_schedule)
from repro.core import control as ctl
from repro.core import population
from repro.core.scheduler import (Schedule, best_channel_schedule,
                                  max_count_schedule, random_schedule)
from repro.data.partition import (ClientData, pad_clients,
                                  pad_clients_bucketed)
from repro.federated import cohort
from repro.federated.aggregation import fedavg, fedavg_stacked
from repro.federated.task import FeelTask, as_task
from repro.obs import trace


@dataclasses.dataclass
class RoundLog:
    round: int
    selected: np.ndarray
    global_acc: float
    n_malicious_selected: int
    objective: float
    values: np.ndarray
    reputations: np.ndarray
    # task-defined global loss metric (the LM task's held-out per-token
    # cross-entropy; NaN for tasks without one, e.g. the MNIST MLP)
    global_loss: float = float("nan")
    source_acc: float = float("nan")   # accuracy on the attacked class
    # attack success rate: fraction of watched source-class test samples
    # the global model classifies as the attack's TARGET class (NaN when
    # the scenario has no watched (source, target) pair)
    attack_success: float = float("nan")
    # honest-vs-malicious reputation separation after this round's Eq. 1
    # update (NaN when the run has no malicious UEs)
    rep_gap: float = float("nan")
    # True when the schedule was degenerate (no UE met the deadline) and the
    # server forced the highest-value UE. Problem (8) had no feasible point,
    # so ``objective`` is reported as 0.0 for forced rounds — the forced
    # UE's V_k must not be credited to the scheduler.
    forced: bool = False
    # defense-plane metrics (core/defenses.py, DESIGN.md §9): what the
    # round's DefensePolicy did — norm-clipped / aggregation-rejected
    # upload counts, validation-detector flags, and detection
    # precision/recall against the ground-truth malicious mask (metrics
    # only; the defense itself never sees the truth)
    n_clipped: int = 0
    n_rejected: int = 0
    n_flagged: int = 0
    det_precision: float = float("nan")
    det_recall: float = float("nan")


@dataclasses.dataclass
class CohortData:
    """Device-resident padded client layout for the vectorized engine.

    ``buckets[b]`` holds one size bucket's stacked per-sample array pytree
    (``data`` — the task's ``sample_arrays`` fields) and validity mask,
    laid out as [real client rows | clean twin rows | one all-zero "null
    client" row at index ``null``] — cohort-size padding gathers the null
    row for a strict training no-op. The twin rows hold the PRE-POISON
    data of clients whose partition baked in a data attack
    (``ClientData.clean``): a round-scheduled (intermittent / colluding)
    data attack gathers a malicious UE's twin row in its off rounds, so
    the schedule gates data attacks without re-partitioning or a second
    device layout. Built once per (dataset, partition) and shareable
    across servers (policies) — ``run_sweep`` exploits this to amortise
    padding + host-to-device transfer across a whole sweep.
    """
    buckets: List[Dict]       # data pytree/mask device arrays, level, null
    bucket_of: np.ndarray     # (K,) bucket index per client
    row_of: np.ndarray        # (K,) row within the client's bucket arrays
    clean_row_of: np.ndarray  # (K,) clean-twin row, -1 when none exists
    mask_dev: jax.Array       # (K+1, U) per-UE eval unit masks + null row
    sizes: np.ndarray         # (K,) true sample counts


def build_cohort_data(clients: List[ClientData], test_mask_arr: np.ndarray,
                      batch_size: int = 50, pad_to: Optional[int] = None,
                      n_buckets: int = 3) -> CohortData:
    """Bucket, pad and device-place the clients (see CohortData).

    test_mask_arr — (K, U) float {0,1} per-UE evaluation unit masks (the
    server restricts Eq. 1's acc_test to the symbols a UE claims to hold).
    """
    bucketed = pad_clients_bucketed(clients, n_buckets=n_buckets,
                                    multiple_of=batch_size, pad_to=pad_to)
    K = len(clients)
    bucket_of = np.full(K, -1)
    row_of = np.full(K, -1)
    clean_row_of = np.full(K, -1)
    zrow = lambda a: np.concatenate([a, np.zeros_like(a[:1])])
    buckets = []
    for b, (ids, pd) in enumerate(bucketed):
        # loop-engine parity contract: the loop's plain sgd epoch DROPS a
        # tail batch (nb = n // batch_size) while the masked engine would
        # train it, so a non-dividing batch_size must fail loudly
        assert not np.any(pd.sizes % batch_size), (
            "vectorized engine requires batch_size to divide every "
            "client dataset size (the loop oracle drops tail batches)")
        bucket_of[ids] = b
        row_of[ids] = np.arange(ids.size)
        arrays = {f: [a] for f, a in pd.arrays.items()}
        mask_parts = [pd.mask]
        # clean twins share the poisoned row's size (data attacks preserve
        # sample counts), so they land in the same bucket level
        twin_ids = [int(i) for i in ids if clients[i].clean is not None]
        if twin_ids:
            tw = pad_clients(
                [dataclasses.replace(clients[i], data=clients[i].clean,
                                     clean=None) for i in twin_ids],
                multiple_of=batch_size, pad_to=pd.max_samples)
            clean_row_of[twin_ids] = ids.size + np.arange(len(twin_ids))
            for f in arrays:
                arrays[f].append(tw.arrays[f])
            mask_parts.append(tw.mask)
        buckets.append({
            "data": {f: jnp.asarray(zrow(np.concatenate(parts)))
                     for f, parts in arrays.items()},
            "mask": jnp.asarray(zrow(np.concatenate(mask_parts))),
            "level": pd.max_samples, "null": ids.size + len(twin_ids)})
    return CohortData(
        buckets=buckets, bucket_of=bucket_of, row_of=row_of,
        clean_row_of=clean_row_of,
        mask_dev=jnp.asarray(zrow(test_mask_arr)),
        sizes=np.array([c.size for c in clients], float))


class FeelServer:
    """policy: 'dqs' | 'random' | 'best_channel' | 'max_count' | 'top_value'.
    'top_value' reproduces §V-B.1 (pure data-quality selection, no wireless).

    task: a ``federated.task.FeelTask`` (or registry name; None defers to
    ``cfg.task``) — the model/data pair the round trains. The task owns
    every model-specific step (init, masked local SGD, unit prediction,
    the loop oracle) and the quality metadata definition (histogram,
    Gini-Simpson diversity); the server only orchestrates Alg. 1 over it.
    ``lr``/``batch_size`` default to the task's protocol values when None.

    engine: 'vectorized' | 'loop' (see module docstring).
    control: 'batched' | 'host' — the control plane (values -> Eq. 9 costs
    -> Alg. 2 selection -> Eq. 1 reputation). 'batched' (default) runs it
    as the jitted vmapped kernel of core/control.py (one run here; the
    sweep runner stacks ALL its runs into the same kernel); 'host' is the
    sequential numpy oracle (tests/test_control.py pins the parity) —
    mirroring the engine='loop' pattern of the data plane.
    n_buckets: number of max_samples size buckets for the vectorized
    engine (1 = the old single global pad; 2-3 reclaim the padding waste).
    scenario: an ``core.attacks.AttackScenario`` (or registry name) — the
    threat model. Its data component must already be baked into
    ``clients`` by the partition; the server applies the model/report
    components on the scenario's activity schedule and tracks the
    watched (source, target) metrics. Supersedes the legacy
    ``model_poison``/``lie_boost`` knobs (kept for back-compat and
    normalized into an equivalent scenario).
    defense: a ``core.defenses.DefensePolicy`` (or registry name) — the
    server-side counter-measure plane (DESIGN.md §9). Its robust
    aggregator replaces/augments FedAvg in ``_aggregate_cohort`` (both
    engines); its validation detector adds one extra vmapped eval per
    round and feeds a trust penalty into Eq. 1 in ``_finalize_round``.
    None defers to ``cfg.defense`` (default ``"none"``).

    The underscore round-phase methods (_schedule_round, _cohort_parts,
    _merge_cohort, _apply_attacks, _eval_masks, _aggregate_cohort,
    _finalize_round, _log_round, draw_control_inputs) are a semi-public
    contract: the batched sweep runner (federated/simulation.py)
    interleaves them across runs — change their signatures and the sweep
    changes with them.
    """

    _N_BUCKET = 8   # cohort sizes are padded to a multiple of this with
                    # zero-weight null clients (shape-stable compiles)

    def __init__(self, cfg: FeelConfig, clients: List[ClientData],
                 test, rng: np.random.Generator,
                 policy: str = "dqs", lr: Optional[float] = None,
                 adaptive_omega: bool = False, lie_boost: float = 0.0,
                 watch_class: Optional[int] = None, model_poison=None,
                 engine: str = "vectorized",
                 batch_size: Optional[int] = None,
                 pad_to: Optional[int] = None, n_buckets: int = 3,
                 cohort_data: Optional[CohortData] = None,
                 control: str = "batched",
                 scenario: Optional[atk.AttackScenario] = None,
                 defense=None, task: Optional[FeelTask] = None):
        assert engine in ("vectorized", "loop"), engine
        assert control in ("batched", "host"), control
        self.control = control
        self.cfg = cfg
        self.task = as_task(task if task is not None else cfg.task)
        self.clients = clients
        self.test = test
        self.rng = rng
        self.policy = policy
        self.lr = self.task.default_lr if lr is None else lr
        self.adaptive_omega = adaptive_omega
        # threat model: either an explicit AttackScenario (data attacks
        # are already baked into ``clients`` by the partition; the server
        # applies the model/report components on the schedule) or the
        # legacy knobs, normalized into an equivalent scenario
        if scenario is not None:
            assert (model_poison is None and not lie_boost
                    and watch_class is None), \
                "scenario supersedes the legacy model_poison/lie_boost/" \
                "watch_class knobs (set AttackScenario.watch instead)"
            self.scenario = atk.as_scenario(scenario)
        else:
            self.scenario = atk.AttackScenario(
                "legacy",
                model=(atk.ModelAttack(scale=model_poison.scale)
                       if model_poison is not None else None),
                report=atk.ReportAttack(lie_boost) if lie_boost else None)
        # metrics watch pair: explicit watch_class wins (legacy callers),
        # else the scenario's (source, target)
        watch = self.scenario.watch
        self.watch_class = (watch_class if watch_class is not None
                            else (watch[0] if watch else None))
        self.watch_target = watch[1] if watch else None
        self.engine = engine
        self.batch_size = (self.task.batch_size if batch_size is None
                           else batch_size)
        self.pad_to = pad_to        # stable cohort shape across seeds
        self.n_buckets = n_buckets

        # candidate width: N = cfg.n_population (== n_ues in the legacy
        # regime, > n_ues under a population cut, DESIGN.md §12) — every
        # per-UE control array spans the full candidate population while
        # cfg.n_ues stays the Eq. 9 bandwidth budget
        assert len(clients) == cfg.n_population, \
            (len(clients), cfg.n_population)
        self.wireless = WirelessModel(cfg, rng)
        self.reputation = ReputationTracker(cfg)
        self.params = self.task.init_params(
            jax.random.PRNGKey(int(rng.integers(1 << 31))))
        self.ages = np.ones(cfg.n_population)   # rounds since last selected
        self.cpu_hz = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max,
                                  cfg.n_population)
        self.sizes = np.array([c.size for c in clients], float)
        # malicious-set layout for the activity schedule: rank within the
        # malicious set (by ue_id) drives the colluding round-robin
        self._mal_mask = np.array([c.malicious for c in clients])
        mal_ids = np.flatnonzero(self._mal_mask)
        self._mal_rank = np.full(cfg.n_population, -1)
        self._mal_rank[mal_ids] = np.arange(mal_ids.size)
        # stale free-riders replay the global model from ``staleness``
        # rounds ago; keep exactly that much history (None otherwise)
        st = self.scenario.model.staleness if self.scenario.model else 0
        self._param_hist = (collections.deque(maxlen=st + 1) if st > 0
                            else None)
        # UEs report their quality metadata once (task-defined: label
        # histograms for MNIST, token histograms for the LM); poisoned data
        # is what the UE *believes*, so the report reflects the attack —
        # including for round-scheduled data attacks, whose one-time report
        # is the poisoned histogram (metadata is not re-reported per round).
        self.divs = np.array([self.task.gini(c.data) for c in clients])
        self.histograms = [self.task.histogram(c.data) for c in clients]
        # Interpretation decision (DESIGN.md): Eq. 1's acc_test is evaluated
        # on the test UNITS restricted to the symbols a UE claims to hold
        # (classes / vocabulary) — otherwise the reputation punishes
        # honest-but-skewed (non-IID) UEs exactly as hard as poisoners,
        # which contradicts the paper's Fig. 2.
        unit_labels = self.task.unit_labels(test)
        self._test_masks = [np.isin(unit_labels, np.flatnonzero(h > 0))
                            for h in self.histograms]
        self._test_mask_arr = np.stack(self._test_masks).astype(np.float32)
        self._ex = self.task.eval_inputs(test)
        self._ey = self.task.unit_targets(test)
        # defense plane (core/defenses.py, DESIGN.md §9): robust
        # aggregation replaces/augments FedAvg in _aggregate_cohort, the
        # validation detector scores every upload on a held-out split
        # (the first n_val test rows) and its anomaly feeds Eq. 1 as a
        # trust penalty in _finalize_round
        self.defense = dfs.as_defense(defense if defense is not None
                                      else cfg.defense)
        det = self.defense.detector
        if det is not None:
            # validation split: the units of the first n_val test rows,
            # restricted per UE to the symbols it claims to hold (the same
            # masking argument as Eq. 1's acc_test, DESIGN.md §2 — an
            # unmasked score cannot tell an honest non-IID UE from a noise
            # UE). The detector's novelty over Eq. 1 is using the ABSOLUTE
            # cohort-relative level of this score, not a report gap.
            self._n_val = min(det.n_val, len(test.y))
            val_rows = self.task.unit_rows(test) < self._n_val
            self._val_masks = [m & val_rows for m in self._test_masks]
            arr = self._test_mask_arr * val_rows.astype(np.float32)[None]
            self._val_mask_dev = jnp.asarray(
                np.concatenate([arr, np.zeros_like(arr[:1])]))
        self._def_stats = dfs.DefenseStats()   # refreshed every round
        # vectorized-engine client layout: injected (sweep-shared) or built
        # lazily on first use (see CohortData)
        self._cohort_data = cohort_data
        # batched-control state (R=1): built lazily; the sweep runner builds
        # its own R=n_runs ControlState instead and never touches this one
        self._ctrl: Optional[ctl.ControlState] = None
        # async-engine busy mask (federated/async_engine.py, DESIGN.md §13):
        # when set, these UEs have an upload in flight and must not be
        # re-scheduled — their channel gains are zeroed for the draw (an
        # arithmetic mask, NOT an RNG op: the host stream of record is
        # untouched), which makes Eq. 9 infeasible so packing skips them.
        # None in synchronous mode (every round's cohort fully lands).
        self.unavailable: Optional[np.ndarray] = None
        self.pad_waste: List[float] = []   # per-round padded/real sample ratio
        self.logs: List[RoundLog] = []
        self._n_params: Optional[int] = None   # telemetry-only param count

    # ------------------------------------------------------------------ #
    def _omega(self, round_t: int) -> Tuple[float, float]:
        """(w_rep, w_div) for this round — annealed under adaptive omega."""
        if self.adaptive_omega:
            return adaptive_weights(round_t, self.cfg.rounds, self.cfg)
        return self.cfg.omega_rep, self.cfg.omega_div

    def _values(self, round_t: int) -> np.ndarray:
        cfg = self.cfg
        I = diversity_index(self.divs, self.sizes, self.ages, cfg.gamma)
        return data_quality_value(self.reputation.values, I, cfg,
                                  omega=self._omega(round_t))

    def _mask_unavailable(self, gains: np.ndarray) -> np.ndarray:
        """Zero the gains of busy UEs (async in-flight uploads): a zero
        gain makes Eq. 9 infeasible (cost K+1), so every channel-aware
        packing skips them. Channel-blind policies (top_value, the forced
        rewrite) are post-filtered by the async engine instead."""
        if self.unavailable is not None:
            gains = np.where(self.unavailable, 0.0, gains)
        return gains

    def _schedule(self, values: np.ndarray) -> Schedule:
        cfg = self.cfg
        gains = self._mask_unavailable(self.wireless.draw_channels().gains)
        t_train = self.wireless.train_time(self.sizes, self.cpu_hz)
        costs = self.wireless.cost(gains, t_train)
        if self.policy == "dqs":
            return dqs_schedule(values, costs, cfg)
        if self.policy == "random":
            return random_schedule(values, costs, cfg, self.rng)
        if self.policy == "best_channel":
            return best_channel_schedule(values, costs, cfg, gains)
        if self.policy == "max_count":
            return max_count_schedule(values, costs, cfg)
        if self.policy == "top_value":
            # selection ignores the channel, but the logged Schedule.cost
            # must report the real Eq. 9 costs (accounting bugfix)
            return top_value_schedule(values, costs, cfg, cfg.min_selected)
        raise KeyError(self.policy)

    # ------------------------------------------------------------------ #
    # Per-cohort execution engines: both return the round's uploads
    # WITHOUT aggregating — (uploads, weights, acc_local, acc_test,
    # acc_val) where ``uploads`` is a params list (loop) or the padded
    # merged stack (vectorized) and ``weights`` the aligned FedAvg sample
    # counts. ``run_round`` aggregates immediately (synchronous Alg. 1);
    # the async engine banks them and aggregates on its trigger with
    # staleness-discounted weights (federated/async_engine.py).
    # ------------------------------------------------------------------ #
    def _run_cohort_loop(self, sel: np.ndarray, t: int):
        cfg = self.cfg
        # round-scheduled data attacks: an inactive malicious UE trains on
        # its clean twin this round (the loop-engine mirror of the
        # vectorized engine's twin-row gather, see CohortData)
        active = self.scenario.schedule.active(t, self._mal_mask,
                                               self._mal_rank)
        reports = []
        for k in sel:
            c = self.clients[k]
            if c.clean is not None and not active[k]:
                c = dataclasses.replace(c, data=c.clean, clean=None)
            reports.append(self.task.local_train(
                c, self.params, cfg.local_epochs, self.lr,
                self.batch_size))
        acc_local = np.array([r.acc_local for r in reports])
        params_list = [r.params for r in reports]

        # attack application, per client — the loop engine IS the host
        # oracle the masked batched path is pinned against
        scn = self.scenario
        ref = self._attack_ref_params()
        mal = active[sel]
        if scn.model is not None:
            params_list = [scn.model.apply_loop(self.params, p, ref)
                           if m else p for p, m in zip(params_list, mal)]
        if scn.report is not None:
            acc_local = scn.report.apply(acc_local, mal)

        # server-side evaluation of every uploaded model (Alg. 1 line 14)
        # on the units of the symbols each UE claims to hold (see
        # __init__ note)
        acc_test = np.empty(len(reports))
        for i, (p, k) in enumerate(zip(params_list, sel)):
            acc_test[i] = self.task.eval_units_loop(p, self.test,
                                                    self._test_masks[k])

        # defense plane, host-oracle side: per-client validation pass
        # (upload AND start-of-round global model on each UE's masked val
        # split) + compressed-matrix robust aggregation (core/defenses.py)
        acc_val = None
        if self.defense.detector is not None:
            acc_val = np.zeros((2, len(params_list)))
            for i, (p, k) in enumerate(zip(params_list, sel)):
                m = self._val_masks[k]
                if m.any():
                    acc_val[0, i] = self.task.eval_units_loop(
                        p, self.test, m)
                    acc_val[1, i] = self.task.eval_units_loop(
                        self.params, self.test, m)
        weights = np.asarray([r.n_samples for r in reports], float)
        return params_list, weights, acc_local, acc_test, acc_val

    def _ensure_cohort_data(self) -> CohortData:
        # resident on device once; per-round cohort stacking is then a
        # device-side gather instead of a host copy + transfer. Only the
        # device copy is kept — a host copy would double the padded
        # dataset's footprint for the server's lifetime.
        if self._cohort_data is None:
            self._cohort_data = build_cohort_data(
                self.clients, self._test_mask_arr,
                batch_size=self.batch_size, pad_to=self.pad_to,
                n_buckets=self.n_buckets)
        return self._cohort_data

    def _cohort_parts(self, sel: np.ndarray, t: int, pad: bool = True):
        """Split round ``t``'s cohort per size bucket.

        Yields ``(bucket, positions_in_sel, row_ids)``. A malicious UE
        whose data attack is INACTIVE in round t (round-scheduled
        scenarios) maps to its clean twin row instead of its poisoned row
        (see CohortData) — for always-on schedules the mapping is the
        identity, bit-for-bit. With ``pad`` the row ids are padded to a
        multiple of _N_BUCKET with the bucket's null client (mask all-zero
        -> training no-op, weight 0 downstream), so rounds with new cohort
        sizes reuse the compiled per-bucket step instead of re-tracing —
        the exact pathology this engine replaces. The sweep runner passes
        ``pad=False`` and pads the cross-run batch once instead.
        """
        cd = self._ensure_cohort_data()
        rows_of = cd.row_of
        if np.any(cd.clean_row_of >= 0):
            active = self.scenario.schedule.active(t, self._mal_mask,
                                                   self._mal_rank)
            use_clean = ~active & (cd.clean_row_of >= 0)
            rows_of = np.where(use_clean, cd.clean_row_of, cd.row_of)
        for b, bkt in enumerate(cd.buckets):
            pos = np.flatnonzero(cd.bucket_of[sel] == b)
            if pos.size == 0:
                continue
            rows = rows_of[sel[pos]]
            if pad:
                n_pad = cohort.pad_count(pos.size, self._N_BUCKET)
                rows = np.concatenate(
                    [rows, np.full(n_pad - pos.size, bkt["null"],
                                   rows.dtype)])
            yield bkt, pos, rows

    def _gather_bucket(self, bkt: Dict, rows: np.ndarray):
        """Device-side gather of a bucket's (data pytree, mask) rows."""
        idx = jnp.asarray(rows)
        return ({f: jnp.take(a, idx, axis=0)
                 for f, a in bkt["data"].items()},
                jnp.take(bkt["mask"], idx, axis=0))

    @staticmethod
    def _merge_cohort(parts):
        """Merge per-bucket results (pos, stacked_real_rows, acc_real) back
        into selection order: FedAvg then accumulates in exactly the order
        the loop oracle uses (bit-for-bit parity)."""
        order = np.concatenate([p[0] for p in parts])
        inv = np.argsort(order, kind="stable")
        stacked = cohort.merge_stacks([p[1] for p in parts], inv)
        acc_local = np.concatenate([p[2] for p in parts])[inv]
        return stacked, acc_local

    def _active_malicious(self, sel: np.ndarray, t: int) -> np.ndarray:
        """(len(sel),) bool — scheduled UEs whose malicious behaviour is
        ACTIVE in round t (the scenario's activity schedule gates the
        model/report components; data attacks are baked into the data)."""
        return self.scenario.schedule.active(
            t, self._mal_mask, self._mal_rank)[sel]

    def _attack_ref_params(self):
        """Reference params for the model attack: the current global
        model, or — for stale free-riders — the global model from
        ``staleness`` rounds ago. Must be called exactly once per round
        (it advances the history)."""
        if self._param_hist is None:
            return self.params
        self._param_hist.append(self.params)     # start-of-round params
        return self._param_hist[0]

    def _apply_attacks(self, sel, stacked, acc_local, t):
        """Model poisoning + dishonest reporting on the merged stack:
        ONE masked ``tree_map`` over the malicious rows
        (``ModelAttack.apply_stacked``) — no per-malicious-client
        dispatch. ``_apply_attacks_loop`` keeps the replaced per-client
        ``.at[i].set`` loop as the parity oracle (tests/test_attacks.py
        pins them bit-for-bit equal)."""
        scn = self.scenario
        with trace.span("attack.apply") as sp:
            ref = self._attack_ref_params()
            mal = self._active_malicious(sel, t)
            if scn.model is not None and mal.any():
                stacked = scn.model.apply_stacked(stacked, self.params,
                                                  mal, ref)
            if scn.report is not None:
                acc_local = scn.report.apply(acc_local, mal)
            if trace.enabled():
                sp.set(scenario=scn.name, n_active=int(mal.sum()))
        return stacked, acc_local

    def _apply_attacks_loop(self, sel, stacked, acc_local, t):
        """The pre-refactor O(n_malicious) dispatch loop — one
        ``.at[i].set`` tree_map per malicious client. Kept ONLY as the
        parity oracle for ``_apply_attacks``."""
        scn = self.scenario
        ref = self._attack_ref_params()
        mal = self._active_malicious(sel, t)
        if scn.model is not None and mal.any():
            for i in np.flatnonzero(mal):
                poisoned = scn.model.apply_loop(
                    self.params, cohort.unstack(stacked, int(i)), ref)
                stacked = jax.tree.map(
                    lambda l, p, i=int(i): l.at[i].set(p), stacked, poisoned)
        if scn.report is not None:
            acc_local = scn.report.apply(acc_local, mal)
        return stacked, acc_local

    def _eval_masks(self, sel: np.ndarray, n_pad: int) -> jax.Array:
        """(n_pad, T) per-UE eval masks for the padded merged stack."""
        cd = self._ensure_cohort_data()
        idx = jnp.asarray(np.concatenate(
            [sel, np.full(n_pad - sel.size, len(self.clients), sel.dtype)]))
        return jnp.take(cd.mask_dev, idx, axis=0)

    def _cohort_weights(self, sel: np.ndarray, stacked_p) -> np.ndarray:
        """FedAvg sample-count weights for a padded merged stack: real rows
        carry their dataset size, pad rows weight 0."""
        cd = self._ensure_cohort_data()
        weights = np.zeros(jax.tree.leaves(stacked_p)[0].shape[0])
        weights[:sel.size] = cd.sizes[sel]
        return weights

    def _aggregate_cohort(self, sel: np.ndarray, stacked_p,
                          weights: Optional[np.ndarray] = None) -> None:
        """ONE fedavg_stacked call whose weights span all buckets — or,
        under a defense with a robust aggregator, the batched defended
        aggregation over the padded (K_pad, P) flattened-update layout
        (core/defenses.py, DESIGN.md §9; stats land in ``_def_stats``
        for ``_log_round``). ``weights`` overrides the sample-count
        weights (the async engine passes staleness-discounted ones);
        None computes them — callers like the stacked sweep runner stay
        on the 2-arg form."""
        if weights is None:
            weights = self._cohort_weights(sel, stacked_p)
        agg = self.defense.aggregator
        with trace.span("defense.aggregate") as sp:
            if trace.enabled():
                sp.set(defense=self.defense.name, n=int(sel.size))
            if agg is None:
                self.params = fedavg_stacked(stacked_p, weights)
                self._def_stats = dfs.DefenseStats()
            else:
                self.params, self._def_stats = dfs.aggregate_stacked(
                    agg, stacked_p, weights, self.params, sel.size,
                    self.cfg.n_malicious)

    def _run_cohort_vectorized(self, sel: np.ndarray, t: int):
        cfg = self.cfg
        cd = self._ensure_cohort_data()
        n = sel.size
        parts, pad_slots = [], 0
        for bkt, pos, rows in self._cohort_parts(sel, t):
            data, ms = self._gather_bucket(bkt, rows)
            with trace.span("train.bucket") as bsp:
                probe0 = (trace.jit_cache_size(cohort.cohort_train)
                          if trace.enabled() else 0)
                stacked_b, acc_b = cohort.cohort_train(
                    self.task, self.params, data, ms, self.lr,
                    cfg.local_epochs, self.batch_size)
                if trace.enabled():
                    bsp.set(level=int(bkt["level"]), rows=int(rows.size),
                            real=int(pos.size),
                            compiled=trace.jit_cache_size(
                                cohort.cohort_train) > probe0)
                    trace.observe("train.bucket_occupancy",
                                  pos.size / rows.size)
            parts.append((pos,
                          jax.tree.map(lambda l, m=pos.size: l[:m],
                                       stacked_b),
                          np.asarray(acc_b, float)[:pos.size]))
            pad_slots += rows.size * bkt["level"]
        stacked, acc_local = self._merge_cohort(parts)
        self.pad_waste.append(
            float(pad_slots) / max(float(cd.sizes[sel].sum()), 1.0))
        if trace.enabled():
            trace.observe("train.pad_waste", self.pad_waste[-1])

        stacked, acc_local = self._apply_attacks(sel, stacked, acc_local, t)

        # evaluate + aggregate once on the merged stack, zero-padded to a
        # stable row count (null rows score 0 under an all-zero mask and
        # contribute exactly 0 with weight 0)
        n_pad = cohort.pad_count(n, self._N_BUCKET)
        stacked_p = cohort.pad_stacked(stacked, n_pad)
        with trace.span("eval") as esp:
            probe0 = (trace.jit_cache_size(cohort.cohort_eval)
                      if trace.enabled() else 0)
            acc_test = np.asarray(
                cohort.cohort_eval(self.task, stacked_p, self._ex, self._ey,
                                   self._eval_masks(sel, n_pad)), float)[:n]
            if trace.enabled():
                esp.set(rows=int(n_pad),
                        compiled=trace.jit_cache_size(
                            cohort.cohort_eval) > probe0)
        acc_val = self._eval_validation(stacked_p, sel)
        return (stacked_p, self._cohort_weights(sel, stacked_p),
                acc_local, acc_test, acc_val)

    def _val_eval_masks(self, sel: np.ndarray, n_pad: int) -> jax.Array:
        """(n_pad, T) per-UE class-masked validation-split eval masks."""
        idx = jnp.asarray(np.concatenate(
            [sel, np.full(n_pad - sel.size, len(self.clients), sel.dtype)]))
        return jnp.take(self._val_mask_dev, idx, axis=0)

    def _eval_validation(self, stacked_p, sel: np.ndarray
                         ) -> Optional[np.ndarray]:
        """Defense detector: the ONE extra vmapped eval — every uploaded
        model AND the start-of-round global model scored on the held-out
        validation split restricted to each UE's claimed classes (same
        ``cohort_eval`` machinery; (2, n): uploads row, global row)."""
        if self.defense.detector is None:
            return None
        with trace.span("eval.validation") as sp:
            n = sel.size
            n_pad = jax.tree.leaves(stacked_p)[0].shape[0]
            vm = self._val_eval_masks(sel, n_pad)
            both = cohort.merge_stacks(
                [stacked_p, cohort.broadcast_params(self.params, n_pad)])
            acc = np.asarray(
                cohort.cohort_eval(self.task, both, self._ex, self._ey,
                                   jnp.concatenate([vm, vm])), float)
            if trace.enabled():
                sp.set(rows=int(2 * n_pad))
            return np.stack([acc[:n], acc[n_pad:n_pad + n]])

    # ------------------------------------------------------------------ #
    # Round phases. ``run_round`` composes them; the batched sweep runner
    # (federated/simulation.py) interleaves the phases of many runs so
    # training/evaluation batch across runs.
    # ------------------------------------------------------------------ #
    def _schedule_round(self, t: int):
        """Alg. 1 lines 4-8: values -> schedule -> participant set.

        Returns (values, sched, sel, forced). ``forced`` marks a degenerate
        channel draw: no UE met the deadline, so the server forces the
        single highest-value UE to keep training alive — but problem (8)
        had no feasible point, so the round's *objective* is 0.0 (the
        forced UE's V_k is not credited to the scheduler).
        """
        with trace.span("schedule") as sp:
            if self.control == "batched":
                out = self._schedule_round_batched(t)
            else:
                out = self._schedule_round_host(t)
            if trace.enabled():
                values, sched, sel, forced = out
                sp.set(t=t, n_selected=int(sel.size), forced=bool(forced),
                       **self._schedule_estimates())
            return out

    def _schedule_round_host(self, t: int):
        """Sequential numpy oracle path of ``_schedule_round``."""
        values = self._values(t)
        sched = self._schedule(values)
        sel = sched.selected
        forced = False
        if sel.size == 0:
            # Rewrite the schedule so the logged selection vector describes
            # the actual participant set, not the empty one.
            k = int(np.argmax(values))
            sel = np.array([k])
            x = np.zeros(values.size, bool)
            x[k] = True
            alpha = np.zeros(values.size)
            alpha[k] = 1.0          # the forced UE gets the whole band
            sched = Schedule(x=x, alpha=alpha, cost=sched.cost,
                             value=sched.value)
            forced = True
        return values, sched, sel, forced

    # -- batched control plane (core/control.py) ----------------------- #
    def _control_state(self) -> ctl.ControlState:
        if self._ctrl is None:
            self._ctrl = ctl.ControlState.from_servers([self])
        return self._ctrl

    def draw_control_inputs(self) -> Tuple[np.ndarray, np.ndarray]:
        """(gains, rand_rank) for one round, drawn from THIS server's RNG
        in the oracle's order: channel draw first, then — only for the
        ``random`` policy — the packing permutation. The batched kernel is
        a deterministic function of these host draws, which is what keeps
        every run's stream identical to its sequential twin."""
        gains = self._mask_unavailable(self.wireless.draw_channels().gains)
        if self.policy == "random":
            rand_rank = np.argsort(
                self.rng.permutation(self.cfg.n_population))
        else:
            rand_rank = np.arange(self.cfg.n_population)
        return gains, rand_rank

    def _schedule_round_batched(self, t: int):
        st = self._control_state()
        st.pull([self])
        gains, rand_rank = self.draw_control_inputs()
        w_rep, w_div = self._omega(t)
        if self.cfg.population is not None:
            # population cut: schedule through the top-M prefilter
            # (schedule-preserving by certificate — identical selection,
            # core/population.py / DESIGN.md §12)
            x, alpha, costs, values, forced, _ = \
                population.prefilter_schedule_runs(
                    st, gains[None], rand_rank[None],
                    np.array([w_rep]), np.array([w_div]))
        else:
            x, alpha, costs, values, forced = ctl.schedule_runs(
                st, gains[None], rand_rank[None],
                np.array([w_rep]), np.array([w_div]))
        sched = Schedule(x=x[0], alpha=alpha[0], cost=costs[0],
                         value=values[0])
        return values[0], sched, sched.selected, bool(forced[0])

    def _train_cohort(self, sel: np.ndarray, t: int):
        """(uploads, weights, acc_local, acc_test, acc_val) of the round's
        cohort — no aggregation (see the engines' section comment);
        ``acc_val`` is None unless the defense has a validation detector."""
        with trace.span("train") as sp:
            if trace.enabled():
                sp.set(t=t, engine=self.engine, n=int(sel.size),
                       **self._train_estimates(sel))
            if self.engine == "vectorized":
                return self._run_cohort_vectorized(sel, t)
            return self._run_cohort_loop(sel, t)

    def _aggregate_uploads(self, sel: np.ndarray, uploads,
                           weights: np.ndarray) -> None:
        """Aggregate a cohort's uploads into ``self.params`` — the single
        write point for both engines and both execution modes. ``uploads``
        is whatever ``_train_cohort`` returned (params list / padded
        stack); ``weights`` the aligned FedAvg weights, possibly
        staleness-discounted by the async engine."""
        if self.engine == "vectorized":
            self._aggregate_cohort(sel, uploads, weights)
            return
        agg = self.defense.aggregator
        with trace.span("defense.aggregate") as sp:
            if trace.enabled():
                sp.set(defense=self.defense.name, n=int(sel.size))
            if agg is None:
                self.params = fedavg(uploads, list(weights))
                self._def_stats = dfs.DefenseStats()
            else:
                self.params, self._def_stats = dfs.aggregate_host(
                    agg, uploads, np.asarray(weights, float), self.params,
                    self.cfg.n_malicious)

    def _detect(self, sel: np.ndarray, acc_val) -> Optional[np.ndarray]:
        """Validation-detector phase: anomaly scores -> Eq. 1 trust
        penalties (returned, aligned with ``sel``) + detection metrics
        against the ground-truth malicious mask (merged into
        ``_def_stats`` for ``_log_round`` — metrics only)."""
        det = self.defense.detector
        if det is None or acc_val is None or sel.size == 0:
            return None
        with trace.span("defense.detect") as sp:
            anomaly = det.anomaly(acc_val)
            flags = anomaly > 0
            st = self._def_stats
            st.n_flagged = int(flags.sum())
            st.det_precision, st.det_recall = dfs.detection_stats(
                flags, self._mal_mask[sel])
            if trace.enabled():
                sp.set(n_flagged=st.n_flagged)
            return det.weight * anomaly

    def _finalize_round(self, t: int, values, sched, sel, forced,
                        acc_local, acc_test, g_acc, src_acc,
                        atk_succ=float("nan"), acc_val=None,
                        g_loss=float("nan")) -> RoundLog:
        """Alg. 1 lines 15-16 + logging: detector penalty, reputation,
        staleness, RoundLog."""
        with trace.span("finalize"):
            penalty = self._detect(sel, acc_val)
            if self.control == "batched":
                st = self._control_state()
                st.pull([self])
                ctl.finalize_runs(st, [sel], [acc_local], [acc_test],
                                  penalties=[penalty])
                st.push([self])
            else:
                self.reputation.update(sel, acc_local, acc_test,
                                       penalty=penalty)
                # ages: selected reset, others grow (staleness of Eq. 2)
                self.ages += 1.0
                self.ages[sel] = 1.0
            return self._log_round(t, values, sched, sel, forced, g_acc,
                                   src_acc, atk_succ, g_loss)

    def _log_round(self, t: int, values, sched, sel, forced, g_acc,
                   src_acc, atk_succ=float("nan"),
                   g_loss=float("nan")) -> RoundLog:
        """Append the RoundLog for a finalized round (reputation/ages
        already updated — the batched sweep runner updates ALL runs in one
        ``control.finalize_runs`` call and then logs per run)."""
        ds = self._def_stats
        log = RoundLog(
            round=t, selected=sel, global_acc=g_acc, global_loss=g_loss,
            n_malicious_selected=sum(self.clients[k].malicious for k in sel),
            objective=0.0 if forced else sched.objective(),
            values=values.copy(),
            reputations=self.reputation.values.copy(), source_acc=src_acc,
            attack_success=atk_succ,
            rep_gap=atk.reputation_gap(self.reputation.values,
                                       self._mal_mask),
            forced=forced,
            n_clipped=ds.n_clipped, n_rejected=ds.n_rejected,
            n_flagged=ds.n_flagged, det_precision=ds.det_precision,
            det_recall=ds.det_recall)
        self.logs.append(log)
        return log

    def _global_metrics(self) -> Tuple[float, float, float, float]:
        """(global unit accuracy, global loss, watch accuracy, attack
        success rate) of the current params — task-defined (NaN loss for
        tasks without one). Attack success is the fraction of watched
        source units classified as the scenario's TARGET symbol (NaN
        without a watched pair)."""
        with trace.span("eval.global"):
            return self.task.global_metrics(self.params, self.test,
                                            self._ex, self._ey,
                                            self.watch_class,
                                            self.watch_target)

    def _global_loss(self) -> float:
        """The task's global loss metric alone (the stacked sweep computes
        accuracies through its batched eval and only needs this extra)."""
        loss = self.task.eval_loss(self.params, self._ex)
        return float("nan") if loss is None else float(loss)

    # ------------------------------------------------------------------ #
    # Telemetry-only analytic cost estimates (DESIGN.md §14). Host
    # metadata arithmetic (sizes, shapes) — never touches device values
    # or the RNG stream; consumed by repro.obs.report's roofline context.
    # ------------------------------------------------------------------ #
    def _param_count(self) -> int:
        if self._n_params is None:
            self._n_params = int(sum(l.size for l in
                                     jax.tree.leaves(self.params)))
        return self._n_params

    def _schedule_estimates(self) -> Dict[str, float]:
        """~flops/bytes of one control-plane round over N candidates:
        Eq. 2/3 elementwise (~40 flops/candidate), the ~64-probe Eq. 9
        bisection, the N log N pack sort; ~12 f64 passes over the (N,)
        control arrays."""
        n = float(self.cfg.n_population)
        flops = n * (40.0 + 64.0 * 8.0) + 2.0 * n * max(np.log2(n), 1.0)
        return {"est_flops": float(flops), "est_bytes": float(8.0 * n * 12.0)}

    def _train_estimates(self, sel: np.ndarray) -> Dict[str, float]:
        """~flops/bytes of the round's local training: 6*P per
        sample-step (fwd 2P + bwd 4P) over every real scheduled sample x
        epochs; ~3 f32 param-array passes per batch step."""
        p = float(self._param_count())
        steps = float(self.sizes[sel].sum()) * self.cfg.local_epochs
        batches = steps / max(self.batch_size, 1)
        return {"est_flops": 6.0 * p * steps,
                "est_bytes": 12.0 * p * max(batches, 1.0)}

    def run_round(self, t: int) -> RoundLog:
        with trace.span("round") as sp:
            if trace.enabled():
                sp.set(t=t, policy=self.policy, engine=self.engine,
                       control=self.control)
            values, sched, sel, forced = self._schedule_round(t)
            uploads, weights, acc_local, acc_test, acc_val = \
                self._train_cohort(sel, t)
            self._aggregate_uploads(sel, uploads, weights)
            g_acc, g_loss, src_acc, atk_succ = self._global_metrics()
            return self._finalize_round(t, values, sched, sel, forced,
                                        acc_local, acc_test, g_acc,
                                        src_acc, atk_succ, acc_val,
                                        g_loss)

    def run(self, rounds: Optional[int] = None) -> List[RoundLog]:
        assert self.cfg.mode == "sync", \
            "mode='async' runs through federated.async_engine.AsyncFeelEngine"
        for t in range(rounds or self.cfg.rounds):
            self.run_round(t)
        return self.logs
