"""FedAvg aggregation (Alg. 1 line 13): g <- sum_k (D_k / D_t) * Omega_k."""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(updates: Sequence, weights: Sequence[float]):
    """Weighted average of parameter pytrees. Weights are normalised."""
    w = np.asarray(weights, np.float64)
    assert w.sum() > 0, "empty aggregation"
    w = (w / w.sum()).astype(np.float32)

    def combine(*leaves):
        out = jnp.zeros_like(leaves[0], jnp.float32)
        for wi, leaf in zip(w, leaves):
            out = out + wi * leaf.astype(jnp.float32)
        return out.astype(leaves[0].dtype)

    return jax.tree.map(combine, *updates)


def fedavg_stacked(stacked, weights):
    """Aggregate updates stacked on axis 0 (device-cohort layout):
    leaf (N, ...) x weights (N,) -> (...). Mirrors the Pallas
    ``weighted_aggregate`` kernel; used by the distributed cohort step."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)

    def combine(leaf):
        wf = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(leaf.astype(jnp.float32) * wf, axis=0).astype(leaf.dtype)

    return jax.tree.map(combine, stacked)
