"""Federated client: local training on the UE's (possibly poisoned) dataset
and the self-reported local accuracy of Alg. 1 line 11.

A malicious UE is not assumed to lie about the *number* it reports — it
truthfully evaluates on its own poisoned data, which is exactly why the
paper's Eq. 1 uses the server-side test-set gap to catch it. An optional
``lie_boost`` models UEs that additionally inflate their report."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import numpy as np

from repro.data.partition import ClientData
from repro.models.mlp import mlp_accuracy, mlp_sgd_epoch


@dataclasses.dataclass
class ClientReport:
    ue_id: int
    params: dict
    acc_local: float
    n_samples: int


def local_train(client: ClientData, global_params, epochs: int,
                lr: float = 0.1, batch_size: int = 50,
                lie_boost: float = 0.0, model_poison=None) -> ClientReport:
    x = jax.numpy.asarray(client.data.x)
    y = jax.numpy.asarray(client.data.y)
    params = global_params
    for _ in range(epochs):
        params = mlp_sgd_epoch(params, x, y, lr, batch_size)
    acc = float(mlp_accuracy(params, x, y))
    if client.malicious and model_poison is not None:
        # model-poisoning (§VI future work): manipulate the update itself;
        # the reported local accuracy is still that of the honest-looking
        # locally-trained model — the lie the server must catch via Eq. 1.
        params = model_poison.apply(global_params, params)
    if client.malicious and lie_boost:
        acc = min(acc + lie_boost, 1.0)
    return ClientReport(ue_id=client.ue_id, params=params,
                        acc_local=acc, n_samples=client.size)
