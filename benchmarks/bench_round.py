"""Wall-clock per FEEL round: sequential per-client loop vs the vectorized
cohort engine (federated/cohort.py), at the paper's K=50 and beyond.

    PYTHONPATH=src python -m benchmarks.bench_round                # K=50,200,500
    PYTHONPATH=src python -m benchmarks.bench_round --ks 50 --rounds 5

Methodology — each (engine, K) measurement runs the §V unit of work in a
FRESH subprocess (cold jit cache): ``--seeds`` independent experiments
(fresh partition each — the paper averages over independent runs) of
``--rounds`` rounds. This charges each engine what the protocol actually
charges it. The loop engine re-traces per *shape*: one ``mlp_sgd_epoch``
per distinct client dataset size and one eager evaluation program per
distinct per-UE test-subset size — and almost every shape is new again in
every fresh partition. The cohort engine compiles a handful of bucketed
(N, max_samples) programs that are shape-stable across seeds. The
per-round median (compiles mostly excluded) is reported alongside.

CSV rows:

    engine,K,n_train,s_per_round,median_round_s,speedup,median_speedup
"""
import argparse
import json
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = r"""
import json, sys, time
import numpy as np
from repro.configs.base import FeelConfig
from repro.core.poisoning import EASY_PAIR, LabelFlipAttack, pick_malicious
from repro.data.partition import partition
from repro.data.synthetic_mnist import generate
from repro.federated.server import FeelServer

engine, k, n_train, n_test, rounds, seeds = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]), int(sys.argv[6]))
cfg = FeelConfig(n_ues=k, n_malicious=max(k // 10, 1))
times = []
for seed in range(seeds):
    train, test = generate(n_train, n_test, seed=seed)
    rng = np.random.default_rng(seed)
    malicious = pick_malicious(cfg.n_ues, cfg.n_malicious, rng)
    clients = partition(train, cfg.n_ues, rng, malicious,
                        LabelFlipAttack(*EASY_PAIR))
    server = FeelServer(cfg, clients, test, rng, policy="dqs", engine=engine)
    for t in range(rounds):
        t0 = time.perf_counter()
        server.run_round(t)
        times.append(time.perf_counter() - t0)
print(json.dumps(times))
"""


def _measure(engine, k, n_train, n_test, rounds, seeds):
    r = subprocess.run(
        [sys.executable, "-c", _WORKER,
         engine, str(k), str(n_train), str(n_test), str(rounds), str(seeds)],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                             "")},
        timeout=3600)
    assert r.returncode == 0, r.stderr[-2000:]
    times = json.loads(r.stdout.strip().splitlines()[-1])
    mean = sum(times) / len(times)
    median = sorted(times)[(len(times) - 1) // 2]   # lower-biased: keeps
    return mean, median, times                      # compile rounds out


def _auto_n_train(k: int) -> int:
    # keep the partition pool >= the clients' demand so datasets stay
    # size-diverse (K=50 matches the paper's regime scaled to bench time);
    # cap at the paper's 50k corpus
    return min(50_000, max(10_000, 100 * k))


def bench_k(k, n_train, n_test, rounds, seeds):
    nt = n_train or _auto_n_train(k)
    out = {}
    for engine in ("loop", "vectorized"):
        out[engine] = _measure(engine, k, nt, n_test, rounds, seeds)
        print(f"# {engine} K={k} per-round s: "
              f"{[round(x, 2) for x in out[engine][2]]}", file=sys.stderr)
    cl, sl = out["loop"][:2]
    for engine in ("loop", "vectorized"):
        c, s, _ = out[engine]
        print(f"{engine},{k},{nt},{c:.3f},{s:.3f},{cl / c:.2f},{sl / s:.2f}",
              flush=True)
    return cl / out["vectorized"][0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", type=int, nargs="+", default=[50, 200, 500])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seeds", type=int, default=3,
                    help="independent fresh-partition runs per measurement")
    ap.add_argument("--n-train", type=int, default=None,
                    help="override the per-K automatic corpus size")
    ap.add_argument("--n-test", type=int, default=1_000)
    args = ap.parse_args()

    print("engine,K,n_train,s_per_round,median_round_s,"
          "speedup,median_speedup")
    for k in args.ks:
        speedup = bench_k(k, args.n_train, args.n_test, args.rounds,
                          args.seeds)
        print(f"# K={k}: vectorized per-round speedup {speedup:.2f}x",
              file=sys.stderr)


if __name__ == "__main__":
    main()
