"""Non-IID federated partition (paper §V-A "Data distribution").

Sort the training data by label, form groups of ``group_size`` same-label
samples, then allocate uniformly between ``min_groups`` and ``max_groups``
groups to each of the K UEs (the paper states 1200 groups of 50 MNIST
images; with 50,000 training samples the scheme yields len(train)//50
groups — the allocation protocol is identical). Groups are drawn without
replacement, so datasets are unbalanced AND class-skewed.

The partition is task-generic: any dataset exposing ``__len__``,
``subset(idx)`` and a ``(N,)`` label array ``y`` works — synthetic-MNIST
``Dataset`` (y = digit class) and the LM task's ``TokenDataset`` (y =
domain id) both do. Padding (``pad_clients`` / ``pad_clients_bucketed``)
is pytree-generic over the per-sample arrays (``sample_arrays``): MNIST
pads ``(S, 784)/(S,)`` feature/label arrays, the LM task pads ``(S, seq)``
int32 token windows, under one shared ``(K, S)`` validity-mask contract.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

GROUP_SIZE = 50
MIN_GROUPS = 1
MAX_GROUPS = 30


@dataclasses.dataclass
class ClientData:
    """One UE's local dataset.

    ``clean`` keeps the pre-poison twin when a data attack rewrote
    ``data`` at partition time (None for honest UEs / benign scenarios):
    round-scheduled (intermittent/colluding) data attacks gather the clean
    rows in the UE's off rounds instead of re-partitioning — see
    ``federated.server.CohortData``.
    """
    ue_id: int
    data: object              # Dataset / TokenDataset (duck-typed)
    malicious: bool = False
    clean: Optional[object] = None

    @property
    def size(self) -> int:
        return len(self.data)


def partition(train, n_ues: int, rng: np.random.Generator,
              malicious: Optional[np.ndarray] = None,
              attack=None, group_size: int = GROUP_SIZE,
              min_groups: int = MIN_GROUPS,
              max_groups: int = MAX_GROUPS,
              context: str = "") -> List[ClientData]:
    """Allocate label-sorted sample groups to K UEs (module docstring).

    ``attack`` poisons each malicious UE's raw data: either a
    ``core.attacks`` data attack (dispatched on the dataset type by
    ``attacks.poison_dataset`` — label flips / feature noise for
    ``Dataset``, token substitution / token noise for ``TokenDataset``)
    or the legacy label-only ``core.poisoning.LabelFlipAttack``
    (``apply(y, rng)``). The clean twin of a poisoned dataset is kept on
    ``ClientData.clean`` for round-scheduled data attacks.
    """
    order = np.argsort(train.y, kind="stable")
    n_groups = len(train) // group_size
    groups = order[: n_groups * group_size].reshape(n_groups, group_size)

    perm = rng.permutation(n_groups)
    counts = rng.integers(min_groups, max_groups + 1, size=n_ues)
    # truncate if the draw exceeds the pool (keeps the protocol well-defined)
    while counts.sum() > n_groups:
        counts[np.argmax(counts)] -= 1

    clients, cursor = [], 0
    mal = set(malicious.tolist()) if malicious is not None else set()
    for k in range(n_ues):
        take = perm[cursor: cursor + counts[k]]
        cursor += counts[k]
        idx = groups[take].reshape(-1)
        ds = train.subset(idx)
        is_mal = k in mal
        clean = None
        if is_mal and attack is not None:
            clean = ds
            if hasattr(attack, "poison") or hasattr(attack, "poison_tokens"):
                from repro.core.attacks import poison_dataset
                ds = poison_dataset(attack, ds, rng, context=context)
            else:                               # legacy label-only attack
                ds = type(ds)(ds.x, attack.apply(ds.y, rng))
        clients.append(ClientData(ue_id=k, data=ds, malicious=is_mal,
                                  clean=clean))
    return clients


def label_histogram(ds, n_classes: int = 10) -> np.ndarray:
    return np.bincount(ds.y.astype(int), minlength=n_classes)


def sample_arrays(data) -> Dict[str, np.ndarray]:
    """Per-sample array pytree of a dataset — the fields the padded cohort
    layout stacks. Token datasets carry one ``(N, seq)`` int window array;
    feature datasets the classic ``(N, D)/(N,)`` (x, y) pair."""
    if hasattr(data, "tokens"):
        return {"tokens": data.tokens}
    return {"x": data.x, "y": data.y}


@dataclasses.dataclass
class PaddedClients:
    """Uniform-shape client layout for the vectorized cohort engine.

    Every client dataset is zero-padded on the sample axis to one shared
    ``max_samples`` length with a {0,1} float validity mask; real samples
    occupy the prefix. ``arrays`` holds the per-sample field pytree
    (``sample_arrays``), each leaf ``(K, max_samples, ...)``; padding rows
    are all-zero with mask 0 — the task's masked SGD guarantees they
    contribute exactly zero gradient, so training on the padded layout
    reproduces the per-client unpadded run. A round's cohort is stacked by
    plain row indexing: ``padded.x[sel]`` is the (N, max_samples, D) batch.

    ``x``/``y`` remain as properties for the classic feature layout.
    """
    arrays: Dict[str, np.ndarray]   # each (K, max_samples, ...)
    mask: np.ndarray                # (K, max_samples) float32, 1 = real
    sizes: np.ndarray               # (K,) true sample counts

    @property
    def x(self) -> np.ndarray:
        return self.arrays["x"]

    @property
    def y(self) -> np.ndarray:
        return self.arrays["y"]

    @property
    def max_samples(self) -> int:
        return self.mask.shape[1]


def bucket_levels(max_size: int, n_buckets: int,
                  multiple_of: int = 1) -> np.ndarray:
    """Quantized ``max_samples`` boundaries for size-bucketed sub-cohorts.

    The (rounded-up) max size is split into ``n_buckets`` equal levels, each
    rounded up to a multiple of ``multiple_of`` (the batch size). Because the
    step is quantized, nearby ``max_size`` values map to the *same* levels —
    the jitted per-bucket cohort programs stay cache-hot across seeds and
    partitions instead of recompiling for every fresh data maximum.
    """
    assert n_buckets >= 1 and max_size >= 1
    step = -(-max_size // (n_buckets * multiple_of)) * multiple_of
    return step * np.arange(1, n_buckets + 1)


def assign_buckets(sizes: np.ndarray, levels: np.ndarray) -> np.ndarray:
    """Smallest bucket level covering each client: (K,) bucket indices."""
    assert sizes.max() <= levels[-1], (sizes.max(), levels)
    return np.searchsorted(levels, sizes)


def pad_clients_bucketed(clients: List[ClientData], n_buckets: int = 3,
                         multiple_of: int = 1, pad_to: Optional[int] = None):
    """Split clients into size buckets, padding each bucket only to its own
    quantized level (see ``bucket_levels``) instead of the global maximum.

    With the paper's 1-30 group allocation a single global pad wastes ~2x
    the real sample count; 2-3 buckets reclaim most of it while keeping the
    number of compiled cohort shapes bounded by ``n_buckets``.

    Returns a list of ``(client_ids, PaddedClients)`` pairs, one per
    non-empty bucket, in increasing level order. ``pad_to`` fixes the level
    grid to a protocol constant so the layout is identical across
    seeds/partitions (multi-seed sweeps reuse every compiled step).
    """
    sizes = np.array([c.size for c in clients], np.int64)
    s_max = int(sizes.max())
    if pad_to is not None:
        assert pad_to >= s_max, (pad_to, s_max)
        s_max = pad_to
    levels = bucket_levels(s_max, n_buckets, multiple_of)
    b_of = assign_buckets(sizes, levels)
    out = []
    for b in range(n_buckets):
        ids = np.flatnonzero(b_of == b)
        if ids.size == 0:
            continue
        pd = pad_clients([clients[i] for i in ids], multiple_of,
                         pad_to=int(levels[b]))
        out.append((ids, pd))
    return out


def pad_clients(clients: List[ClientData], multiple_of: int = 1,
                pad_to: Optional[int] = None) -> PaddedClients:
    """Pad every client to the cohort-uniform shape (see PaddedClients).

    multiple_of — round ``max_samples`` up so the masked SGD's batch grid
    divides it exactly (callers pass their batch size).
    pad_to — pad to this protocol-level constant instead of the data
    maximum (e.g. ``MAX_GROUPS * GROUP_SIZE``): keeps the cohort shape
    identical across seeds/partitions so the jitted cohort step compiles
    once for a whole multi-seed sweep. Must cover the largest client.
    """
    sizes = np.array([c.size for c in clients], np.int64)
    s_max = int(sizes.max())
    if pad_to is not None:
        assert pad_to >= s_max, (pad_to, s_max)
        s_max = pad_to
    s_max = ((s_max + multiple_of - 1) // multiple_of) * multiple_of
    k = len(clients)
    fields = sample_arrays(clients[0].data)
    arrays = {f: np.zeros((k, s_max) + a.shape[1:], a.dtype)
              for f, a in fields.items()}
    mask = np.zeros((k, s_max), np.float32)
    for i, c in enumerate(clients):
        n = c.size
        for f, a in sample_arrays(c.data).items():
            arrays[f][i, :n] = a
        mask[i, :n] = 1.0
    return PaddedClients(arrays=arrays, mask=mask, sizes=sizes)
