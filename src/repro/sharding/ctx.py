"""Logical activation-sharding context.

Models call ``constrain(x, name)`` at well-known points; outside a sharding
context this is the identity, inside (set by the launcher/dry-run) it becomes
``with_sharding_constraint`` with the registered ``PartitionSpec``. Keeps the
model code mesh-agnostic while letting GSPMD propagation be pinned where it
matters (activations, MoE dispatch buffers, decode caches).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_state = threading.local()


def _specs() -> Dict[str, PartitionSpec]:
    return getattr(_state, "specs", {})


@contextlib.contextmanager
def activation_specs(specs: Dict[str, PartitionSpec]):
    old = _specs()
    _state.specs = {**old, **specs}
    try:
        yield
    finally:
        _state.specs = old


def constrain(x, name: str):
    spec = _specs().get(name)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # outside a mesh context (e.g. plain CPU tests)
