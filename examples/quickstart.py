"""Quickstart: data-quality based scheduling (DQS) for FEEL in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's setup at reduced scale — 50 UEs with non-IID synthetic
MNIST, 5 label-flipping attackers — and runs a few FedAvg rounds under DQS,
printing the accuracy curve and which UEs the scheduler trusted.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import FeelConfig
from repro.core.poisoning import EASY_PAIR, LabelFlipAttack, pick_malicious
from repro.data.partition import partition
from repro.data.synthetic_mnist import generate
from repro.federated.server import FeelServer


def main():
    rng = np.random.default_rng(0)
    cfg = FeelConfig(rounds=6)
    print("generating synthetic MNIST (offline stand-in)...")
    train, test = generate(12_000, 2_000, seed=0)
    malicious = pick_malicious(cfg.n_ues, cfg.n_malicious, rng)
    clients = partition(train, cfg.n_ues, rng, malicious,
                        LabelFlipAttack(*EASY_PAIR))
    print(f"{cfg.n_ues} UEs, malicious: {sorted(malicious.tolist())}, "
          f"attack {EASY_PAIR[0]}->{EASY_PAIR[1]}")

    # the vectorized cohort engine trains every scheduled UE in one vmapped
    # step (pass engine="loop" for the sequential per-client oracle)
    server = FeelServer(cfg, clients, test, rng, policy="dqs",
                        engine="vectorized")
    for t in range(cfg.rounds):
        log = server.run_round(t)
        print(f"round {t}: acc={log.global_acc:.3f} "
              f"selected={len(log.selected)} "
              f"(malicious among them: {log.n_malicious_selected})")
    rep = server.reputation.values
    print(f"\nfinal mean reputation  honest:    "
          f"{np.delete(rep, malicious).mean():.3f}")
    print(f"final mean reputation  malicious: {rep[malicious].mean():.3f}")


if __name__ == "__main__":
    main()
