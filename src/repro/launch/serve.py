"""Serving launcher: prefill a batch of requests, then batched greedy decode.

    python -m repro.launch.serve --arch starcoder2-15b --smoke \
        --batch 4 --prompt-len 32 --gen 32 --host-mesh
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get, reduced
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    if cfg.is_encoder_decoder:
        raise SystemExit("serve launcher targets decoder LMs; see tests for "
                         "the enc-dec decode path")
    mesh = (make_host_mesh() if args.host_mesh
            else make_production_mesh())

    B, Pn, G = args.batch, args.prompt_len, args.gen
    total = Pn + G
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, Pn)).astype(np.int32)

    with mesh:
        params = api.init(cfg, jax.random.PRNGKey(0))
        prefill = jax.jit(make_prefill_step(cfg), static_argnames=())
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

        t0 = time.time()
        logits, cache = api.prefill(cfg, params,
                                    {"tokens": jnp.asarray(prompts)},
                                    target_len=total)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [tok]
        t_prefill = time.time() - t0
        t0 = time.time()
        for _ in range(G - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(tok)
        gen = jnp.concatenate(out, 1)
        t_decode = time.time() - t0
    print(f"prefill {B}x{Pn}: {t_prefill*1e3:.1f} ms; "
          f"decode {G-1} steps: {t_decode/(G-1)*1e3:.1f} ms/step")
    print("generated (first request):", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
