"""Diversity (Eq. 2), reputation (Eq. 1) and data-quality value (Eq. 3)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import FeelConfig
from repro.core.diversity import diversity_index, gini_simpson, normalize
from repro.core.quality import adaptive_weights, data_quality_value
from repro.core.reputation import ReputationTracker


def test_gini_simpson_extremes():
    assert gini_simpson(np.zeros(100, int), 10) == 0.0
    uniform = np.repeat(np.arange(10), 10)
    assert gini_simpson(uniform, 10) == pytest.approx(0.9)


@given(st.lists(st.integers(0, 9), min_size=1, max_size=500))
@settings(max_examples=50, deadline=None)
def test_gini_simpson_bounds(labels):
    g = gini_simpson(np.array(labels), 10)
    assert 0.0 <= g <= 0.9 + 1e-12


@given(st.lists(st.floats(0, 1e6, allow_nan=False), min_size=2, max_size=50))
@settings(max_examples=50, deadline=None)
def test_normalize_bounds(vals):
    v = normalize(np.array(vals))
    assert np.all((v >= 0) & (v <= 1))


def test_diversity_index_orders_richer_datasets_higher():
    div = np.array([0.9, 0.0])
    sizes = np.array([1500.0, 50.0])
    ages = np.array([1.0, 1.0])
    I = diversity_index(div, sizes, ages, (1/3, 1/3, 1/3))
    assert I[0] > I[1]


def test_reputation_drops_for_liar():
    cfg = FeelConfig(n_ues=3)
    rt = ReputationTracker(cfg)
    # UE0 honest (local == test), UE1 overstates by 0.4, UE2 honest
    rt.update(np.array([0, 1, 2]),
              acc_local=np.array([0.6, 0.9, 0.6]),
              acc_test=np.array([0.6, 0.5, 0.6]))
    assert rt.values[1] < rt.values[0]
    assert rt.values[0] == rt.values[2]


def test_reputation_clipped():
    cfg = FeelConfig(n_ues=1, eta=1.0)
    rt = ReputationTracker(cfg)
    for _ in range(50):
        rt.update(np.array([0]), np.array([1.0]), np.array([0.0]))
    assert rt.values[0] == 0.0


def test_value_weights():
    cfg = FeelConfig(omega_rep=1.0, omega_div=0.0)
    v = data_quality_value(np.array([0.5]), np.array([0.9]), cfg)
    assert v[0] == pytest.approx(0.5)
    cfg = FeelConfig(omega_rep=0.0, omega_div=1.0)
    v = data_quality_value(np.array([0.5]), np.array([0.9]), cfg)
    assert v[0] == pytest.approx(0.9)


def test_adaptive_weights_shift_toward_reputation():
    cfg = FeelConfig()
    early_rep, early_div = adaptive_weights(0, 15, cfg)
    late_rep, late_div = adaptive_weights(14, 15, cfg)
    assert late_rep > early_rep
    assert early_div > late_div
    total = cfg.omega_rep + cfg.omega_div
    assert early_rep + early_div == pytest.approx(total)


def test_value_omega_override_matches_config():
    """The allocation-free omega override is the same Eq. 3 as a replaced
    config (the old adaptive path allocated a FeelConfig per round)."""
    rep = np.array([0.2, 0.8])
    div = np.array([0.5, 0.1])
    cfg = FeelConfig(omega_rep=0.7, omega_div=0.3)
    np.testing.assert_array_equal(
        data_quality_value(rep, div, cfg),
        data_quality_value(rep, div, FeelConfig(), omega=(0.7, 0.3)))
