"""Distributed FEEL cohort step (shard_map) — semantics match the sequential
FedAvg reference on a 1-device mesh, and the DQS mask zeroes out unselected
clients exactly like a missed deadline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federated.aggregation import fedavg
from repro.federated.distributed import make_cohort_step
from repro.models.mlp import mlp_init, mlp_loss


def _mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _clients(n, key):
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (n, 64, 784))
    y = jax.random.randint(ks[1], (n, 64), 0, 10)
    return {"x": x, "y": y}


def _local_sgd_ref(params, batch, lr, steps):
    for _ in range(steps):
        g = jax.grad(mlp_loss)(params, batch)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    return params


def test_cohort_step_matches_sequential_fedavg():
    mesh = _mesh()
    n = mesh.shape["data"]
    key = jax.random.PRNGKey(0)
    params = mlp_init(key)
    batch = _clients(n, key)
    weights = jnp.arange(1.0, n + 1.0)
    select = jnp.ones((n,))
    step = make_cohort_step(mesh, mlp_loss, lr=0.1, local_steps=3)
    out = step(params, batch, weights, select)

    locals_ = [_local_sgd_ref(params,
                              {"x": batch["x"][i], "y": batch["y"][i]},
                              0.1, 3) for i in range(n)]
    expect = fedavg(locals_, list(np.asarray(weights)))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


def test_selection_mask_excludes_clients():
    """A client with x_k = 0 contributes nothing (like a missed deadline)."""
    mesh = _mesh()
    n = mesh.shape["data"]
    if n < 2:
        pytest.skip("needs >= 2 devices to exercise masking across clients")
    key = jax.random.PRNGKey(1)
    params = mlp_init(key)
    batch = _clients(n, key)
    weights = jnp.ones((n,))
    select = jnp.asarray([1.0] + [0.0] * (n - 1))
    step = make_cohort_step(mesh, mlp_loss, lr=0.1, local_steps=2)
    out = step(params, batch, weights, select)
    expect = _local_sgd_ref(params, {"x": batch["x"][0], "y": batch["y"][0]},
                            0.1, 2)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


def test_mask_single_device_identity():
    """On one device: select=1 reduces to plain local SGD."""
    mesh = _mesh()
    n = mesh.shape["data"]
    key = jax.random.PRNGKey(2)
    params = mlp_init(key)
    batch = _clients(n, key)
    step = make_cohort_step(mesh, mlp_loss, lr=0.05, local_steps=1)
    out = step(params, batch, jnp.ones((n,)), jnp.ones((n,)))
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(out))
