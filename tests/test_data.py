"""Paper §V-A data protocol: synthetic MNIST, non-IID partition, label flip."""
import numpy as np
import pytest

from repro.core.poisoning import EASY_PAIR, HARD_PAIR, LabelFlipAttack
from repro.data.partition import (GROUP_SIZE, MAX_GROUPS, MIN_GROUPS,
                                  label_histogram, partition)
from repro.data.synthetic_mnist import generate


@pytest.fixture(scope="module")
def data():
    return generate(5000, 1000, seed=0)


def test_generate_shapes(data):
    train, test = data
    assert train.x.shape == (5000, 784) and test.x.shape == (1000, 784)
    assert train.x.min() >= 0 and train.x.max() <= 1
    assert set(np.unique(train.y)) == set(range(10))


def test_partition_protocol(data):
    train, _ = data
    rng = np.random.default_rng(0)
    clients = partition(train, 20, rng)
    for c in clients:
        # sizes are whole groups within [1, 30]
        assert c.size % GROUP_SIZE == 0
        assert MIN_GROUPS * GROUP_SIZE <= c.size <= MAX_GROUPS * GROUP_SIZE
    # groups are same-digit -> clients are class-skewed (non-IID)
    n_classes = [len(np.unique(c.data.y)) for c in clients]
    assert min(n_classes) < 10
    # groups are single-digit except at the <=9 class-boundary groups of the
    # sorted pool (inherent to the paper's sort-then-group protocol)
    pure = mixed = 0
    for c in clients:
        for g in range(c.size // GROUP_SIZE):
            grp = c.data.y[g * GROUP_SIZE:(g + 1) * GROUP_SIZE]
            if len(np.unique(grp)) == 1:
                pure += 1
            else:
                mixed += 1
    assert mixed <= 9
    assert pure > 10 * mixed
    assert sum(c.size for c in clients) <= len(train)


def test_label_flip(data):
    train, _ = data
    rng = np.random.default_rng(0)
    atk = LabelFlipAttack(*EASY_PAIR)
    flipped = atk.apply(train.y, rng)
    assert not np.any(flipped == EASY_PAIR[0])
    assert np.sum(flipped == EASY_PAIR[1]) == (np.sum(train.y == EASY_PAIR[1])
                                               + np.sum(train.y == EASY_PAIR[0]))
    # non-source labels untouched
    keep = train.y != EASY_PAIR[0]
    assert np.array_equal(flipped[keep], train.y[keep])


def test_malicious_clients_get_flipped(data):
    train, _ = data
    rng = np.random.default_rng(0)
    clients = partition(train, 10, rng, malicious=np.array([3]),
                        attack=LabelFlipAttack(*HARD_PAIR))
    assert clients[3].malicious
    assert not np.any(clients[3].data.y == HARD_PAIR[0])
    honest = [c for c in clients if not c.malicious]
    assert all(not c.malicious for c in honest)


def test_histogram(data):
    train, _ = data
    h = label_histogram(train, 10)
    assert h.sum() == len(train)
