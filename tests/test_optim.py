"""Optimizers: reference math, descent, adafactor state factorisation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.optim import make_optimizer
from repro.optim.optimizers import clip_by_global_norm, global_norm


def _quadratic(params):
    return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(params))


def _fit(opt_name, steps=60, lr=0.1):
    tcfg = TrainConfig(optimizer=opt_name, lr=lr, weight_decay=0.0)
    opt = make_optimizer(tcfg)
    params = {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]]),
              "b": jnp.asarray([4.0, -4.0])}
    state = opt.init(params)
    for t in range(steps):
        g = jax.grad(_quadratic)(params)
        params, state = opt.update(params, g, state, jnp.asarray(t), lr)
    return float(_quadratic(params))


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw",
                                  "adafactor"])
def test_descent(name):
    assert _fit(name) < 0.3


def test_adam_matches_reference_step():
    tcfg = TrainConfig(optimizer="adam", beta1=0.9, beta2=0.999, eps=1e-8)
    opt = make_optimizer(tcfg)
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.5])}
    s = opt.init(p)
    new, s = opt.update(p, g, s, jnp.asarray(0), 0.01)
    # bias-corrected first step: m_hat = g, v_hat = g^2 -> step = lr * sign-ish
    expect = 1.0 - 0.01 * 0.5 / (np.sqrt(0.25) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]), [expect], rtol=1e-5)


def test_adamw_decays_matrices_only():
    tcfg = TrainConfig(optimizer="adamw", weight_decay=0.1)
    opt = make_optimizer(tcfg)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    s = opt.init(p)
    new, _ = opt.update(p, g, s, jnp.asarray(0), 0.5)
    assert np.all(np.asarray(new["w"]) < 1.0)       # decayed
    np.testing.assert_allclose(np.asarray(new["b"]), 1.0)  # not decayed


def test_adafactor_state_is_factored():
    tcfg = TrainConfig(optimizer="adafactor")
    opt = make_optimizer(tcfg)
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    s = opt.init(p)
    assert s["s"]["w"]["vr"].shape == (64,)
    assert s["s"]["w"]["vc"].shape == (32,)
    assert s["s"]["b"]["v"].shape == (64,)
    # factored state is O(rows+cols), not O(rows*cols)
    n_state = sum(x.size for x in jax.tree.leaves(s))
    assert n_state < p["w"].size


def test_grad_clip():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), np.sqrt(90.0), rtol=1e-5)
