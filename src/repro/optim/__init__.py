from repro.optim.optimizers import (Optimizer, adafactor, adam, adamw,
                                    make_optimizer, momentum, sgd)
from repro.optim.schedule import cosine_warmup

__all__ = ["Optimizer", "adafactor", "adam", "adamw", "make_optimizer",
           "momentum", "sgd", "cosine_warmup"]
