"""moonshot-v1-16b-a3b — Moonlight-16B-A3B MoE
[hf:moonshotai/Moonlight-16B-A3B; DeepSeek-V3-style arch, kimi/moonlight].

48L, d_model 2048, 16H (GQA kv=16 i.e. MHA), expert d_ff 1408, vocab 163840,
64 routed experts top-6 (+2 shared per the model card)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,                      # dense-equivalent width (unused: all-MoE)
    vocab_size=163_840,
    moe=MoEConfig(n_routed=64, top_k=6, d_ff_expert=1408, n_shared=2),
    long_context_window=8192,        # long_500k SWA variant (DESIGN.md)
    rope_theta=50_000.0,
    citation="[hf:moonshotai/Moonlight-16B-A3B]",
)
