"""FEEL server (Alg. 1): per-round schedule -> local train -> evaluate ->
reputation update -> FedAvg aggregate.

The server sees only what the paper allows it to see: dataset *metadata*
(size, label histogram for the diversity index, staleness), self-reported
local accuracies, uploaded models evaluated on the public test set, and
channel state. It never touches raw client data.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import FeelConfig
from repro.core import (ReputationTracker, WirelessModel, data_quality_value,
                        diversity_index, dqs_schedule, gini_simpson,
                        top_value_schedule)
from repro.core.scheduler import (Schedule, best_channel_schedule,
                                  max_count_schedule, random_schedule)
from repro.data.partition import ClientData, label_histogram
from repro.data.synthetic_mnist import Dataset, N_CLASSES
from repro.federated.aggregation import fedavg
from repro.federated.client import local_train
from repro.models.mlp import mlp_accuracy, mlp_init


@dataclasses.dataclass
class RoundLog:
    round: int
    selected: np.ndarray
    global_acc: float
    n_malicious_selected: int
    objective: float
    values: np.ndarray
    reputations: np.ndarray
    source_acc: float = float("nan")   # accuracy on the attacked class


class FeelServer:
    """policy: 'dqs' | 'random' | 'best_channel' | 'max_count' | 'top_value'.
    'top_value' reproduces §V-B.1 (pure data-quality selection, no wireless).
    """

    def __init__(self, cfg: FeelConfig, clients: List[ClientData],
                 test: Dataset, rng: np.random.Generator,
                 policy: str = "dqs", lr: float = 0.1,
                 adaptive_omega: bool = False, lie_boost: float = 0.0,
                 watch_class: Optional[int] = None, model_poison=None):
        self.cfg = cfg
        self.clients = clients
        self.test = test
        self.rng = rng
        self.policy = policy
        self.lr = lr
        self.adaptive_omega = adaptive_omega
        self.lie_boost = lie_boost
        self.watch_class = watch_class     # the attack's source class
        self.model_poison = model_poison

        self.wireless = WirelessModel(cfg, rng)
        self.reputation = ReputationTracker(cfg)
        self.params = mlp_init(jax.random.PRNGKey(int(rng.integers(1 << 31))))
        self.ages = np.ones(cfg.n_ues)          # rounds since last selected
        self.cpu_hz = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, cfg.n_ues)
        self.sizes = np.array([c.size for c in clients], float)
        # UEs report label histograms once (metadata); poisoned labels are
        # what the UE *believes*, so the histogram reflects the flip.
        self.divs = np.array([gini_simpson(c.data.y, N_CLASSES)
                              for c in clients])
        self.histograms = [label_histogram(c.data, N_CLASSES) for c in clients]
        # Interpretation decision (DESIGN.md): Eq. 1's acc_test is evaluated
        # on the test subset restricted to the classes a UE claims to hold —
        # otherwise the reputation punishes honest-but-skewed (non-IID) UEs
        # exactly as hard as poisoners, which contradicts the paper's Fig. 2.
        self._test_masks = [np.isin(test.y, np.flatnonzero(h > 0))
                            for h in self.histograms]
        self.logs: List[RoundLog] = []

    # ------------------------------------------------------------------ #
    def _values(self, round_t: int) -> np.ndarray:
        cfg = self.cfg
        if self.adaptive_omega:
            from repro.core import adaptive_weights
            cfg = adaptive_weights(round_t, cfg.rounds, cfg)
        I = diversity_index(self.divs, self.sizes, self.ages, cfg.gamma)
        return data_quality_value(self.reputation.values, I, cfg)

    def _schedule(self, values: np.ndarray) -> Schedule:
        cfg = self.cfg
        gains = self.wireless.draw_channels().gains
        t_train = self.wireless.train_time(self.sizes, self.cpu_hz)
        costs = self.wireless.cost(gains, t_train)
        if self.policy == "dqs":
            return dqs_schedule(values, costs, cfg)
        if self.policy == "random":
            return random_schedule(values, costs, cfg, self.rng)
        if self.policy == "best_channel":
            return best_channel_schedule(values, costs, cfg, gains)
        if self.policy == "max_count":
            return max_count_schedule(values, costs, cfg)
        if self.policy == "top_value":
            return top_value_schedule(values, cfg, cfg.min_selected)
        raise KeyError(self.policy)

    # ------------------------------------------------------------------ #
    def run_round(self, t: int) -> RoundLog:
        cfg = self.cfg
        values = self._values(t)
        sched = self._schedule(values)
        sel = sched.selected
        if sel.size == 0:       # degenerate channel draw — skip the round
            sel = np.array([int(np.argmax(values))])

        reports = [local_train(self.clients[k], self.params,
                               cfg.local_epochs, self.lr,
                               lie_boost=self.lie_boost,
                               model_poison=self.model_poison) for k in sel]

        # server-side evaluation of every uploaded model (Alg. 1 line 14) on
        # the classes each UE claims to hold (see __init__ note)
        tx = jax.numpy.asarray(self.test.x)
        ty = jax.numpy.asarray(self.test.y)
        acc_test = np.empty(len(reports))
        for i, (r, k) in enumerate(zip(reports, sel)):
            m = self._test_masks[k]
            acc_test[i] = float(mlp_accuracy(
                r.params, jax.numpy.asarray(self.test.x[m]),
                jax.numpy.asarray(self.test.y[m]))) if m.any() else 0.0
        acc_local = np.array([r.acc_local for r in reports])
        self.reputation.update(sel, acc_local, acc_test)

        # aggregate
        self.params = fedavg([r.params for r in reports],
                             [r.n_samples for r in reports])
        g_acc = float(mlp_accuracy(self.params, tx, ty))
        src_acc = float("nan")
        if self.watch_class is not None:
            m = self.test.y == self.watch_class
            if m.any():
                src_acc = float(mlp_accuracy(
                    self.params, jax.numpy.asarray(self.test.x[m]),
                    jax.numpy.asarray(self.test.y[m])))

        # ages: selected reset, others grow (staleness metric of Eq. 2)
        self.ages += 1.0
        self.ages[sel] = 1.0

        log = RoundLog(
            round=t, selected=sel, global_acc=g_acc,
            n_malicious_selected=sum(self.clients[k].malicious for k in sel),
            objective=sched.objective(), values=values.copy(),
            reputations=self.reputation.values.copy(), source_acc=src_acc)
        self.logs.append(log)
        return log

    def run(self, rounds: Optional[int] = None) -> List[RoundLog]:
        for t in range(rounds or self.cfg.rounds):
            self.run_round(t)
        return self.logs
