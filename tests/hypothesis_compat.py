"""Degrade gracefully when ``hypothesis`` is not installed (offline
container): property tests run against a seeded random-example fallback
instead of skipping.

Test modules import the hypothesis API from here::

    from hypothesis_compat import given, settings, st

With hypothesis installed (``pip install -e .[test]``) this is a plain
re-export — shrinking, the example database and the full strategy
vocabulary all work. Without it, a miniature implementation of the
strategies this repo actually uses (``integers``, ``floats``, ``lists``,
``sampled_from``, ``booleans``, ``tuples``, ``one_of``,
``dictionaries``, ``text``) draws
``max_examples`` pseudo-random examples from a
fixed per-test seed, so the property tests still execute deterministically
and regressions fail loudly rather than silently skipping. Unsupported
strategy names raise at collection time — add them to _FallbackStrategies
when a new test needs them.
"""
import zlib

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as np

    _MAX_EXAMPLES = 20       # fallback default; @settings overrides

    class _Strategy:
        """A draw rule: ``example(rng)`` produces one value."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _FallbackStrategies:
        """The subset of ``hypothesis.strategies`` this repo uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def dictionaries(keys, values, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                out = {}
                # bounded rejection: duplicate keys shrink the dict in
                # real hypothesis too, so under-filling is acceptable
                for _ in range(4 * n):
                    if len(out) >= n:
                        break
                    out[keys.example(rng)] = values.example(rng)
                return out
            return _Strategy(draw)

        @staticmethod
        def text(alphabet="abcdefghijklmnopqrstuvwxyz_",
                 min_size=0, max_size=12):
            chars = list(alphabet)
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return "".join(chars[int(rng.integers(len(chars)))]
                               for _ in range(n))
            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strategies))

        @staticmethod
        def one_of(*strategies):
            # hypothesis also accepts a single iterable of strategies
            if len(strategies) == 1 and not isinstance(strategies[0],
                                                       _Strategy):
                strategies = tuple(strategies[0])
            seq = list(strategies)
            return _Strategy(
                lambda rng: seq[int(rng.integers(len(seq)))].example(rng))

        def __getattr__(self, name):
            raise AttributeError(
                f"hypothesis fallback: strategy st.{name} not implemented "
                "(tests/hypothesis_compat.py) — install hypothesis or add "
                "it to _FallbackStrategies")

    st = _FallbackStrategies()

    def settings(max_examples=_MAX_EXAMPLES, **kwargs):
        def deco(f):
            f._fallback_max_examples = max_examples
            return f
        return deco

    def given(*strategies):
        for s in strategies:
            assert isinstance(s, _Strategy), (
                "hypothesis fallback supports positional strategies only")

        def deco(f):
            def _property_test():
                n = getattr(f, "_fallback_max_examples", _MAX_EXAMPLES)
                # deterministic per-test stream: same examples every run
                rng = np.random.default_rng(
                    zlib.crc32(f.__qualname__.encode()))
                for i in range(n):
                    args = tuple(s.example(rng) for s in strategies)
                    try:
                        f(*args)
                    except Exception:
                        print(f"falsifying example (fallback, #{i}): "
                              f"{f.__name__}{args}")
                        raise
            _property_test.__name__ = f.__name__
            _property_test.__doc__ = f.__doc__
            return _property_test
        return deco
