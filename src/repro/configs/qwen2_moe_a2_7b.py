"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16H (kv=16), expert d_ff 1408, vocab 151936;
60 routed experts top-4 + 4 shared experts; QKV bias (Qwen family)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,
    vocab_size=151_936,
    qkv_bias=True,
    moe=MoEConfig(n_routed=60, top_k=4, d_ff_expert=1408, n_shared=4),
    long_context_window=8192,        # long_500k SWA variant (DESIGN.md)
    citation="[hf:Qwen/Qwen1.5-MoE-A2.7B]",
)
