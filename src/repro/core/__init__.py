"""The paper's contribution: data-quality based scheduling (DQS) for FEEL.

diversity (Eq. 2) + reputation (Eq. 1) -> data-quality value (Eq. 3);
wireless cost model (Eq. 4-7, 9); greedy-knapsack scheduler (Algorithm 2)
with baseline policies; label-flip poisoning (§III-B.1) generalized to a
pluggable threat-model plane (core/attacks.py: scenario registry, masked
batched application, host oracles); a matching defense plane
(core/defenses.py: robust aggregators + validation detection, each with
a host oracle and a batched twin); batched JAX control plane
(core/control.py) scheduling all runs of a sweep in one vmapped call,
with the numpy implementations as the bit-parity oracle.
"""
from repro.core.attacks import (SCENARIOS, AttackScenario, FeatureNoise,
                                LabelFlip, MaliciousSchedule, ModelAttack,
                                NO_ATTACK, ReportAttack, as_scenario,
                                colluding, feature_noise, free_rider,
                                intermittent, label_flip, legacy_scenario,
                                lie_boost, model_poison, multi_flip,
                                recovery_rounds, register,
                                reputation_gap)
from repro.core.control import (ControlState, finalize_runs, schedule_runs)
from repro.core.defenses import (DEFENSES, DefensePolicy, DefenseStats,
                                 Krum, Median, NO_DEFENSE, NormClip,
                                 TrimmedMean, ValidationDetector,
                                 as_defense, detection_stats, krum,
                                 median, norm_clip, trimmed_mean,
                                 validation, with_validation)
from repro.core.diversity import (diversity_index, diversity_index_eq2,
                                  diversity_index_rows, gini_simpson,
                                  normalize, normalize_last,
                                  normalize_rows)
from repro.core.poisoning import (EASY_PAIR, HARD_PAIR, LabelFlipAttack,
                                  pick_malicious)
from repro.core.quality import adaptive_weights, data_quality_value
from repro.core.reputation import ReputationTracker, reputation_update_eq1
from repro.core.scheduler import (POLICIES, POLICY_IDS, Schedule,
                                  best_channel_schedule,
                                  brute_force_schedule, dqs_schedule,
                                  greedy_pack, greedy_pack_jnp,
                                  max_count_schedule, pack_scan,
                                  priority_key, random_schedule,
                                  top_value_schedule)
from repro.core.wireless import (ChannelState, WirelessModel, cost_bisect,
                                 dbm_to_watt, rate_eq4)

__all__ = [
    "SCENARIOS", "AttackScenario", "FeatureNoise", "LabelFlip",
    "MaliciousSchedule", "ModelAttack", "NO_ATTACK", "ReportAttack",
    "as_scenario", "colluding", "feature_noise", "free_rider",
    "intermittent", "label_flip", "legacy_scenario", "lie_boost",
    "model_poison", "multi_flip", "recovery_rounds", "register",
    "reputation_gap",
    "ControlState", "finalize_runs", "schedule_runs",
    "DEFENSES", "DefensePolicy", "DefenseStats", "Krum", "Median",
    "NO_DEFENSE", "NormClip", "TrimmedMean", "ValidationDetector",
    "as_defense", "detection_stats", "krum", "median", "norm_clip",
    "trimmed_mean", "validation", "with_validation",
    "diversity_index", "diversity_index_eq2", "diversity_index_rows",
    "gini_simpson", "normalize", "normalize_last", "normalize_rows",
    "EASY_PAIR", "HARD_PAIR", "LabelFlipAttack", "pick_malicious",
    "adaptive_weights", "data_quality_value",
    "ReputationTracker", "reputation_update_eq1",
    "POLICIES", "POLICY_IDS", "Schedule", "best_channel_schedule",
    "brute_force_schedule", "dqs_schedule", "greedy_pack",
    "greedy_pack_jnp", "max_count_schedule", "pack_scan", "priority_key",
    "random_schedule", "top_value_schedule",
    "ChannelState", "WirelessModel", "cost_bisect", "dbm_to_watt",
    "rate_eq4",
]
