"""Decoder-only language models (dense / MoE / SSM / hybrid / VLM) built from
``repro.models.blocks``: init, train forward, prefill, and single-token decode.

DeepSeek-V3 extras supported here: ``first_dense_layers`` unrolled before the
scanned MoE stack, and the depth-1 multi-token-prediction (MTP) head.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import (cross_entropy, dtype_of, embed_init, ones,
                                 rms_norm, dense_init)
from repro.sharding.ctx import constrain


# ---------------------------------------------------------------------- #
# Init
# ---------------------------------------------------------------------- #
def lm_init(key, cfg):
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    d = cfg.d_model
    params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, d), dt),
        "blocks": blk.stacked_blocks_init(ks[1], cfg),
        "final_norm": ones((d,), dt),
        "lm_head": dense_init(ks[2], (d, cfg.vocab_size), dt),
    }
    if cfg.first_dense_layers:
        kind = {"mixer": "attn", "mlp": "dense"}
        hks = jax.random.split(ks[3], cfg.first_dense_layers)
        params["head_layers"] = tuple(blk.layer_init(k, cfg, kind) for k in hks)
    if cfg.mtp:
        kind = {"mixer": "attn", "mlp": "dense"}
        params["mtp"] = {
            "proj": dense_init(ks[4], (2 * d, d), dt, fan_in=2 * d),
            "norm_h": ones((d,), dt),
            "norm_e": ones((d,), dt),
            "layer": blk.layer_init(ks[5], cfg, kind),
        }
    return params


def _head_kind():
    return {"mixer": "attn", "mlp": "dense"}


# ---------------------------------------------------------------------- #
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------- #
def lm_forward(cfg, params, tokens, *, window=None, remat=False,
               return_cache=False):
    """tokens (B,S) int32 -> (logits (B,S,V), aux, cache|None, h_last)."""
    h = params["embed"][tokens].astype(dtype_of(cfg))
    h = constrain(h, "act")
    aux = 0.0
    head_caches = []
    for p in params.get("head_layers", ()):
        h, a, c = blk.layer_apply(cfg, p, _head_kind(), h, window=window,
                                  return_cache=return_cache)
        aux += a
        head_caches.append(c)
    h, a, caches = blk.scan_blocks(cfg, params["blocks"], h, window=window,
                                   return_cache=return_cache, remat=remat)
    aux += a
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = constrain(hn @ params["lm_head"], "logits")
    cache = None
    if return_cache:
        cache = {"blocks": caches, "head_layers": tuple(head_caches)}
    return logits, aux, cache, h


def lm_loss(cfg, params, batch, *, remat=False):
    """Next-token CE (+ MoE aux + optional MTP)."""
    tokens = batch["tokens"]
    window = cfg.sliding_window
    logits, aux, _, h = lm_forward(cfg, params, tokens, window=window,
                                   remat=remat)
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:])
    metrics = {"ce": loss}
    if cfg.mtp:
        mtp = params["mtp"]
        # depth-1 MTP: combine running hidden state with the embedding of the
        # *next* token, run one extra block, predict token t+2.
        nxt = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        e = params["embed"][nxt].astype(h.dtype)
        z = jnp.concatenate([rms_norm(h, mtp["norm_h"], cfg.norm_eps),
                             rms_norm(e, mtp["norm_e"], cfg.norm_eps)], -1)
        z = z @ mtp["proj"]
        z, a2, _ = blk.layer_apply(cfg, mtp["layer"], _head_kind(), z,
                                   window=window)
        aux += a2
        mtp_logits = rms_norm(z, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
        mtp_loss = cross_entropy(mtp_logits[:, :-2], tokens[:, 2:])
        loss = loss + 0.3 * mtp_loss
        metrics["mtp_ce"] = mtp_loss
    loss = loss + aux
    metrics["aux"] = aux
    return loss, metrics


# ---------------------------------------------------------------------- #
# Masked federated twins — the cohort engine's contract (models/mlp.py has
# the feature-model originals): client datasets are zero-padded to a
# uniform window count with a {0,1} per-window validity mask; a padded
# window must contribute *exactly* zero loss and gradient so the padded
# run reproduces the unpadded one. The window mask expands to per-TOKEN
# target weights (a target position counts iff both it and its input
# position are valid), so the same code path supports ragged windows.
# ---------------------------------------------------------------------- #
def _token_weights(tokens, m):
    """(B,) or (B, S) validity mask -> (B, S-1) next-token target weights."""
    m = jnp.asarray(m, jnp.float32)
    if m.ndim == 1:
        m = jnp.broadcast_to(m[:, None], tokens.shape)
    return m[:, 1:] * m[:, :-1]


def lm_loss_masked(cfg, params, batch, *, remat=False):
    """Masked next-token CE over the valid target positions of a batch.

    batch["tokens"] (B, S) int32; batch["m"] (B,) per-window or (B, S)
    per-token {0,1} validity. Padded positions carry weight 0: the loss is
    invariant to their token content and their gradient contribution is
    exactly zero (a fully padded batch is a strict parameter no-op). For a
    fully valid batch the masked mean reduces to the plain ``lm_loss``
    (weight sum == target count). MoE router aux is NOT masked — use
    aux-free (dense/ssm) configs for the federated task.
    """
    tokens = batch["tokens"]
    logits, aux, _, _ = lm_forward(cfg, params, tokens,
                                   window=cfg.sliding_window, remat=remat)
    w = _token_weights(tokens, batch["m"])
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:], mask=w)
    return loss + aux, {"ce": loss, "aux": aux}


def lm_accuracy_masked(cfg, params, tokens, m):
    """Masked next-token (greedy top-1) accuracy — the LM analogue of the
    MLP's masked local accuracy (Alg. 1 line 11); 0.0 on an empty mask."""
    logits, _, _, _ = lm_forward(cfg, params, tokens,
                                 window=cfg.sliding_window)
    correct = (jnp.argmax(logits[:, :-1], -1)
               == tokens[:, 1:]).astype(jnp.float32)
    w = _token_weights(tokens, m)
    return jnp.sum(correct * w) / jnp.maximum(jnp.sum(w), 1.0)


@partial(jax.jit, static_argnums=(0, 4))
def lm_sgd_epoch(cfg, params, tokens, lr, batch_size: int = 8):
    """One epoch of mini-batch SGD over a client's token windows (the
    federated loop oracle's path; mirrors ``mlp_sgd_epoch`` — a tail batch
    that does not fill ``batch_size`` is dropped)."""
    n = tokens.shape[0]
    nb = max(n // batch_size, 1)

    def body(params, i):
        tb = jax.lax.dynamic_slice_in_dim(tokens, i * batch_size, batch_size)
        g = jax.grad(lambda p: lm_loss(cfg, p, {"tokens": tb})[0])(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, 0.0

    params, _ = jax.lax.scan(body, params, jnp.arange(nb))
    return params


@partial(jax.jit, static_argnums=(0, 5))
def lm_sgd_epoch_masked(cfg, params, tokens, m, lr, batch_size: int = 8):
    """Masked twin of ``lm_sgd_epoch`` over a padded window set.

    tokens (S, seq), m (S,) with S a multiple of batch_size; batches that
    fall entirely in the padding leave params untouched. Same row-major
    reshape batch grid as ``mlp_sgd_epoch_masked``.
    """
    n = tokens.shape[0]
    assert n % batch_size == 0, (
        f"padded window count {n} must be a multiple of batch_size "
        f"{batch_size} (pad_clients(multiple_of=batch_size) guarantees this)")
    nb = n // batch_size
    tb = tokens.reshape(nb, batch_size, -1)
    mb = m.reshape(nb, batch_size)

    def body(params, batch):
        bt, bm = batch
        g = jax.grad(lambda p: lm_loss_masked(
            cfg, p, {"tokens": bt, "m": bm})[0])(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, 0.0

    params, _ = jax.lax.scan(body, params, (tb, mb))
    return params


# ---------------------------------------------------------------------- #
# Serving
# ---------------------------------------------------------------------- #
def decode_cache_len(cfg, seq_len: int):
    """(cache_len, is_ring). Ring caches are used for sliding-window archs and
    for the explicit long-context variant of full-attention archs."""
    win = cfg.sliding_window
    if seq_len > 32_768 and cfg.long_context_window and cfg.attn_layer_period == 0:
        win = (min(win, cfg.long_context_window) if win
               else cfg.long_context_window)
    if win and win < seq_len:
        return win, True
    return seq_len, False


def lm_cache_init(cfg, batch: int, seq_len: int):
    cache_len, ring = decode_cache_len(cfg, seq_len)
    cache = {
        "blocks": blk.stacked_cache_init(cfg, batch, cache_len),
        "head_layers": tuple(
            blk.layer_cache_init(cfg, _head_kind(), batch, cache_len)
            for _ in range(cfg.first_dense_layers)),
        "index": jnp.zeros((), jnp.int32),
    }
    if ring:
        cache["slot_pos"] = jnp.full((cache_len,), -1, jnp.int32)
    return cache


def lm_prefill(cfg, params, tokens, target_len: Optional[int] = None):
    """Prefill: returns (last-position logits, decode-ready cache)."""
    S = tokens.shape[1]
    logits, _, cache, _ = lm_forward(cfg, params, tokens,
                                     window=cfg.sliding_window,
                                     return_cache=True)
    cache = {"blocks": cache["blocks"], "head_layers": cache["head_layers"],
             "index": jnp.asarray(S, jnp.int32)}
    if target_len is not None and target_len > S:
        cache = grow_cache(cache, target_len - S)
    return logits[:, -1], cache


def grow_cache(cache, extra: int):
    """Pad linear attention caches by ``extra`` positions (prefill->decode)."""
    def pad(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ckv", "kr"):
            pads = [(0, 0)] * x.ndim
            pads[-3 if name in ("k", "v") else -2] = (0, extra)
            return jnp.pad(x, pads)
        return x
    return jax.tree_util.tree_map_with_path(pad, cache)


def lm_decode_step(cfg, params, cache, token):
    """token (B,1) int32 -> (logits (B,V), new cache)."""
    index = cache["index"]
    slot_pos = cache.get("slot_pos")
    window = cfg.sliding_window if slot_pos is None else None
    h = params["embed"][token].astype(dtype_of(cfg))
    h = constrain(h, "dec")
    new_head = []
    for p, c in zip(params.get("head_layers", ()), cache["head_layers"]):
        h, nc = blk.layer_decode(cfg, p, _head_kind(), h, c, index,
                                 slot_pos=slot_pos, window=window)
        new_head.append(nc)
    h, new_blocks = blk.scan_blocks_decode(cfg, params["blocks"], h,
                                           cache["blocks"], index,
                                           slot_pos=slot_pos, window=window)
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = hn[:, 0] @ params["lm_head"]
    new_cache = {"blocks": new_blocks, "head_layers": tuple(new_head),
                 "index": index + 1}
    if slot_pos is not None:
        C = slot_pos.shape[0]
        new_cache["slot_pos"] = slot_pos.at[index % C].set(index)
    return logits, new_cache
