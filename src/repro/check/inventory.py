"""Dead-inheritance inventory (DESIGN.md §11d) — report, not a gate.

The seed shipped a big-model serving stack (sharding/, launch/, the
MoE/SSM/MLA/encdec zoo, 11 big-model configs) most of which the FEEL
reproduction does not reach yet. ROADMAP.md makes claims about which of
it is still untouched; this inventory keeps those claims honest by
computing actual reachability: build the ``repro.*`` import graph,
take every module imported (transitively) from tests/, examples/ and
benchmarks/ as live, and report the rest with line counts.

Dead modules are NOT violations — several are named targets of open
ROADMAP items (e.g. launch/serve.py for the async/streaming engine).
The report exists so growth is a decision, not an accident; when a PR
revives a subsystem, tests/test_check.py pins it OFF this list so it
cannot silently lose its last caller (sharding/ + launch/mesh.py left
the list with the population plane, DESIGN.md §12).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set

from repro.check.common import CheckContext


def _module_name(rel: str) -> str:
    """'src/repro/core/attacks.py' -> 'repro.core.attacks'."""
    parts = Path(rel).with_suffix("").parts
    parts = parts[parts.index("repro"):]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _imports_of(tree: ast.Module, known: Set[str]) -> Set[str]:
    """repro.* modules referenced by a module's import statements."""
    out: Set[str] = set()

    def add(name: str) -> None:
        if name in known:
            out.add(name)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                add(a.name)
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.module.split(".")[0] == "repro":
            add(node.module)
            for a in node.names:
                add(f"{node.module}.{a.name}")
    return out


def build_inventory(ctx: CheckContext) -> Dict:
    modules: Dict[str, object] = {}      # module -> SourceFile
    for src in ctx.sources:
        modules[_module_name(src.rel)] = src
    known = set(modules)

    graph = {m: _imports_of(src.tree, known)
             for m, src in modules.items()}
    # a submodule implicitly keeps its package __init__ alive
    for m in list(graph):
        parts = m.split(".")
        for i in range(1, len(parts)):
            pkg = ".".join(parts[:i])
            if pkg in known:
                graph[m].add(pkg)

    roots: Set[str] = set()
    for d in ("tests", "examples", "benchmarks"):
        base = ctx.repo_root / d
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            try:
                tree = ast.parse(p.read_text())
            except SyntaxError:
                continue
            roots |= _imports_of(tree, known)

    live: Set[str] = set()
    frontier = list(roots)
    while frontier:
        m = frontier.pop()
        if m in live:
            continue
        live.add(m)
        frontier.extend(graph.get(m, ()))

    dead = sorted(known - live)
    records: List[Dict] = []
    for m in dead:
        src = modules[m]
        records.append({"module": m, "path": src.rel,
                        "loc": src.text.count("\n") + 1})
    total = sum(r["loc"] for r in records)
    by_pkg: Dict[str, int] = {}
    for r in records:
        pkg = r["module"].split(".")[1] if "." in r["module"] else "."
        by_pkg[pkg] = by_pkg.get(pkg, 0) + r["loc"]
    return {"n_modules": len(known), "n_live": len(live & known),
            "n_dead": len(dead), "dead_loc": total,
            "dead_by_package": dict(sorted(by_pkg.items())),
            "dead": records}
