"""The paper's contribution: data-quality based scheduling (DQS) for FEEL.

diversity (Eq. 2) + reputation (Eq. 1) -> data-quality value (Eq. 3);
wireless cost model (Eq. 4-7, 9); greedy-knapsack scheduler (Algorithm 2)
with baseline policies; label-flip poisoning (§III-B.1).
"""
from repro.core.diversity import diversity_index, gini_simpson, normalize
from repro.core.poisoning import (EASY_PAIR, HARD_PAIR, LabelFlipAttack,
                                  pick_malicious)
from repro.core.quality import adaptive_weights, data_quality_value
from repro.core.reputation import ReputationTracker
from repro.core.scheduler import (POLICIES, Schedule, best_channel_schedule,
                                  brute_force_schedule, dqs_schedule,
                                  max_count_schedule, random_schedule,
                                  top_value_schedule)
from repro.core.wireless import ChannelState, WirelessModel, dbm_to_watt

__all__ = [
    "diversity_index", "gini_simpson", "normalize",
    "EASY_PAIR", "HARD_PAIR", "LabelFlipAttack", "pick_malicious",
    "adaptive_weights", "data_quality_value", "ReputationTracker",
    "POLICIES", "Schedule", "best_channel_schedule", "brute_force_schedule",
    "dqs_schedule", "max_count_schedule", "random_schedule",
    "top_value_schedule", "ChannelState", "WirelessModel", "dbm_to_watt",
]
