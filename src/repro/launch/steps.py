"""Jittable train / prefill / decode steps shared by the launcher, the
dry-run and the benchmarks."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import api
from repro.optim import make_optimizer
from repro.optim.optimizers import clip_by_global_norm


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    opt = make_optimizer(tcfg)

    def train_step(params, opt_state, step, batch):
        def loss_fn(p):
            loss, metrics = api.loss(cfg, p, batch, remat=tcfg.remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        params, opt_state = opt.update(params, grads, opt_state, step, tcfg.lr)
        out = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, step + 1, out

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return api.prefill(cfg, params, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token):
        return api.decode_step(cfg, params, cache, token)
    return decode_step


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    params = api.init(cfg, key)
    opt = make_optimizer(tcfg)
    return params, opt.init(params), jnp.zeros((), jnp.int32)
