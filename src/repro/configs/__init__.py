from repro.configs.base import (FeelConfig, InputShape, MLAConfig, ModelConfig,
                                MoEConfig, SHAPES, SSMConfig, TrainConfig)
from repro.configs.registry import ARCHS, get, grid, list_archs, reduced

__all__ = ["FeelConfig", "InputShape", "MLAConfig", "ModelConfig", "MoEConfig",
           "SHAPES", "SSMConfig", "TrainConfig", "ARCHS", "get", "grid",
           "list_archs", "reduced"]
