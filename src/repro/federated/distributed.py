"""FEEL mapped onto a TPU mesh (DESIGN.md §3): the jax-native expression of
the paper's per-round communication pattern.

Each slice of the ``data`` axis hosts one cohort client: it trains a local
replica for ``local_steps`` SGD steps (``lax.fori_loop``), then the round's
FedAvg aggregation (Alg. 1 line 13) is a masked, size-weighted ``psum`` over
the client axes — with the DQS selection vector ``x_k`` as the mask, so an
unscheduled client contributes exactly nothing, like a UE that missed the
deadline. On the multi-pod mesh aggregation is hierarchical: intra-pod psum
(ICI) then inter-pod psum (DCI), mirroring BS -> MEC -> cloud edge
aggregation.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def make_cohort_step(mesh: Mesh, loss_fn: Callable, lr: float,
                     local_steps: int, client_axes: Tuple[str, ...] = ("data",),
                     agg_dtype=None):
    """Build the jitted distributed FEEL round step.

    loss_fn(params, batch) -> scalar. Batch leaves have a leading
    per-client axis sharded over ``client_axes``; ``weights`` and ``select``
    are (n_clients,) arrays sharded likewise. Params are replicated in and
    replicated (aggregated) out.
    """
    def local_sgd(params, batch):
        def step(_, p):
            g = jax.grad(loss_fn)(p, batch)
            return jax.tree.map(
                lambda w, gg: (w.astype(jnp.float32)
                               - lr * gg.astype(jnp.float32)).astype(w.dtype),
                p, g)
        return jax.lax.fori_loop(0, local_steps, step, params)

    def cohort_body(params, batch, weights, select):
        # strip the per-client leading axis (size 1 inside the shard)
        local_batch = jax.tree.map(lambda x: x[0], batch)
        w = (weights[0] * select[0]).astype(jnp.float32)
        local = local_sgd(params, local_batch)
        # hierarchical FedAvg: ICI first, then cross-pod. agg_dtype=bf16 is
        # the quantized-aggregation hillclimb lever (halves collective bytes;
        # the FedAvg mean itself stays fp32-accumulated per psum stage).
        def agg(leaf):
            dt = agg_dtype or jnp.float32
            s = jax.lax.psum((leaf.astype(jnp.float32) * w).astype(dt),
                             client_axes[-1])
            for ax in client_axes[:-1][::-1]:
                s = jax.lax.psum(s, ax)
            return s.astype(jnp.float32)
        wsum = agg(jnp.asarray(1.0))
        out = jax.tree.map(
            lambda l, p: (agg(l) / jnp.maximum(wsum, 1e-9)).astype(p.dtype),
            local, params)
        return out

    client_spec = P(client_axes)
    fn = shard_map(cohort_body, mesh=mesh,
                   in_specs=(P(), client_spec, client_spec, client_spec),
                   out_specs=P(),
                   check_rep=False)
    return jax.jit(fn)


def cohort_input_specs(mesh: Mesh, n_clients: int, batch_shapes: dict,
                       client_axes: Tuple[str, ...] = ("data",)):
    """ShapeDtypeStructs for the cohort step (dry-run helper)."""
    batch = {k: jax.ShapeDtypeStruct((n_clients,) + tuple(s), d)
             for k, (s, d) in batch_shapes.items()}
    vec = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
    return batch, vec, vec
