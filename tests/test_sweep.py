"""Batched multi-run sweep (run_sweep) vs the sequential run_experiment
oracle, plus the tidy-table / averaged() API contracts."""
import numpy as np
import pytest

from repro.core.poisoning import EASY_PAIR
from repro.federated.simulation import (SweepResult, averaged,
                                        run_experiment, run_sweep)

KW = dict(n_train=3000, n_test=400, rounds=4)


@pytest.fixture(scope="module")
def sweep() -> SweepResult:
    return run_sweep(["dqs", "random"], seeds=[0, 1],
                     attack_pairs=[EASY_PAIR], **KW)


def test_sweep_matches_sequential_run_experiment(sweep):
    """Every run of the stacked sweep must reproduce its sequential
    ``run_experiment`` twin: same RNG streams, same schedules, same
    accuracy curves. Global accuracy matches to float32 exactness;
    source_acc is a masked-sum instead of a subset-mean, so it is equal
    to ~1 ulp."""
    for run in sweep.runs:
        ref = run_experiment(run["policy"], run["attack_pair"],
                             seed=run["seed"], **KW)
        np.testing.assert_allclose(run["acc"], ref["acc"], atol=1e-7)
        np.testing.assert_allclose(run["source_acc"], ref["source_acc"],
                                   atol=1e-6)
        np.testing.assert_allclose(run["attack_success"],
                                   ref["attack_success"], atol=1e-6)
        np.testing.assert_allclose(run["rep_gap"], ref["rep_gap"],
                                   atol=1e-7)
        assert run["recovery_rounds"] == ref["recovery_rounds"]
        assert run["malicious_selected"] == ref["malicious_selected"]
        np.testing.assert_allclose(run["objective"], ref["objective"],
                                   atol=1e-9)
        assert run["malicious"] == ref["malicious"]
        np.testing.assert_allclose(
            run["final_reputation_honest"], ref["final_reputation_honest"],
            atol=1e-7)
        np.testing.assert_allclose(
            run["final_reputation_malicious"],
            ref["final_reputation_malicious"], atol=1e-7)


def test_stacked_matches_unstacked_sweep(sweep):
    """stack_runs=False (sequential execution, shared caches) is the
    oracle for the cross-run stacked path."""
    seq = run_sweep(["dqs", "random"], seeds=[0, 1],
                    attack_pairs=[EASY_PAIR], stack_runs=False, **KW)
    assert len(seq.runs) == len(sweep.runs)
    for a, b in zip(sweep.runs, seq.runs):
        assert (a["policy"], a["seed"]) == (b["policy"], b["seed"])
        np.testing.assert_allclose(a["acc"], b["acc"], atol=1e-7)
        assert a["malicious_selected"] == b["malicious_selected"]


def test_sweep_tidy_table(sweep):
    """rows is one record per (policy, seed, round) with the per-round
    metrics; mean_curve reduces over seeds."""
    assert len(sweep.rows) == 2 * 2 * KW["rounds"]
    r0 = sweep.rows[0]
    for field in ("policy", "seed", "scenario", "attack_pair", "round",
                  "acc", "source_acc", "attack_success",
                  "malicious_selected", "objective", "rep_gap", "forced"):
        assert field in r0, field
    curve = sweep.mean_curve("acc", policy="dqs")
    assert curve.shape == (KW["rounds"],)
    manual = np.mean([r["acc"] for r in sweep.runs
                      if r["policy"] == "dqs"], axis=0)
    np.testing.assert_allclose(curve, manual)
    assert len(sweep.select(policy="random", seed=1)) == 1


def test_partition_shared_across_policies(sweep):
    """Policies of the same (seed, attack pair) must see the same
    partition: identical malicious sets."""
    by_seed = {}
    for run in sweep.runs:
        by_seed.setdefault(run["seed"], []).append(run["malicious"])
    for mal_lists in by_seed.values():
        assert all(m == mal_lists[0] for m in mal_lists)


def test_averaged_runs_on_sweep():
    out = averaged("dqs", EASY_PAIR, n_runs=2, **KW)
    assert len(out["acc"]) == KW["rounds"]
    assert len(out["malicious_selected"]) == KW["rounds"]
    assert np.isfinite(out["rep_gap"])


def test_sweep_loop_engine_falls_back():
    """engine='loop' executes sequentially but returns the same table."""
    res = run_sweep(["dqs"], seeds=[0], attack_pairs=[EASY_PAIR],
                    engine="loop", n_train=3000, n_test=200, rounds=2)
    assert len(res.rows) == 2
    ref = run_experiment("dqs", EASY_PAIR, seed=0, engine="loop",
                         n_train=3000, n_test=200, rounds=2)
    np.testing.assert_allclose(res.runs[0]["acc"], ref["acc"], atol=1e-7)


def test_mean_curve_nan_aware_watch_metrics():
    """Regression (defense-plane PR): NaN watch-metric rows — a watch-less
    scenario's attack_success, undefined det_precision — must not poison
    cross-run means, and all-NaN slices stay NaN without numpy's all-NaN
    RuntimeWarning."""
    import warnings
    res = run_sweep(["dqs"], seeds=[0], scenarios=["none", "flip_6to2"],
                    n_train=1200, n_test=300, rounds=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # any RuntimeWarning fails
        # "none" has no watched pair -> attack_success all NaN
        none_curve = res.mean_curve("attack_success", scenario="none")
        assert np.isnan(none_curve).all()
        # mixing the NaN run with the watched run keeps the finite values
        mixed = res.mean_curve("attack_success")
        flip = res.mean_curve("attack_success", scenario="flip_6to2")
        np.testing.assert_allclose(mixed, flip)
        assert np.isfinite(mixed).all()
        # the bundle API rides the same reduction
        out = res.averaged(scenario="none")
        assert np.isfinite(out["acc"]).all()
        assert np.isnan(out["attack_success"]).all()


def test_sweep_rows_carry_defense_fields(sweep):
    r0 = sweep.rows[0]
    for field in ("defense", "n_clipped", "n_rejected", "n_flagged",
                  "det_precision", "det_recall"):
        assert field in r0, field
    assert r0["defense"] == "none"
