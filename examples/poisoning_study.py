"""Full paper-protocol reproduction of Fig. 2 and Fig. 3.

    PYTHONPATH=src python examples/poisoning_study.py [--fast]

Fig. 2 (§V-B.1): selection of the 5 highest-V_k UEs per round under three
omega weightings (diversity-only / reputation-only / both), for the easy
(6->2) and hard (8->4) label-flip pairs — no wireless constraint.

Fig. 3 (§V-B.2): full DQS (greedy knapsack + bandwidth costs) under the
wireless model. Reported in two regimes: the paper's literal 100 KB update
(bandwidth is slack -> near-full participation) and a constrained 5 MB update
where the knapsack binds (see EXPERIMENTS.md §Repro).

Writes results/poisoning_study.json and prints round-by-round curves.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.federated.simulation import run_sweep

OMEGAS = [("div_only", (0.0, 1.0)), ("rep_only", (1.0, 0.0)),
          ("both", (0.5, 0.5))]
PAIRS = [("easy_6to2", (6, 2)), ("hard_8to4", (8, 4))]


def curves(policies, scenario, omega, cfg, seeds, **kw):
    """One batched sweep over (policies x seeds) of one threat scenario;
    per-policy seed-averaged summaries. All seeds (and policies) of a
    setting run as stacked cohorts — one vmapped train/eval call per size
    bucket per round."""
    res = run_sweep(policies, seeds=seeds, scenarios=[scenario], cfg=cfg,
                    omega=omega, **kw)
    out = {}
    for policy in policies:
        runs = res.select(policy=policy)
        out[policy] = {
            "acc": [round(float(a), 4)
                    for a in res.mean_curve("acc", policy=policy)],
            "source_acc": [round(float(a), 4) for a in
                           res.mean_curve("source_acc", policy=policy)],
            "attack_success": [round(float(a), 4) for a in
                               res.mean_curve("attack_success",
                                              policy=policy)],
            "malicious_selected_mean":
                [round(float(m), 2) for m in
                 res.mean_curve("malicious_selected", policy=policy)],
            "recovery_rounds": [r["recovery_rounds"] for r in runs],
            "rep_gap": round(float(np.mean(
                [r["final_reputation_honest"]
                 - r["final_reputation_malicious"] for r in runs])), 4)}
    return out


def curve(policy, scenario, omega, cfg, seeds, **kw):
    return curves([policy], scenario, omega, cfg, seeds, **kw)[policy]


def _flip(pair):
    return atk.label_flip(*pair)


def _control(pair, tag):
    """Benign control that still watches the would-be pair's metrics."""
    return atk.AttackScenario(f"none_{tag}", watch=pair)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced scale (12k samples, 8 rounds, 2 seeds)")
    ap.add_argument("--engine", choices=["vectorized", "loop"],
                    default="vectorized",
                    help="cohort execution engine (the vectorized engine + "
                         "run_sweep batching make this multi-seed study "
                         "feasible; 'loop' is the sequential oracle)")
    args = ap.parse_args()
    if args.fast:
        kw = dict(n_train=12_000, n_test=2_000, rounds=8)
        seeds = (0, 1)
    else:
        kw = dict(n_train=50_000, n_test=10_000, rounds=15)  # paper protocol
        seeds = (0, 1, 2)
    kw["engine"] = args.engine

    results = {}
    t0 = time.time()
    for pair_tag, pair in PAIRS:
        # no-attack control: quantifies the damage the flip causes
        key = f"control_{pair_tag}_no_attack"
        results[key] = curve("dqs", _control(pair, pair_tag), (0.5, 0.5),
                             None, seeds, **kw)
        print(f"{key}: {results[key]['acc']} src={results[key]['source_acc']}")
        for om_tag, omega in OMEGAS:
            key = f"fig2_{pair_tag}_{om_tag}"
            results[key] = curve("top_value", _flip(pair), omega, None,
                                 seeds, **kw)
            print(f"{key}: {results[key]['acc']}")
        for regime, bits in [("paper_100KB", 100e3 * 8),
                             ("constrained_5MB", 5e6 * 8)]:
            cfg = FeelConfig(model_size_bits=bits)
            for om_tag, omega in OMEGAS:
                key = f"fig3_{pair_tag}_{regime}_{om_tag}"
                results[key] = curve("dqs", _flip(pair), omega, cfg,
                                     seeds, **kw)
                print(f"{key}: {results[key]['acc']}")
        # baselines for context — one batched sweep over all three policies
        base = curves(["random", "best_channel", "max_count"], _flip(pair),
                      (0.5, 0.5), FeelConfig(model_size_bits=5e6 * 8),
                      seeds, **kw)
        for pol, summary in base.items():
            key = f"baseline_{pair_tag}_{pol}"
            results[key] = summary
            print(f"{key}: {summary['acc']}")

    os.makedirs("results", exist_ok=True)
    with open("results/poisoning_study.json", "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote results/poisoning_study.json ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
