"""End-to-end LM training driver: any assigned architecture family at reduced
scale, or a ~100M dense preset, on synthetic token streams with the full
substrate (config -> data -> optimizer -> checkpointing).

    PYTHONPATH=src python examples/train_lm.py --preset smoke --steps 60
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch qwen2-moe-a2.7b --steps 40

(--arch trains the reduced smoke variant of that architecture's family;
--preset 100m is a 12-layer d=768 GQA decoder ~= 100M params.)
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import TrainConfig, get, reduced
from repro.configs.base import ModelConfig
from repro.data.tokens import batches, make_stream
from repro.launch.steps import init_state, make_train_step

PRESET_100M = ModelConfig(
    name="dense-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_ff=3072, vocab_size=32_000,
    citation="[in-repo 100M preset]")

PRESET_SMOKE = ModelConfig(
    name="dense-smoke", family="dense", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=1024, vocab_size=2_000,
    citation="[in-repo smoke preset]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.arch:
        cfg = dataclasses.replace(reduced(get(args.arch)), dtype="float32")
    elif args.preset == "100m":
        cfg = PRESET_100M
    else:
        cfg = dataclasses.replace(PRESET_SMOKE, dtype="float32")
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec archs: use the seq2seq batch layout "
                         "(see tests/test_models_smoke.py)")

    tcfg = TrainConfig(optimizer="adamw", lr=args.lr, remat=False)
    key = jax.random.PRNGKey(0)
    params, opt_state, step = init_state(cfg, tcfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"optimizer={tcfg.optimizer}")

    if args.ckpt:
        state, meta = restore(args.ckpt, (params, opt_state, step))
        if state is not None:
            params, opt_state, step = state
            print(f"restored step {meta['step']}")

    stream = make_stream(200_000, cfg.vocab_size, seed=0)
    it = batches(stream, args.batch, args.seq, np.random.default_rng(0))
    train_step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, step, m = train_step(params, opt_state, step, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {int(step):5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            save(args.ckpt, int(step), (params, opt_state, step))
            print(f"checkpointed step {int(step)}")
    final = float(m["loss"])
    print(f"done: final loss {final:.4f} "
          f"({args.steps} steps, {time.time()-t0:.0f}s)")
    assert np.isfinite(final)


if __name__ == "__main__":
    main()
