"""Population plane (core/population.py, DESIGN.md §12).

The contracts under test:

- PREFILTER PRESERVATION: ``prefilter_schedule_runs`` (top-M candidate
  cut + certificate + escalation) selects exactly the same cohort as the
  exact N-wide ``control.schedule_runs`` — for every packing policy,
  every M (certificate-passing AND escalated rows), both kernel layouts.
- SCATTER PARITY: ``scatter_finalize`` (O(K) sparse update of the
  N-wide state) is bitwise identical to the dense ``finalize_runs``
  hybrid path, and the ``t - last_sel`` age encoding reproduces the
  dense age trajectory in exact integers.
- N == K PINNING: ``population=n_ues`` is the legacy regime — same RNG
  streams, same schedules, same curves as ``population=None``.
- The revived mesh plumbing (launch.mesh + sharding.specs) shards the
  population axis without changing the schedule (subprocess, forced
  2-device host CPU).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import FeelConfig
from repro.core import control as ctl
from repro.core import population as pop
from repro.core.scheduler import POLICY_IDS

ALL_POLICIES = list(POLICY_IDS)


def _instance(seed, k, n, r=10):
    """Random (R, N) control instance cycling all five policies."""
    rng = np.random.default_rng(seed)
    cfg = FeelConfig(n_ues=k, population=n)
    state = ctl.ControlState(
        policy_id=np.array([POLICY_IDS[ALL_POLICIES[i % 5]]
                            for i in range(r)], np.int32),
        sizes=rng.uniform(100, 3000, (r, n)),
        divs=rng.uniform(0, 1, (r, n)),
        r_min=rng.uniform(1e4, 1e7, (r, n)),
        reputations=rng.uniform(0, 1, (r, n)),
        ages=rng.integers(1, 10, (r, n)).astype(float),
        cfg=cfg)
    gains = rng.exponential(1e-9, (r, n))
    rand_rank = np.stack([np.argsort(rng.permutation(n))
                          for _ in range(r)])
    omega = (np.full(r, cfg.omega_rep), np.full(r, cfg.omega_div))
    return cfg, state, gains, rand_rank, omega


# ---------------------------------------------------------------------- #
# Prefilter preservation
# ---------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1), st.integers(4, 12),
       st.sampled_from([2, 5, 12]))
@settings(max_examples=12, deadline=None)
def test_prefilter_matches_exact_all_policies(seed, k, n_factor):
    """Top-M prefilter == exact N-wide schedule, across all five packing
    policies, for M values that exercise BOTH the certificate-pass fast
    path and the escalation path (m down to min_selected)."""
    n = k * n_factor
    cfg, state, gains, rand_rank, omega = _instance(seed, k, n)
    exact = ctl.schedule_runs(state, gains, rand_rank, *omega,
                              kernel="hybrid")
    for m in {cfg.min_selected, max(k, cfg.min_selected), 2 * k, n}:
        out = pop.prefilter_schedule_runs(state, gains, rand_rank, *omega,
                                          m=m, kernel="hybrid")
        x, alpha, costs, values, forced, info = out
        np.testing.assert_array_equal(x, exact[0], err_msg=f"m={m}")
        np.testing.assert_array_equal(alpha, exact[1], err_msg=f"m={m}")
        np.testing.assert_array_equal(costs, exact[2], err_msg=f"m={m}")
        np.testing.assert_array_equal(values, exact[3], err_msg=f"m={m}")
        np.testing.assert_array_equal(forced, exact[4], err_msg=f"m={m}")
        assert info["m"] == min(m, n)


@given(st.integers(0, 2**31 - 1), st.integers(4, 10))
@settings(max_examples=6, deadline=None)
def test_prefilter_jax_matches_exact(seed, k):
    """The jax prefilter layout (lax.top_k cut, shardable) picks the
    same UEs/costs/forced as the exact path; alpha to ~1 ulp."""
    n = 8 * k
    cfg, state, gains, rand_rank, omega = _instance(seed, k, n)
    exact = ctl.schedule_runs(state, gains, rand_rank, *omega,
                              kernel="hybrid")
    for m in (cfg.min_selected + 1, 2 * k):
        x, alpha, costs, values, forced, _ = pop.prefilter_schedule_runs(
            state, gains, rand_rank, *omega, m=m, kernel="jax")
        np.testing.assert_array_equal(x, exact[0], err_msg=f"m={m}")
        np.testing.assert_array_equal(costs, exact[2], err_msg=f"m={m}")
        np.testing.assert_array_equal(forced, exact[4], err_msg=f"m={m}")
        np.testing.assert_allclose(alpha, exact[1], rtol=1e-14, atol=0)


def test_prefilter_escalation_is_exercised():
    """A tiny M must trip the preservation certificate on some rows (and
    the escalated rows still match the exact schedule — covered above);
    a full-width M never escalates."""
    cfg, state, gains, rand_rank, omega = _instance(0, 8, 64)
    esc = 0
    for seed in range(5):
        _, state, gains, rand_rank, omega = _instance(seed, 8, 64)
        *_, info = pop.prefilter_schedule_runs(
            state, gains, rand_rank, *omega, m=cfg.min_selected,
            kernel="hybrid")
        esc += info["n_escalated"]
    assert esc > 0, "certificate never failed at the minimum M"
    *_, info = pop.prefilter_schedule_runs(state, gains, rand_rank,
                                           *omega, m=64, kernel="hybrid")
    assert info["n_escalated"] == 0 and info["m"] == 64


@given(st.integers(0, 2**31 - 1), st.integers(3, 30))
@settings(max_examples=20, deadline=None)
def test_topm_prefix_is_stable_argsort_prefix(seed, m):
    """_topm_prefix == the stable ascending argsort prefix, including
    heavy ties (small integer key alphabet)."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 6, (4, 40)).astype(float)
    m = min(m, keys.shape[1])
    got = pop._topm_prefix(keys, m)
    want = np.argsort(keys, axis=-1, kind="stable")[:, :m]
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------- #
# Scatter finalize / PopulationState
# ---------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_scatter_finalize_bitwise_matches_dense(seed):
    """scatter_finalize (sparse K-sized writes into the N-wide state) ==
    finalize_runs (dense hybrid path) bitwise, over several rounds with
    empty cohorts and defense penalties mixed in; the t - last_sel age
    encoding reproduces the dense ages exactly."""
    rng = np.random.default_rng(seed)
    R, N, K = 6, 50, 10
    cfg = FeelConfig(n_ues=K, population=N)
    dense = ctl.ControlState(
        policy_id=np.zeros(R, np.int32),
        sizes=rng.uniform(100, 3000, (R, N)),
        divs=rng.uniform(0, 1, (R, N)),
        r_min=rng.uniform(1e4, 1e7, (R, N)),
        reputations=rng.uniform(0, 1, (R, N)),
        ages=np.ones((R, N)), cfg=cfg)
    ps = pop.PopulationState.from_control(dense, t=0)
    assert np.all(ps.last_sel == -1)            # dense ages started at 1
    for t in range(4):
        np.testing.assert_array_equal(ps.ages(t), dense.ages)
        sels, als, ats, pens = [], [], [], []
        for i in range(R):
            sel = rng.choice(N, size=rng.integers(0, K), replace=False)
            sels.append(sel)
            als.append(rng.uniform(0, 1, sel.size))
            ats.append(rng.uniform(0, 1, sel.size))
            pens.append(rng.uniform(0, 0.01, sel.size) if i % 2 else None)
        ctl.finalize_runs(dense, sels, als, ats, penalties=pens,
                          kernel="hybrid")
        pop.scatter_finalize(ps, t, sels, als, ats, penalties=pens)
        np.testing.assert_array_equal(ps.reputations, dense.reputations)
    np.testing.assert_array_equal(ps.ages(4), dense.ages)


def test_control_view_shares_buffers():
    """control_view is a zero-copy scheduling view: reputations are the
    SAME buffer, ages are materialized for the requested round."""
    _, state, *_ = _instance(3, 6, 24)
    ps = pop.PopulationState.from_control(state, t=2)
    cv = ps.control_view(t=2)
    assert cv.reputations is ps.reputations
    np.testing.assert_array_equal(cv.ages, state.ages)
    assert ps.n_population == 24 and ps.n_runs == state.n_runs
    assert ps.nbytes() > 0
    assert pop.bytes_per_device(ps, 2) < ps.nbytes()


def test_population_config_contract():
    cfg = FeelConfig(n_ues=10)
    assert cfg.n_population == 10                 # legacy N == K
    assert FeelConfig(n_ues=10, population=40).n_population == 40
    with pytest.raises(AssertionError):
        FeelConfig(n_ues=10, population=5).n_population
    assert pop.default_m(FeelConfig(n_ues=10, population=1000)) == 80
    assert pop.default_m(FeelConfig(n_ues=10, population=40)) == 40


# ---------------------------------------------------------------------- #
# N == K pinning + end-to-end population runs
# ---------------------------------------------------------------------- #
KW = dict(n_train=2500, n_test=300, rounds=2)


def test_population_equal_k_is_legacy_regime():
    """population=n_ues must reproduce population=None bit-for-bit: same
    RNG streams, same schedules (the prefilter delegates at M >= N),
    same curves."""
    from repro.federated.simulation import run_experiment
    a = run_experiment(policy="dqs", seed=0, **KW)
    b = run_experiment(policy="dqs", seed=0, population=50, **KW)
    assert a["acc"] == b["acc"]
    assert a["malicious"] == b["malicious"]
    assert a["objective"] == b["objective"]


def test_population_cut_end_to_end():
    """N > K: the sweep schedules over all N candidates through the
    prefilter, trains only the scheduled cohorts, and matches its
    sequential run_experiment twin exactly."""
    from repro.federated.simulation import run_experiment, run_sweep
    r = run_experiment(policy="dqs", seed=0, population=120, **KW)
    assert np.isfinite(r["acc"]).all()
    res = run_sweep(["dqs"], seeds=[0], population=120, **KW)
    assert res.select(policy="dqs", seed=0)[0]["acc"] == r["acc"]


# ---------------------------------------------------------------------- #
# Mesh plumbing (launch.mesh + sharding.specs revival)
# ---------------------------------------------------------------------- #
def test_mesh_helpers_single_device():
    import jax
    from jax.sharding import NamedSharding

    mesh = pop.population_mesh()
    assert mesh.axis_names == ("data", "model")
    arr = np.arange(12.0).reshape(3, 4)
    sharded = pop.shard_population(mesh, arr)
    assert isinstance(sharded.sharding, NamedSharding)
    np.testing.assert_array_equal(np.asarray(sharded), arr)


_MESH_PARITY = r"""
import numpy as np, jax
from tests.test_population import _instance
from repro.core import control as ctl
from repro.core import population as pop

assert len(jax.devices()) == 2
mesh = pop.population_mesh()
assert mesh.devices.size == 2
cfg, state, gains, rand_rank, omega = _instance(11, 8, 160)
exact = ctl.schedule_runs(state, gains, rand_rank, *omega,
                          kernel="hybrid")
x, _, costs, _, forced, info = pop.prefilter_schedule_runs(
    state, gains, rand_rank, *omega, m=32, kernel="jax", mesh=mesh)
np.testing.assert_array_equal(x, exact[0])
np.testing.assert_array_equal(costs, exact[2])
np.testing.assert_array_equal(forced, exact[4])
print("MESH-PARITY-OK")
"""


def test_prefilter_sharded_mesh_parity():
    """Forced 2-device host mesh (subprocess: conftest pins no XLA_FLAGS
    in-process): the GSPMD-sharded prefilter kernel still selects the
    exact cohort."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-c", _MESH_PARITY], capture_output=True,
        text=True, timeout=600,
        env={**os.environ,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
             "PYTHONPATH": os.pathsep.join(
                 [os.path.join(root, "src"), os.path.join(root, "tests"),
                  root, os.environ.get("PYTHONPATH", "")])})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MESH-PARITY-OK" in r.stdout
