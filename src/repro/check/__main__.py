"""CLI: ``python -m repro.check [--strict] [--json] [--out PATH]``.

Prints every violation as ``path:line: [rule] message`` plus the
dead-inheritance inventory summary. ``--strict`` exits non-zero on any
violation (the tier-1 gate and CI mode); without it the run is a
report. ``--json`` additionally writes ``results/check_report.json``
keyed by commit, the same meta schema as the BENCH_* writers
(benchmarks/bench_round.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import datetime
import json
import os
import platform
import subprocess
import sys
from pathlib import Path

from repro.check import repo_root, run_checks


def _meta():
    """Commit/env metadata — mirrors benchmarks/bench_round._bench_meta."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=str(repo_root())).stdout.strip() or "unknown"
    except OSError:
        commit = "unknown"

    def ver(pkg):
        try:
            import importlib.metadata
            return importlib.metadata.version(pkg)
        except Exception:                           # noqa: BLE001
            return "unknown"

    return {"commit": commit, "python": platform.python_version(),
            "jax": ver("jax"), "numpy": ver("numpy"),
            "timestamp": datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds")}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-check",
        description="Static contract checker (DESIGN.md §11)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any violation (tier-1 / CI mode)")
    ap.add_argument("--json", action="store_true", dest="json_out",
                    help="write the report to results/check_report.json")
    ap.add_argument("--out", type=Path, default=None,
                    help="override the --json report path")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root to check (default: this repo)")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the (slower) abstract-trace checks")
    args = ap.parse_args(argv)

    report = run_checks(args.root, skip_trace=args.no_trace)

    for v in report.violations:
        print(v.format())
    inv = report.inventory
    print(f"\ncheckers: " + ", ".join(
        f"{k}={'skipped' if n < 0 else n}"
        for k, n in report.per_checker.items()))
    print(f"dead-inheritance: {inv['n_dead']}/{inv['n_modules']} modules "
          f"unreachable from tests/examples/benchmarks "
          f"({inv['dead_loc']} LoC): " + ", ".join(
              f"{pkg}={loc}" for pkg, loc in
              inv["dead_by_package"].items()))

    if args.json_out or args.out:
        out = args.out or (repo_root() / "results" / "check_report.json")
        out.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "check": "contracts",
            "meta": _meta(),
            "ok": report.ok,
            "per_checker": report.per_checker,
            "violations": [dataclasses.asdict(v)
                           for v in report.violations],
            "inventory": {k: inv[k] for k in
                          ("n_modules", "n_live", "n_dead", "dead_loc",
                           "dead_by_package", "dead")},
        }
        out.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {os.path.relpath(out)}")

    if report.ok:
        print("contracts: clean")
        return 0
    print(f"contracts: {len(report.violations)} violation(s)")
    return 1 if args.strict else 0


if __name__ == "__main__":
    sys.exit(main())
