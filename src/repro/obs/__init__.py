"""Observability plane (DESIGN.md §14).

Structured telemetry for the round pipeline: a span tracer with a
context-manager API (``obs/trace.py``), a counter/gauge registry
(``obs/metrics.py``), and the repo's ONLY sanctioned wall-clock site
(``obs/clock.py`` — enforced by the ``repro.check`` nondeterminism
lint).  The hard contract is **zero semantic footprint**: telemetry
never touches the RNG stream of record, f64 accumulation order, or any
traced value, and the disabled tracer (``REPRO_TRACE=0``, the default)
is a shared-singleton no-op.

Sinks: in-memory ring, JSONL trace file keyed commit+env (like
``BENCH_history.jsonl``), Chrome/Perfetto ``trace_event`` export, and
``python -m repro.obs.report`` for per-phase p50/p95 + roofline
context.
"""
from repro.obs import trace  # noqa: F401

__all__ = ["trace"]
