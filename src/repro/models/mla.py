"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Faithful low-rank structure: compressed KV latent ``c_kv`` (kv_lora_rank) +
decoupled shared RoPE key (qk_rope_head_dim). Prefill/train expands the latent;
decode uses the *absorbed* formulation (W_uk folded into the query, W_uv applied
after attention) so the cache holds only (kv_lora_rank + rope_dim) per token —
the actual MLA memory win, visible in dry-run cache bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, dtype_of, ones, rms_norm


def mla_init(key, cfg):
    m, d = cfg.mla, cfg.d_model
    H = cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank), dt),
        "q_norm": ones((m.q_lora_rank,), dt),
        "wuq": dense_init(ks[1], (m.q_lora_rank, H * qk_hd), dt),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank), dt),
        "kv_norm": ones((m.kv_lora_rank,), dt),
        "wkr": dense_init(ks[3], (d, m.qk_rope_head_dim), dt),
        "wuk": dense_init(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim), dt),
        "wuv": dense_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim), dt),
        "wo": dense_init(ks[6], (H * m.v_head_dim, d), dt,
                         fan_in=H * m.v_head_dim),
    }


def _queries(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(cfg, p, x, *, window=None, positions=None):
    """Full-sequence (train / prefill). Returns (y, cache=(c_kv, k_rope))."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.arange(S)[None]
    q_nope, q_rope = _queries(cfg, p, x, positions)

    ckv = rms_norm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)     # (B,S,r)
    k_rope = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]                   # (B,S,dr)
    k_nope = (ckv @ p["wuk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (ckv @ p["wuv"]).reshape(B, S, H, m.v_head_dim)

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshd,btd->bhst", q_rope, k_rope))
    logits = logits.astype(jnp.float32) * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window is not None:
        mask &= (i - j) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, -1)
    return out @ p["wo"], (ckv, k_rope)


def mla_decode(cfg, p, x, cache_ckv, cache_kr, index, *, slot_pos=None,
               window=None):
    """Absorbed single-token decode over the compressed cache.

    cache_ckv (B,C,r), cache_kr (B,C,dr). Returns (y, ckv, kr, slot_pos).
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos = jnp.full((B, 1), index)
    q_nope, q_rope = _queries(cfg, p, x, pos)                      # (B,1,H,*)

    ckv_new = rms_norm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)  # (B,1,r)
    kr_new = apply_rope((x @ p["wkr"])[:, :, None, :], pos,
                        cfg.rope_theta)[:, :, 0]                   # (B,1,dr)
    C = cache_ckv.shape[1]
    slot = index % C if slot_pos is not None else index
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(cache_ckv, ckv_new, slot, 1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(cache_kr, kr_new, slot, 1)
    if slot_pos is not None:
        slot_pos = slot_pos.at[slot].set(index)
        valid = slot_pos >= 0
    else:
        j = jnp.arange(C)
        valid = j <= index
        if window is not None:
            valid &= j > index - window

    # absorb W_uk into q: q_lat (B,1,H,r)
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, cache_ckv)
              + jnp.einsum("bshd,btd->bhst", q_rope, cache_kr))
    logits = logits.astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, cache_ckv)       # (B,1,H,r)
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", out_lat, wuv).reshape(B, 1, -1)
    return out @ p["wo"], cache_ckv, cache_kr, slot_pos
