from repro.federated.aggregation import (fedavg, fedavg_stacked,
                                         normalize_weights)
from repro.federated.client import ClientReport, local_train
from repro.federated.cohort import cohort_eval, cohort_train
from repro.federated.server import (CohortData, FeelServer, RoundLog,
                                    build_cohort_data)
from repro.federated.simulation import (SweepResult, averaged,
                                        run_experiment, run_sweep)
from repro.federated.task import TASKS, FeelTask, LmTask, MnistTask, as_task

__all__ = ["fedavg", "fedavg_stacked", "normalize_weights", "ClientReport",
           "local_train", "cohort_eval", "cohort_train", "CohortData",
           "FeelServer", "RoundLog", "build_cohort_data", "SweepResult",
           "averaged", "run_experiment", "run_sweep", "TASKS", "FeelTask",
           "LmTask", "MnistTask", "as_task"]
