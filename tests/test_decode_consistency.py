"""Serving-path correctness: prefill + step-by-step decode must reproduce the
full-sequence forward logits exactly (fp32, no-drop MoE capacity)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get, list_archs, reduced
from repro.models import api, encdec as ed, transformer as tf


def _exact_cfg(arch):
    cfg = reduced(get(arch))
    kw = {"dtype": "float32"}
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, **kw)


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    cfg = _exact_cfg(arch)
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    B, S, P = 2, 32, 24
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.is_encoder_decoder:
        batch["src"] = jax.random.normal(key, (B, 16, cfg.d_model))
        full, _, _, _ = ed.encdec_forward(cfg, params, batch["src"], tok)
    else:
        full, _, _, _ = tf.lm_forward(cfg, params, tok,
                                      window=cfg.sliding_window)
    pre = dict(batch)
    pre["tokens"] = tok[:, :P]
    logits, cache = api.prefill(cfg, params, pre, target_len=S)
    errs = [float(jnp.max(jnp.abs(logits - full[:, P - 1])))]
    for t in range(P, S):
        logits, cache = api.decode_step(cfg, params, cache, tok[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
    assert max(errs) < 1e-3, f"{arch}: prefill/decode divergence {max(errs)}"


def test_ring_buffer_matches_linear_window():
    """Sliding-window ring cache == linear cache with window mask
    (starcoder2 family)."""
    cfg = _exact_cfg("starcoder2-15b")
    assert cfg.sliding_window
    key = jax.random.PRNGKey(3)
    params = api.init(cfg, key)
    B, S = 1, 48
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _, _, _ = tf.lm_forward(cfg, params, tok, window=cfg.sliding_window)
    # pure decode from scratch with a ring cache of exactly window size
    cache = tf.lm_cache_init(cfg, B, S)
    assert "slot_pos" in cache, "expected a ring cache"
    errs = []
    for t in range(S):
        logits, cache = api.decode_step(cfg, params, cache, tok[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
    assert max(errs) < 1e-3, f"ring-cache divergence {max(errs)}"


def test_mla_compressed_cache_is_small():
    """The MLA decode cache must store the compressed latent, not full K/V."""
    cfg = _exact_cfg("deepseek-v3-671b")
    cache = jax.eval_shape(lambda: api.cache_init(cfg, 1, 64))
    leaves = jax.tree_util.tree_leaves_with_path(cache)
    names = {p[-1].key for p, _ in leaves if hasattr(p[-1], "key")}
    assert "ckv" in names and "k" not in names
