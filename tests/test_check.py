"""Tier-1 gate + self-tests for the static contract suite (DESIGN.md §11).

Three layers:
- the repo itself must be CLEAN under every checker (`run_checks` with
  the trace pass included — this is `python -m repro.check --strict`);
- the CLI contract: `--json` writes the commit-keyed report, `--strict`
  exit codes;
- per-checker self-tests: mutate a known-good snippet (inject `jnp`
  into an oracle, branch on a tracer, draw from the global RNG, drop a
  registry entry, delete a kernel's `_ref` twin, promote to f64) and
  assert the checker catches exactly that injection — a checker that
  cannot detect its own target rule is silently useless.
"""
import ast
import json
import textwrap
import types

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.check import CHECKERS, run_checks
from repro.check.__main__ import main as check_main
from repro.check.common import SourceFile, parse_waivers
from repro.check.lints import (check_nondeterminism, lint_dtype_f64,
                               lint_masked_mean, lint_nondeterminism,
                               lint_oracle_purity, lint_tracer_leak,
                               lint_wall_clock)
from repro.check.registry import kernel_ref_twins, registry_coverage
from repro.check.trace import (_static_spec_literal, assert_f64_outputs,
                               assert_no_f64)


def _src(text: str) -> SourceFile:
    return SourceFile.from_text(textwrap.dedent(text))


# ---------------------------------------------------------------------- #
# the repo is clean (the tier-1 gate: `python -m repro.check --strict`)
# ---------------------------------------------------------------------- #
def test_repo_is_clean_strict():
    report = run_checks()
    assert [v.format() for v in report.violations] == []
    assert report.ok
    assert set(report.per_checker) == set(CHECKERS)
    inv = report.inventory
    assert inv["n_modules"] == inv["n_live"] + inv["n_dead"]
    assert inv["n_modules"] > 50            # the import graph was walked
    assert inv["dead_loc"] == sum(m["loc"] for m in inv["dead"])


def test_population_plane_revived_sharding_stack():
    """The population plane (DESIGN.md §12) revived part of the seed's
    big-model serving inheritance: core.population imports launch.mesh
    and sharding.specs, so both must now be LIVE in the
    dead-inheritance inventory — if either falls back onto the dead
    list, the million-UE mesh path silently lost its only caller."""
    inv = run_checks().inventory
    dead = {m["module"] for m in inv["dead"]}
    for mod in ("repro.core.population", "repro.launch.mesh",
                "repro.sharding.specs"):
        assert mod not in dead, f"{mod} regressed to dead inheritance"
    assert not any(m.startswith("repro.sharding") for m in dead), dead


def test_async_plane_revived_serve_launcher():
    """The async engine (DESIGN.md §13) revived launch/serve.py from the
    seed's dead decode launcher into the event-driven simulation driver:
    it and the engine itself must be LIVE in the dead-inheritance
    inventory — falling back onto the dead list means the async plane
    silently lost its only caller."""
    inv = run_checks().inventory
    dead = {m["module"] for m in inv["dead"]}
    for mod in ("repro.launch.serve", "repro.federated.async_engine"):
        assert mod not in dead, f"{mod} regressed to dead inheritance"


def test_observability_plane_live_obs_and_roofline():
    """PR 10 (DESIGN.md §14) built the obs package and wired
    launch/roofline.py into a production consumer (repro.obs.report's
    roofline context) — all of it must be LIVE in the dead-inheritance
    inventory, or the telemetry plane silently lost its last caller."""
    inv = run_checks().inventory
    dead = {m["module"] for m in inv["dead"]}
    for mod in ("repro.obs", "repro.obs.trace", "repro.obs.clock",
                "repro.obs.metrics", "repro.obs.report",
                "repro.launch.roofline"):
        assert mod not in dead, f"{mod} regressed to dead inheritance"


def test_cli_strict_json_report(tmp_path):
    out = tmp_path / "check_report.json"
    rc = check_main(["--strict", "--json", "--out", str(out),
                     "--no-trace"])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["check"] == "contracts" and payload["ok"]
    # same meta schema as the BENCH_* writers
    assert set(payload["meta"]) == {"commit", "python", "jax", "numpy",
                                    "timestamp"}
    assert payload["violations"] == []
    assert payload["per_checker"]["trace"] == -1        # --no-trace
    assert payload["per_checker"]["oracle-purity"] == 0
    assert payload["inventory"]["n_modules"] > 0


def test_checker_registry_names():
    assert list(CHECKERS) == [
        "oracle-purity", "tracer-leak", "nondeterminism", "dtype",
        "registry-coverage", "kernel-ref-twin", "static-args", "trace"]


# ---------------------------------------------------------------------- #
# self-test: oracle purity
# ---------------------------------------------------------------------- #
def test_oracle_purity_catches_injected_jnp():
    good = _src("""
        import numpy as np
        import jax.numpy as jnp

        def agg_oracle(x):
            return np.sum(x, axis=0)

        def agg_batched(x):
            return jnp.sum(x, axis=0)     # non-oracle: jnp is fine
    """)
    assert lint_oracle_purity(good) == []
    bad = _src("""
        import numpy as np
        import jax.numpy as jnp

        def agg_oracle(x):
            return jnp.sum(x, axis=0)
    """)
    vs = lint_oracle_purity(bad)
    assert len(vs) == 1 and vs[0].rule == "oracle-purity"
    assert "agg_oracle" in vs[0].message
    # the *_host suffix is reserved too
    host = _src("""
        import jax

        def eval_host(p):
            return jax.tree.map(lambda l: l, p)
    """)
    assert [v.rule for v in lint_oracle_purity(host)] == ["oracle-purity"]


# ---------------------------------------------------------------------- #
# self-test: tracer leaks
# ---------------------------------------------------------------------- #
def test_tracer_leak_catches_branch_on_traced_arg():
    bad = _src("""
        import jax

        @jax.jit
        def step(x):
            if x > 0:
                return x
            return -x
    """)
    vs = lint_tracer_leak(bad)
    assert len(vs) == 1 and vs[0].rule == "tracer-leak"
    assert "`if`" in vs[0].message


def test_tracer_leak_catches_host_conversion():
    bad = _src("""
        import jax

        @jax.jit
        def step(x):
            return float(x) * 2.0
    """)
    assert [v.rule for v in lint_tracer_leak(bad)] == ["tracer-leak"]


def test_tracer_leak_exemptions():
    # static args are Python values; shape attrs are trace-static;
    # un-jitted functions may do anything
    good = _src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("k",))
        def step(x, k):
            if k > 2:
                return x
            if x.shape[0] > 4:
                return -x
            return x

        def host_side(x):
            if x > 0:
                return float(x)
            return 0.0
    """)
    assert lint_tracer_leak(good) == []


# ---------------------------------------------------------------------- #
# self-test: nondeterminism
# ---------------------------------------------------------------------- #
def test_nondeterminism_catches_global_rng_and_clocks():
    bad = _src("""
        import time
        import numpy as np

        def sample():
            t = time.time()
            u = np.random.normal(size=3)
            rng = np.random.default_rng()
            return t, u, rng
    """)
    vs = lint_nondeterminism(bad)
    assert len(vs) == 3
    assert all(v.rule == "nondeterminism" for v in vs)
    good = _src("""
        import numpy as np

        def sample(seed):
            rng = np.random.default_rng(seed)
            return rng.normal(size=3)
    """)
    assert lint_nondeterminism(good) == []


def test_nondeterminism_catches_wall_clock_in_async_engine_style_code():
    """The async engine's event clock must come from the Eq. 6/7 latency
    model on seeded draws — wall-clock reads (and sleeps) in an
    async-engine-styled event loop are violations, and the engine's
    module path is inside the lint's simulation scope."""
    from repro.check.lints import _in_scope
    bad = SourceFile.from_text(textwrap.dedent("""
        import heapq
        import time

        def run(heap):
            while heap:
                t_arr, e = heapq.heappop(heap)
                time.sleep(t_arr - time.time())
                yield e
    """), rel="src/repro/federated/async_engine.py")
    vs = lint_nondeterminism(bad)
    assert len(vs) == 2 and all(v.rule == "nondeterminism" for v in vs)
    assert any("sleep" in v.message for v in vs)
    assert any("time.time" in v.message for v in vs)
    assert _in_scope(bad)
    # launch/ is outside the SIMULATION lint's scope (ad-hoc seeds are
    # fine there) — but the wall-clock half still applies repo-wide
    # through lint_wall_clock (tests below)
    assert not _in_scope(SourceFile.from_text(
        "x = 1", rel="src/repro/launch/serve.py"))


def test_wall_clock_lint_outside_sanctioned_site():
    """Telemetry contract (DESIGN.md §14): direct time-module clock
    reads anywhere under src/repro are violations EXCEPT in
    obs/clock.py — host tooling routes through
    ``repro.obs.clock.wall_clock``."""
    bad = SourceFile.from_text(textwrap.dedent("""
        import time

        def timed():
            return time.perf_counter()
    """), rel="src/repro/launch/serve.py")
    vs = lint_wall_clock(bad)
    assert len(vs) == 1 and vs[0].rule == "nondeterminism"
    assert "repro.obs.clock" in vs[0].message
    # the from-import alias form is caught too
    alias = SourceFile.from_text(textwrap.dedent("""
        from time import monotonic

        def timed():
            return monotonic()
    """), rel="src/repro/launch/dryrun.py")
    assert len(lint_wall_clock(alias)) == 1
    # no time import at all -> clean
    assert lint_wall_clock(SourceFile.from_text(
        "x = 1", rel="src/repro/launch/serve.py")) == []
    # the shared rule id means the existing waiver mechanism covers the
    # repo-wide rule too
    waived = SourceFile.from_text(textwrap.dedent("""
        import time

        def timed():
            # repro: allow(nondeterminism)
            return time.time()
    """), rel="src/repro/launch/serve.py")
    assert lint_wall_clock(waived) == []


def test_check_nondeterminism_exempts_only_obs_clock():
    """check_nondeterminism dispatch: simulation dirs get the full
    lint, every other src/repro file gets the wall-clock half, and
    obs/clock.py — the one sanctioned site — is exempt."""
    code = "import time\n\ndef t():\n    return time.monotonic()\n"

    def ctx(rel):
        return types.SimpleNamespace(
            sources=[SourceFile.from_text(code, rel=rel)])

    assert check_nondeterminism(ctx("src/repro/obs/clock.py")) == []
    assert len(check_nondeterminism(ctx("src/repro/obs/trace.py"))) == 1
    assert len(check_nondeterminism(
        ctx("src/repro/launch/serve.py"))) == 1
    assert len(check_nondeterminism(
        ctx("src/repro/federated/server.py"))) == 1     # full sim lint
    assert check_nondeterminism(ctx("tests/whatever.py")) == []


def test_waiver_comment_suppresses_rule():
    waived = _src("""
        import numpy as np

        def sample():
            # repro: allow(nondeterminism)
            return np.random.normal(size=3)
    """)
    assert lint_nondeterminism(waived) == []
    # a waiver for a DIFFERENT rule does not suppress
    other = _src("""
        import numpy as np

        def sample():
            # repro: allow(dtype-f64)
            return np.random.normal(size=3)
    """)
    assert len(lint_nondeterminism(other)) == 1


# ---------------------------------------------------------------------- #
# self-test: dtype discipline
# ---------------------------------------------------------------------- #
def test_dtype_f64_requires_x64_scope():
    bad = _src("""
        import jax.numpy as jnp

        def promote(x):
            return x.astype(jnp.float64)
    """)
    assert [v.rule for v in lint_dtype_f64(bad)] == ["dtype-f64"]
    good = _src("""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        def promote(x):
            with enable_x64():
                return x.astype(jnp.float64)
    """)
    assert lint_dtype_f64(good) == []


def test_masked_mean_pin():
    bad = _src("""
        import jax.numpy as jnp

        def mean(x, m):
            return jnp.sum(x * m) / jnp.sum(m)
    """)
    assert [v.rule for v in lint_masked_mean(bad)] == ["masked-mean-pin"]
    good = _src("""
        import jax.numpy as jnp

        def mean(x, m):
            return jnp.sum(x * m) / jnp.maximum(jnp.sum(m), 1.0)
    """)
    assert lint_masked_mean(good) == []


# ---------------------------------------------------------------------- #
# self-test: registry completeness
# ---------------------------------------------------------------------- #
def test_registry_coverage_catches_dropped_entry():
    covered = ast.parse("def test_a():\n    run('alpha')\n"
                        "def test_b():\n    run('beta')\n")
    assert registry_coverage({"alpha", "beta"}, "REG",
                             covered, "tests/t.py") == []
    partial_ = ast.parse("def test_a():\n    run('alpha')\n")
    vs = registry_coverage({"alpha", "beta"}, "REG",
                           partial_, "tests/t.py")
    assert len(vs) == 1 and vs[0].rule == "registry-coverage"
    assert "`beta`" in vs[0].message


def test_registry_coverage_parametrize_over_symbol_cannot_lag():
    para = ast.parse(
        "import pytest\n"
        "@pytest.mark.parametrize('name', sorted(REG))\n"
        "def test_all(name):\n    pass\n")
    # the registry can grow arbitrarily: coverage holds by construction
    assert registry_coverage({"a", "b", "zzz-new"}, "REG",
                             para, "tests/t.py") == []


# ---------------------------------------------------------------------- #
# self-test: kernel _ref twins
# ---------------------------------------------------------------------- #
def test_kernel_twin_catches_missing_ref():
    ref_mod = types.SimpleNamespace(foo_ref=object())
    tested = ast.parse("from k import foo, foo_ref\n"
                       "def test_foo():\n    assert foo and foo_ref\n")
    assert kernel_ref_twins(["foo"], ref_mod, tested, "tests/t.py") == []
    vs = kernel_ref_twins(["foo", "bar"], ref_mod, tested, "tests/t.py")
    assert len(vs) == 1 and vs[0].rule == "kernel-ref-twin"
    assert "bar_ref" in vs[0].message


def test_kernel_twin_requires_parity_test():
    ref_mod = types.SimpleNamespace(foo_ref=object())
    vs = kernel_ref_twins(["foo"], ref_mod, ast.parse("x = 1"),
                          "tests/t.py")
    assert len(vs) == 1 and "never referenced" in vs[0].message


# ---------------------------------------------------------------------- #
# self-test: abstract-trace dtype checks
# ---------------------------------------------------------------------- #
def test_trace_checker_catches_f64_promotion():
    import jax
    import jax.numpy as jnp

    x32 = np.ones(3, np.float32)
    assert assert_no_f64(
        "good", lambda: jax.make_jaxpr(lambda x: x * 2.0)(x32)) == []
    vs = assert_no_f64(
        "bad", lambda: jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) + 1.0)(x32))
    assert vs and all(v.rule == "trace-f64" for v in vs)


def test_trace_checker_reports_trace_errors():
    def boom():
        raise ValueError("no inputs")
    vs = assert_no_f64("broken", boom)
    assert len(vs) == 1 and vs[0].rule == "trace-error"


def test_control_f64_pin():
    import jax

    x64 = np.zeros(3)                       # f64 under enable_x64
    assert assert_f64_outputs(
        "good", lambda: jax.make_jaxpr(lambda x: x + 1.0)(x64)) == []
    vs = assert_f64_outputs(
        "bad", lambda: jax.make_jaxpr(
            lambda x: (x + 1.0).astype(np.float32))(x64))
    assert len(vs) == 1 and vs[0].rule == "control-f64-pin"


def test_static_spec_literal():
    lit = ast.parse("partial(jax.jit, static_argnames=('k',))",
                    mode="eval").body
    assert _static_spec_literal(lit) == [("static_argnames", True)]
    computed = ast.parse("partial(jax.jit, static_argnames=NAMES)",
                         mode="eval").body
    assert _static_spec_literal(computed) == [("static_argnames", False)]


# ---------------------------------------------------------------------- #
# property tests (exercise the st.dictionaries/st.text fallback too)
# ---------------------------------------------------------------------- #
@given(st.dictionaries(st.text(alphabet="abcdefgh_", min_size=1,
                               max_size=8),
                       st.booleans(), min_size=1, max_size=6))
@settings(max_examples=10, deadline=None)
def test_registry_coverage_property(reg):
    """For any registry: full literal coverage is clean, and dropping
    the first entry is reported as exactly that entry."""
    names = sorted(reg)
    full = ast.parse("\n".join(
        f"def test_{i}():\n    use({n!r})" for i, n in enumerate(names)))
    assert registry_coverage(names, "REG", full, "tests/t.py") == []
    kept = names[1:]
    partial_ = ast.parse("\n".join(
        f"def test_{i}():\n    use({n!r})"
        for i, n in enumerate(kept)) or "x = 1")
    vs = registry_coverage(names, "REG", partial_, "tests/t.py")
    assert {v.message.split("`")[1] for v in vs} == {names[0]}


@given(st.text(alphabet="abcdefgh-", min_size=1, max_size=10))
@settings(max_examples=10, deadline=None)
def test_waiver_parse_property(rule):
    """A waiver comment covers its own line and the next one, nothing
    else, for any well-formed rule name."""
    text = f"x = 1\ny = 2  # repro: allow({rule})\nz = 3\nw = 4\n"
    w = parse_waivers(text)
    assert rule in w.get(2, set()) and rule in w.get(3, set())
    assert 1 not in w and 4 not in w
