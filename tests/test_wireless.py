"""Wireless model (paper Eq. 4-7, 9) properties, including the O(K log K)
monotone-bisection cost against the exhaustive (K, K) scan oracle."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from jax.experimental import enable_x64

from repro.configs.base import FeelConfig
from repro.core.wireless import WirelessModel, cost_bisect, dbm_to_watt


def _wm(seed=0, **kw):
    cfg = FeelConfig(**kw)
    return WirelessModel(cfg, np.random.default_rng(seed)), cfg


def test_dbm():
    assert dbm_to_watt(0) == pytest.approx(1e-3)
    assert dbm_to_watt(30) == pytest.approx(1.0)


def test_rate_monotone_in_bandwidth():
    """Eq. 4: r(alpha) is increasing in alpha (log concavity)."""
    wm, _ = _wm()
    g = np.array([1e-9])
    alphas = np.linspace(0.01, 1.0, 50)
    r = wm.rate(g, alphas[None, :] * np.ones((1, 50)))[0]
    r = wm.rate(np.full(50, 1e-9), alphas)
    assert np.all(np.diff(r) > 0)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_cost_is_minimal(seed):
    """Eq. 9: c_k is the MINIMUM feasible fraction count."""
    wm, cfg = _wm(seed)
    ch = wm.draw_channels()
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 31, cfg.n_ues) * 50.0
    cpu = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, cfg.n_ues)
    tt = wm.train_time(sizes, cpu)
    costs = wm.cost(ch.gains, tt)
    r_min = wm.min_rate(tt)
    K = cfg.n_ues
    for k in range(K):
        c = costs[k]
        if c <= K:
            assert wm.rate(ch.gains[k:k+1], np.array([c / K]))[0] >= r_min[k]
            if c > 1:
                assert wm.rate(ch.gains[k:k+1],
                               np.array([(c - 1) / K]))[0] < r_min[k]
        else:
            assert wm.rate(ch.gains[k:k+1], np.array([1.0]))[0] < r_min[k]


def test_train_time_scales_with_data_and_epochs():
    wm, cfg = _wm()
    t1 = wm.train_time(np.array([100.0]), np.array([1e8]))
    t2 = wm.train_time(np.array([200.0]), np.array([1e8]))
    assert t2 == pytest.approx(2 * t1)
    wm2, _ = _wm(local_epochs=cfg.local_epochs * 2)
    assert wm2.train_time(np.array([100.0]), np.array([1e8])) \
        == pytest.approx(2 * t1)


def test_deadline_violation_infeasible():
    """A UE whose training alone blows T can never upload (cost K+1)."""
    wm, cfg = _wm()
    tt = np.full(cfg.n_ues, cfg.deadline_s + 1.0)
    costs = wm.cost(wm.draw_channels().gains, tt)
    assert np.all(costs == cfg.n_ues + 1)


def _random_cost_instance(seed, k):
    """Random gains/deadlines with the Eq. 9 edges forced in: blown
    deadlines (t_train >= T -> r_min = inf), near-deadline stragglers, and
    a boosted-gain row that should resolve at c = 1."""
    cfg = FeelConfig(n_ues=k)
    rng = np.random.default_rng(seed)
    wm = WirelessModel(cfg, rng)
    gains = wm.draw_channels().gains
    sizes = rng.integers(1, 31, k) * 50.0
    cpu = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, k)
    tt = wm.train_time(sizes, cpu)
    tt[0] = cfg.deadline_s                 # exactly blown (slack == 0)
    tt[1] = cfg.deadline_s + 1.0           # blown
    tt[2] = cfg.deadline_s * (1 - 1e-6)    # near-blown straggler
    gains[3] = gains.max() * 1e3           # excellent channel
    return cfg, wm, gains, tt


@given(st.integers(0, 2**31 - 1), st.sampled_from([7, 23, 50, 211]))
@settings(max_examples=25, deadline=None)
def test_cost_bisection_equals_exhaustive_scan(seed, k):
    """Eq. 9 bisection == the dense (K, K) scan, EXACTLY, on random
    instances including the infeasible (c = K+1) and blown-deadline
    (t_train >= T) edges."""
    cfg, wm, gains, tt = _random_cost_instance(seed, k)
    bisected = wm.cost(gains, tt)
    scanned = wm.cost_scan(gains, tt)
    np.testing.assert_array_equal(bisected, scanned)
    assert bisected[0] == k + 1 and bisected[1] == k + 1
    assert np.all((bisected >= 1) & (bisected <= k + 1))


@given(st.integers(0, 2**31 - 1), st.sampled_from([7, 50, 211]))
@settings(max_examples=15, deadline=None)
def test_cost_bisect_jnp_matches_numpy(seed, k):
    """The jnp twin (batched control plane) reproduces the numpy bisection
    exactly in float64, same edges included."""
    cfg, wm, gains, tt = _random_cost_instance(seed, k)
    with enable_x64():
        jc = np.asarray(cost_bisect(
            gains, np.asarray(wm.min_rate(tt)), k, cfg.bandwidth_hz,
            cfg.p_watt, cfg.n0_watt_hz))
    np.testing.assert_array_equal(jc, wm.cost(gains, tt))


def test_cost_bisect_jnp_batched_axes():
    """cost_bisect accepts leading batch (run) axes — the (R, K) layout the
    control plane feeds it."""
    cfg, wm, gains, tt = _random_cost_instance(0, 23)
    r_min = np.asarray(wm.min_rate(tt))
    with enable_x64():
        single = np.asarray(cost_bisect(
            gains, r_min, 23, cfg.bandwidth_hz, cfg.p_watt,
            cfg.n0_watt_hz))
        stacked = np.asarray(cost_bisect(
            np.stack([gains, gains * 2.0]), np.stack([r_min, r_min]), 23,
            cfg.bandwidth_hz, cfg.p_watt, cfg.n0_watt_hz))
    np.testing.assert_array_equal(stacked[0], single)
    feas = single <= 23
    assert np.all(stacked[1][feas] <= single[feas])   # better channel


# ---------------------------------------------------------------------- #
# AR(1)/Gauss-Markov block fading (cfg.channel_corr, DESIGN.md §13)
# ---------------------------------------------------------------------- #
def test_channel_corr_zero_is_legacy_draw_bit_for_bit():
    """rho = 0 (the default) must consume the EXACT legacy RNG stream:
    uniform positions, then one exponential per draw_channels() call."""
    wm, cfg = _wm(seed=7)
    twin = np.random.default_rng(7)
    half = cfg.cell_side_m / 2.0
    xy = twin.uniform(-half, half, size=(cfg.n_population, 2))
    dist = np.maximum(np.linalg.norm(xy, axis=1), 1.0)
    for _ in range(3):
        ch = wm.draw_channels()
        h2 = twin.exponential(1.0, size=dist.shape)
        np.testing.assert_array_equal(
            ch.gains, dist ** (-cfg.pathloss_exp) * h2)
        np.testing.assert_array_equal(wm.last_gains, ch.gains)
    assert wm._h is None                     # no fading state materialised


def test_channel_corr_state_persists_and_positive():
    wm, _ = _wm(seed=3, channel_corr=0.8)
    g1 = wm.draw_channels().gains
    h_after_first = wm._h.copy()
    g2 = wm.draw_channels().gains
    assert wm._h is not None and not np.array_equal(wm._h, h_after_first)
    assert np.all(g1 > 0) and np.all(g2 > 0)
    assert not np.array_equal(g1, g2)        # fading evolves, not frozen


def test_channel_corr_stationary_stats():
    """|h|^2 stays Exp(1) (mean 1) and its lag-1 correlation is ~rho^2."""
    rho = 0.8
    wm, cfg = _wm(seed=11, n_ues=200, channel_corr=rho)
    d_alpha = wm.distances ** cfg.pathloss_exp
    # divide out the pathloss to recover the (T, N) small-scale power
    h2 = np.stack([wm.draw_channels().gains * d_alpha
                   for _ in range(400)])
    assert abs(h2.mean() - 1.0) < 0.05
    x, y = h2[:-1].ravel(), h2[1:].ravel()
    corr = np.corrcoef(x, y)[0, 1]
    assert abs(corr - rho ** 2) < 0.05
