"""Federated client: local training on the UE's (possibly poisoned) dataset
and the self-reported local accuracy of Alg. 1 line 11.

A malicious UE is not assumed to lie about the *number* it reports — it
truthfully evaluates on its own poisoned data, which is exactly why the
paper's Eq. 1 uses the server-side test-set gap to catch it. Update- and
report-level attacks (model poisoning, lie boosting) are NOT applied
here: the server applies them through the threat-model plane
(``core/attacks.py`` — ``FeelServer._apply_attacks`` / the loop engine's
per-client oracle), which is what keeps their activity schedules and
stale reference params consistent across engines."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax

from repro.data.partition import ClientData
from repro.models.mlp import mlp_accuracy, mlp_sgd_epoch


@dataclasses.dataclass
class ClientReport:
    ue_id: int
    params: dict
    acc_local: float
    n_samples: int


def local_train(client: ClientData, global_params, epochs: int,
                lr: float = 0.1, batch_size: int = 50) -> ClientReport:
    x = jax.numpy.asarray(client.data.x)
    y = jax.numpy.asarray(client.data.y)
    params = global_params
    for _ in range(epochs):
        params = mlp_sgd_epoch(params, x, y, lr, batch_size)
    acc = float(mlp_accuracy(params, x, y))
    return ClientReport(ue_id=client.ue_id, params=params,
                        acc_local=acc, n_samples=client.size)
