"""Beyond-paper robustness extensions (the paper's §VI future-work items):

  1. MODEL poisoning (sign-flip / boosted updates) instead of data poisoning —
     does Eq. 1's test-set evaluation still catch the attacker?
  2. Dishonest accuracy reporting (lie_boost) — the beta1 term's target.
  3. Adaptive omega schedule (core.quality.adaptive_weights) vs fixed
     omega1=omega2 — implements the paper's own §V-B.2 suggestion.
  4. Scale: K=100 UEs (paper §VI: "larger number of UEs").

    PYTHONPATH=src python examples/robustness_extensions.py [--fast]

Writes results/robustness.json.
"""
import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import FeelConfig
from repro.federated.simulation import run_experiment


def curve(tag, seeds, **kw):
    runs = [run_experiment(seed=s, **kw) for s in seeds]
    out = {
        "acc": [round(float(a), 4) for a in np.mean([r["acc"] for r in runs], 0)],
        "rep_gap": round(float(np.mean(
            [r["final_reputation_honest"] - r["final_reputation_malicious"]
             for r in runs])), 4),
        "malicious_selected_mean": [round(float(m), 2) for m in np.mean(
            [r["malicious_selected"] for r in runs], 0)],
    }
    print(f"{tag:40s} acc={out['acc'][-1]:.3f} repgap={out['rep_gap']:+.3f} "
          f"malsel_last={out['malicious_selected_mean'][-1]}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    kw = (dict(n_train=10_000, n_test=2_000, rounds=6) if args.fast
          else dict(n_train=20_000, n_test=4_000, rounds=10))
    seeds = (0, 1)
    cfg5 = FeelConfig(model_size_bits=5e6 * 8)
    results = {}
    t0 = time.time()

    # 1) model poisoning: sign-flip and boosted
    for scale, tag in [(-1.0, "signflip"), (4.0, "boost4")]:
        results[f"model_poison_{tag}_dqs"] = curve(
            f"model_poison_{tag}_dqs", seeds, policy="dqs",
            attack_pair=(8, 4), cfg=cfg5, model_poison_scale=scale, **kw)
        results[f"model_poison_{tag}_random"] = curve(
            f"model_poison_{tag}_random", seeds, policy="random",
            attack_pair=(8, 4), cfg=cfg5, model_poison_scale=scale, **kw)
    results["model_poison_control"] = curve(
        "model_poison_control", seeds, policy="dqs", attack_pair=(8, 4),
        cfg=cfg5, no_attack=True, **kw)

    # 2) dishonest reporting: label flip + inflated self-reported accuracy
    for boost in (0.0, 0.3):
        results[f"lie_{boost}"] = curve(
            f"lie_boost_{boost}", seeds, policy="dqs", attack_pair=(8, 4),
            cfg=cfg5, lie_boost=boost, **kw)

    # 3) adaptive omega vs fixed
    results["fixed_omega"] = curve(
        "fixed_omega", seeds, policy="dqs", attack_pair=(8, 4), cfg=cfg5, **kw)
    results["adaptive_omega"] = curve(
        "adaptive_omega", seeds, policy="dqs", attack_pair=(8, 4), cfg=cfg5,
        adaptive_omega=True, **kw)

    # 4) scale: K=100 UEs, 10 malicious
    cfg100 = dataclasses.replace(cfg5, n_ues=100, n_malicious=10)
    results["k100_dqs"] = curve(
        "k100_dqs", seeds, policy="dqs", attack_pair=(8, 4), cfg=cfg100, **kw)
    results["k100_random"] = curve(
        "k100_random", seeds, policy="random", attack_pair=(8, 4),
        cfg=cfg100, **kw)

    os.makedirs("results", exist_ok=True)
    with open("results/robustness.json", "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote results/robustness.json ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
