"""End-to-end FEEL system behaviour (paper Alg. 1 + Alg. 2 + Eq. 1-3):
reduced-scale runs of the full federated pipeline."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import FeelConfig
from repro.core.poisoning import EASY_PAIR
from repro.federated.simulation import run_experiment

# reduced-scale protocol: fewer samples/rounds so the suite stays fast
KW = dict(n_train=4000, n_test=800, rounds=4)


@pytest.fixture(scope="module")
def dqs_run():
    return run_experiment("dqs", EASY_PAIR, seed=0, **KW)


def test_training_improves_accuracy(dqs_run):
    acc = dqs_run["acc"]
    assert acc[-1] > acc[0]
    assert acc[-1] > 0.25          # far above the 0.1 random baseline


def test_curves_complete(dqs_run):
    assert len(dqs_run["acc"]) == KW["rounds"]
    assert len(dqs_run["malicious_selected"]) == KW["rounds"]


def test_reputation_tracks_malice(dqs_run):
    """Across the run, honest UEs end with reputation >= malicious UEs."""
    assert dqs_run["final_reputation_honest"] >= \
        dqs_run["final_reputation_malicious"] - 0.05


def test_policies_run_and_return_curves():
    for policy in ["random", "best_channel", "max_count", "top_value"]:
        r = run_experiment(policy, EASY_PAIR, seed=1, **KW)
        assert len(r["acc"]) == KW["rounds"]
        assert all(0.0 <= a <= 1.0 for a in r["acc"])


def test_constrained_bandwidth_limits_participation():
    """With a 5 MB update the knapsack binds: the scheduled value per round
    cannot exceed the paper's 100 KB regime."""
    small = FeelConfig(model_size_bits=100e3 * 8)
    big = FeelConfig(model_size_bits=5e6 * 8)
    r_small = run_experiment("dqs", EASY_PAIR, cfg=small, seed=2, **KW)
    r_big = run_experiment("dqs", EASY_PAIR, cfg=big, seed=2, **KW)
    assert np.mean(r_big["objective"]) <= np.mean(r_small["objective"]) + 1e-9


def test_adaptive_omega_runs():
    r = run_experiment("dqs", EASY_PAIR, seed=3, adaptive_omega=True, **KW)
    assert len(r["acc"]) == KW["rounds"]
