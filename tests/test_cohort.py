"""Vectorized cohort engine vs the sequential loop oracle, the
padding/masking contract, the degenerate-schedule fallback, and the Eq. 1
reputation ordering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FeelConfig
from repro.core.poisoning import EASY_PAIR, LabelFlipAttack, pick_malicious
from repro.core.reputation import ReputationTracker
from repro.data.partition import pad_clients, partition
from repro.data.synthetic_mnist import generate
from repro.federated import cohort
from repro.federated.server import FeelServer
from repro.federated.simulation import run_experiment
from repro.federated.task import MnistTask
from repro.models.mlp import (mlp_accuracy, mlp_init, mlp_sgd_epoch,
                              mlp_sgd_epoch_masked)

KW = dict(n_train=3000, n_test=400, rounds=5)


def _k10_cfg():
    return FeelConfig(n_ues=10, n_malicious=2)


# ---------------------------------------------------------------------- #
# Tentpole acceptance: the engines produce the same experiment.
# ---------------------------------------------------------------------- #
def test_vectorized_matches_loop_fixed_seed_k10():
    """Identical accuracy curve (within 1e-5 per round) on a fixed-seed
    K=10 experiment — the loop engine is the correctness oracle."""
    a = run_experiment("dqs", EASY_PAIR, cfg=_k10_cfg(), seed=0,
                       engine="loop", **KW)
    b = run_experiment("dqs", EASY_PAIR, cfg=_k10_cfg(), seed=0,
                       engine="vectorized", **KW)
    np.testing.assert_allclose(b["acc"], a["acc"], atol=1e-5)
    np.testing.assert_allclose(b["source_acc"], a["source_acc"], atol=1e-5)
    # same schedules round for round -> same malicious-selection counts
    assert b["malicious_selected"] == a["malicious_selected"]
    assert b["final_reputation_malicious"] == pytest.approx(
        a["final_reputation_malicious"], abs=1e-5)


# ---------------------------------------------------------------------- #
# Padding / masking contract
# ---------------------------------------------------------------------- #
def test_masked_epoch_padding_is_a_no_op():
    """Training on a zero-padded, masked dataset reproduces the unpadded
    epoch: padding batches contribute exactly zero gradient."""
    rng = np.random.default_rng(0)
    n, d, pad_to = 100, 784, 250
    x = rng.random((n, d)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    params = mlp_init(jax.random.PRNGKey(0))

    plain = mlp_sgd_epoch(params, jnp.asarray(x), jnp.asarray(y), 0.1, 50)

    xp = np.zeros((pad_to, d), np.float32)
    yp = np.zeros(pad_to, np.int32)
    m = np.zeros(pad_to, np.float32)
    xp[:n], yp[:n], m[:n] = x, y, 1.0
    masked = mlp_sgd_epoch_masked(params, jnp.asarray(xp), jnp.asarray(yp),
                                  jnp.asarray(m), 0.1, 50)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(masked)):
        np.testing.assert_allclose(a, b, atol=1e-7)


def test_pad_clients_layout():
    train, _ = generate(1500, 100, seed=0)
    rng = np.random.default_rng(0)
    clients = partition(train, 6, rng)
    padded = pad_clients(clients, multiple_of=50)
    assert padded.x.shape[0] == 6
    assert padded.max_samples % 50 == 0
    assert padded.max_samples >= max(c.size for c in clients)
    for k, c in enumerate(clients):
        n = c.size
        assert padded.sizes[k] == n
        np.testing.assert_array_equal(padded.x[k, :n], c.data.x)
        np.testing.assert_array_equal(padded.y[k, :n], c.data.y)
        assert padded.mask[k, :n].all()
        assert not padded.mask[k, n:].any()
        assert not padded.x[k, n:].any()


def test_cohort_eval_matches_subset_eval():
    """The vmapped masked test evaluation equals per-model subset scoring."""
    _, test = generate(200, 300, seed=1)
    task = MnistTask()
    params = [mlp_init(jax.random.PRNGKey(i)) for i in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params)
    masks = np.stack([np.isin(test.y, [0, 1, 2]),
                      np.isin(test.y, [5]),
                      np.ones_like(test.y, bool)]).astype(np.float32)
    got = np.asarray(cohort.cohort_eval(
        task, stacked, task.eval_inputs(test), jnp.asarray(test.y),
        jnp.asarray(masks)))
    for i, p in enumerate(params):
        m = masks[i].astype(bool)
        want = float(mlp_accuracy(p, jnp.asarray(test.x[m]),
                                  jnp.asarray(test.y[m])))
        assert got[i] == pytest.approx(want, abs=1e-6)


# ---------------------------------------------------------------------- #
# Size-bucketed sub-cohorts: parity with the single-bucket path and the
# padding-waste reclaim the ROADMAP item targets.
# ---------------------------------------------------------------------- #
def test_bucket_levels_quantized():
    from repro.data.partition import assign_buckets, bucket_levels
    levels = bucket_levels(1500, 3, multiple_of=50)
    np.testing.assert_array_equal(levels, [500, 1000, 1500])
    # quantized step: nearby maxima share the same level grid (compile
    # cache stays warm across seeds)
    np.testing.assert_array_equal(bucket_levels(1451, 3, 50), levels)
    np.testing.assert_array_equal(
        assign_buckets(np.array([50, 500, 501, 1000, 1500]), levels),
        [0, 0, 1, 1, 2])


def test_pad_clients_bucketed_layout():
    from repro.data.partition import pad_clients_bucketed
    train, _ = generate(4000, 100, seed=0)
    rng = np.random.default_rng(0)
    clients = partition(train, 8, rng)
    buckets = pad_clients_bucketed(clients, n_buckets=3, multiple_of=50)
    seen = np.concatenate([ids for ids, _ in buckets])
    assert sorted(seen) == list(range(8))        # every client, exactly once
    sizes = np.array([c.size for c in clients])
    for ids, pd in buckets:
        assert (pd.sizes == sizes[ids]).all()
        assert pd.max_samples >= sizes[ids].max()
        for j, k in enumerate(ids):
            n = clients[k].size
            np.testing.assert_array_equal(pd.x[j, :n], clients[k].data.x)
            assert pd.mask[j, :n].all() and not pd.mask[j, n:].any()
    # bucketed padding is never worse than the single global pad
    total_bucketed = sum(len(ids) * pd.max_samples for ids, pd in buckets)
    global_pad = pad_clients(clients, multiple_of=50)
    assert total_bucketed <= 8 * global_pad.max_samples


def test_bucketed_k500_parity_and_padding_waste():
    """K=500 regression for the ROADMAP item: the bucketed engine must
    reproduce the single-bucket vectorized accuracy curve while cutting
    per-round padded-sample waste below 1.25x (single global pad wastes
    ~1.5-2x after the partition pool truncates)."""
    from repro.core.poisoning import pick_malicious
    cfg = FeelConfig(n_ues=500, n_malicious=50, rounds=2)
    train, test = generate(50_000, 400, seed=0)
    rng = np.random.default_rng(0)
    mal = pick_malicious(cfg.n_ues, cfg.n_malicious, rng)
    clients = partition(train, cfg.n_ues, rng, mal,
                        LabelFlipAttack(*EASY_PAIR))
    curves, wastes = {}, {}
    for nb in (1, 3):
        server = FeelServer(cfg, clients, test, np.random.default_rng(0),
                            policy="dqs", n_buckets=nb)
        server.run(2)
        curves[nb] = [l.global_acc for l in server.logs]
        wastes[nb] = np.mean(server.pad_waste)
    np.testing.assert_allclose(curves[3], curves[1], atol=1e-5)
    assert wastes[3] < 1.25, wastes
    assert wastes[3] < wastes[1], wastes


# ---------------------------------------------------------------------- #
# Degenerate-schedule fallback (satellite): the log must describe the
# forced participant set, not the empty schedule.
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("engine", ["loop", "vectorized"])
def test_degenerate_schedule_log_reflects_forced_participant(engine):
    train, test = generate(800, 150, seed=2)
    rng = np.random.default_rng(2)
    cfg = FeelConfig(n_ues=4, n_malicious=0, rounds=1)
    clients = partition(train, cfg.n_ues, rng)
    # control="host": this test stubs wireless.cost, which only the host
    # oracle calls (the batched plane bisects from precomputed min rates —
    # its forced-round behaviour is pinned by test_control.py and
    # test_impossible_deadline_forces_round_with_zero_objective below)
    server = FeelServer(cfg, clients, test, rng, engine=engine,
                        control="host")
    # all-infeasible channel draw: every UE costs more than the K-fraction
    # budget, so the scheduler returns the empty schedule
    server.wireless.cost = lambda gains, t_train: np.full(
        cfg.n_ues, cfg.n_ues + 1, float)

    before = server.reputation.values.copy()
    params_before = jax.tree.map(np.asarray, server.params)
    log = server.run_round(0)

    assert log.selected.size == 1
    k = int(log.selected[0])
    assert k == int(np.argmax(log.values))
    # problem (8) had no feasible point: the round is marked forced and its
    # objective is 0.0 — the forced UE's V_k is not credited (accounting
    # regression: the seed reported objective = V_k for infeasible rounds)
    assert log.forced
    assert log.objective == 0.0
    # the forced UE really trained: the global model moved
    moved = any(np.abs(np.asarray(a) - b).max() > 0
                for a, b in zip(jax.tree.leaves(server.params),
                                jax.tree.leaves(params_before)))
    assert moved
    # only the forced participant's reputation was touched
    np.testing.assert_array_equal(np.delete(log.reputations, k),
                                  np.delete(before, k))


def test_impossible_deadline_forces_round_with_zero_objective():
    """A deadline no UE can meet (Eq. 8b infeasible for every UE) makes the
    wireless costs K+1 across the board; every round must come back forced
    with objective 0.0, and a normal deadline must not set the flag."""
    train, test = generate(800, 150, seed=3)
    rng = np.random.default_rng(3)
    cfg = FeelConfig(n_ues=4, n_malicious=0, rounds=2, deadline_s=1e-9)
    clients = partition(train, cfg.n_ues, rng)
    server = FeelServer(cfg, clients, test, rng)
    logs = server.run()
    assert all(l.forced for l in logs)
    assert all(l.objective == 0.0 for l in logs)
    assert all(l.selected.size == 1 for l in logs)

    ok = FeelServer(dataclasses.replace(cfg, deadline_s=300.0), clients,
                    test, np.random.default_rng(3))
    log = ok.run_round(0)
    assert not log.forced
    assert log.objective > 0.0


# ---------------------------------------------------------------------- #
# Eq. 1 reputation ordering (satellite audit): honest UEs must end above
# a poisoner even though the beta1 term penalises above-average reports.
# ---------------------------------------------------------------------- #
def test_reputation_orders_honest_above_poisoner():
    cfg = FeelConfig(n_ues=4)
    tracker = ReputationTracker(cfg)
    everyone = np.arange(4)
    # honest UEs report what the server then measures (acc_local==acc_test);
    # UE 3 is a label-flip poisoner: high self-report, poor test accuracy
    acc_local = np.array([0.85, 0.70, 0.75, 0.90])
    acc_test = np.array([0.85, 0.70, 0.75, 0.30])
    for _ in range(5):
        tracker.update(everyone, acc_local, acc_test)
    assert tracker.values[3] < tracker.values[:3].min()
    # the best honest UE (above-average report, beta1 penalty applies)
    # still outranks the poisoner by a wide margin
    assert tracker.values[0] - tracker.values[3] > 0.5


def test_reputation_beta1_penalises_above_average_reports():
    """Documented Eq. 1 behaviour (see core/reputation.py): with beta2
    silent (report == test), the relative beta1 term alone moves
    above-average reporters down and below-average reporters up."""
    cfg = FeelConfig(n_ues=2, eta=1.0)
    tracker = ReputationTracker(cfg)
    tracker.values[:] = 0.5
    acc = np.array([0.9, 0.5])           # both honest: report == test
    tracker.update(np.arange(2), acc, acc)
    assert tracker.values[0] < 0.5 < tracker.values[1]
