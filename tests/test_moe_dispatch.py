"""MoE dispatch paths: the group-local (§Perf iteration 3) path must be
exactly equivalent to the global path whenever capacity admits every token,
for any grouping that divides the tokens."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get, reduced
from repro.models import moe as moe_mod


def _cfg(cf=8.0, groups=0, **kw):
    cfg = reduced(get("qwen2-moe-a2.7b"))
    return dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=cf,
                                dispatch_groups=groups, **kw))


@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_grouped_equals_global_no_drops(g, seed):
    cfg0 = _cfg(groups=0)
    cfgg = _cfg(groups=g)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 16, cfg0.d_model))
    y0, a0 = moe_mod.moe_apply(cfg0, p, x)
    yg, ag = moe_mod.moe_apply(cfgg, p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yg),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(a0), float(ag), rtol=1e-5)


def test_grouped_finite_under_drops():
    cfg = _cfg(cf=0.5, groups=4)      # force capacity overflow
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
    y, aux = moe_mod.moe_apply(cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.isfinite(aux))


def test_grouped_gradients_flow():
    cfg = _cfg(groups=2)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_mod.moe_apply(cfg, p, x)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(p)
    norms = [float(jnp.linalg.norm(v.astype(jnp.float32)))
             for v in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


def test_model_poison_signflip():
    from repro.core.poisoning import ModelPoisonAttack
    g = {"w": jnp.ones((3,))}
    l = {"w": jnp.asarray([2.0, 0.0, 1.0])}
    out = ModelPoisonAttack(scale=-1.0).apply(g, l)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.0, 2.0, 1.0])


def test_capacity_rounding():
    cfg = _cfg()
    assert moe_mod.capacity(64, cfg) % 8 == 0
    assert moe_mod.capacity(1, cfg) >= 8
