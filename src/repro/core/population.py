"""Population plane: million-UE candidate state + schedule-preserving
top-M prefilter (DESIGN.md §12).

Production FEEL schedules each round's cohort from a persistent
*population* of N candidate devices (10^6+), not from the K-sized
scheduling plane the paper's §V protocol materializes. This module keeps
that population as a struct-of-arrays ``PopulationState`` — O(N) memory,
one row per run — and feeds the existing batched control plane
(``core.control.schedule_runs`` / ``finalize_runs``) two ways:

  exact      — the (R, N) state is materialized as a ``ControlState``
      view and scheduled by the unchanged kernels: O(N log N) stable
      sort + an O(N)-sequential-step budget scan per round. The oracle.
  prefilter  — ``prefilter_schedule_runs``: the per-policy priority key
      (scheduler.priority_key — monotone in the per-UE value for every
      packing policy) is computed over all N candidates, but only the
      first M positions of the *visit order* (``lax.top_k`` of the
      negated key; ties resolve to the lower index, exactly the stable
      argsort prefix) enter the sort + budget walk. Alg. 2's greedy walk
      only ever admits K fractions' worth of UEs, so M ≳ K·headroom
      almost always contains the whole exact selection — and instead of
      trusting "almost always", every round carries a per-instance
      **preservation certificate**:

          B_rem < min{ c_u : u not kept }

      where B_rem is the budget remaining after packing the kept
      prefix. Dropped candidates all follow the kept prefix in visit
      order, the walk's remaining budget is non-increasing, and a UE is
      admitted iff its cost fits the remaining budget — so the
      certificate implies the exact N-wide walk admits no dropped
      candidate and the two selections are *identical* (infeasible
      dropped UEs cost K+1 > K >= B_rem, so the plain min works; an
      empty dropped set passes vacuously). Rows whose certificate fails
      are escalated to the exact path — the prefilter is exact by
      construction, the certificate only decides who pays the O(N log N)
      toll. The dqs modified-greedy fallback and the forced-round
      rewrite compare against *global* O(N) reductions (masked argmax /
      masked sum over all N), so they need no kept-set argument;
      ``top_value`` rows take ``lax.top_k(values, n_sel)`` directly
      (preserved whenever M >= n_sel).

``scatter_finalize`` closes the loop: each round's K-sized results
update the N-wide state sparsely (``reputations[i, sel]`` and a
``last_sel`` round stamp whose difference to t reproduces the dense
ages in exact integers) — bit-for-bit against the dense
``finalize_runs`` (tests/test_population.py).

The population axis shards over a device mesh (``population_mesh`` /
``shard_population``): the previously-dead ``launch.mesh`` +
``sharding.specs`` provide the mesh and the NamedSharding placement, and
the jitted prefilter kernel runs GSPMD-sharded over the ``data`` axes —
elementwise Eq. 2/3/9 math and the top-M cut are population-parallel,
only the M-sized tail is sequential. ``bench_round --population``
measures both paths at N ∈ {10^4, 10^5, 10^6}
(results/BENCH_population.json), asserting prefilter == exact per cell.

Channel state is per-UE and N-wide but lives in ``core.wireless``
(``WirelessModel`` already spans the full candidate population): with
``cfg.channel_corr`` > 0 each candidate carries a persistent AR(1)
block-fading state across rounds (DESIGN.md §13) instead of the legacy
memoryless per-round redraw — closing the PR 8 follow-up that channel
statistics had no temporal state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec

from repro.configs.base import FeelConfig
from repro.core import control as ctl
from repro.core.diversity import diversity_index_eq2, diversity_index_rows
from repro.core.quality import data_quality_value
from repro.core.scheduler import pack_scan, priority_key
from repro.core.wireless import cost_bisect
from repro.launch.mesh import make_host_mesh
from repro.obs import trace
from repro.sharding.specs import data_axes, named

# Default M = PREFILTER_HEADROOM * K candidates survive the top-M cut.
# The greedy walk admits at most K UEs (every cost >= 1 fraction), so K
# of headroom covers the selection itself and the rest buys certificate
# slack: the walk usually drives B_rem to 0 (plenty of cost-1 feasible
# candidates near the top of the key order), making the certificate
# pass outright. Escalation keeps any choice of M exact.
PREFILTER_HEADROOM = 8


def default_m(cfg: FeelConfig) -> int:
    return min(cfg.n_population, PREFILTER_HEADROOM * cfg.n_ues)


@dataclasses.dataclass
class PopulationState:
    """Struct-of-arrays population-plane state: R runs x N candidates.

    The mutable per-candidate fields are ``reputations`` and
    ``last_sel`` (round of last selection, -1 = never): the dense ages
    the control kernels consume are the exact integer difference
    ``t - last_sel`` (init 1.0, +1 per round, reset to 1 on selection —
    same trajectory, no O(N) per-round age sweep). Everything else is
    round-invariant and shared with the ControlState view.
    """
    policy_id: np.ndarray     # (R,)  int32, scheduler.POLICY_IDS
    sizes: np.ndarray         # (R, N) float64 true dataset sizes
    divs: np.ndarray          # (R, N) element (Gini-Simpson) diversities
    r_min: np.ndarray         # (R, N) Eq. 9 min rates (round-invariant)
    reputations: np.ndarray   # (R, N) Eq. 1 state
    last_sel: np.ndarray      # (R, N) int64 round of last selection, -1
    cfg: FeelConfig

    @property
    def n_runs(self) -> int:
        return self.policy_id.shape[0]

    @property
    def n_population(self) -> int:
        return self.reputations.shape[1]

    def ages(self, t: int) -> np.ndarray:
        """Dense staleness ages at schedule time of round ``t``."""
        return (t - self.last_sel).astype(float)

    def nbytes(self) -> int:
        return (self.sizes.nbytes + self.divs.nbytes + self.r_min.nbytes
                + self.reputations.nbytes + self.last_sel.nbytes)

    @classmethod
    def from_control(cls, state: ctl.ControlState,
                     t: int = 0) -> "PopulationState":
        """Adopt a dense control state at round ``t`` (ages -> last_sel)."""
        last_sel = (t - np.asarray(state.ages)).astype(np.int64)
        return cls(policy_id=np.asarray(state.policy_id),
                   sizes=np.asarray(state.sizes, float),
                   divs=np.asarray(state.divs, float),
                   r_min=np.asarray(state.r_min, float),
                   reputations=np.array(state.reputations, float),
                   last_sel=last_sel, cfg=state.cfg)

    def control_view(self, t: int) -> ctl.ControlState:
        """ControlState over the SAME buffers (ages materialized for
        round ``t``) — feed it to ``schedule_runs`` / the exact path;
        finalize through ``scatter_finalize``, not ``finalize_runs``."""
        return ctl.ControlState(
            policy_id=self.policy_id, sizes=self.sizes, divs=self.divs,
            r_min=self.r_min, reputations=self.reputations,
            ages=self.ages(t), cfg=self.cfg)


def scatter_finalize(pop: PopulationState, t: int,
                     sels: List[np.ndarray],
                     acc_locals: List[np.ndarray],
                     acc_tests: List[np.ndarray],
                     penalties: Optional[List] = None) -> None:
    """Eq. 1 + staleness from K-sized round results, scattered into the
    N-wide state — O(R*K) writes, no O(N) sweep.

    Bit-for-bit against the dense ``finalize_runs`` hybrid path: the
    cohort average is ``np.mean`` over the compressed cohort and the
    delta/clip expressions are the same float64 ops in the same order;
    ages agree exactly because ``t - last_sel`` is integer arithmetic.
    """
    cfg = pop.cfg
    for i, (sel, a, te) in enumerate(zip(sels, acc_locals, acc_tests)):
        sel = np.asarray(sel, int)
        if sel.size == 0:
            continue
        a = np.asarray(a, float)
        te = np.asarray(te, float)
        delta = cfg.eta * (cfg.beta1 * (a - np.mean(a))
                           + cfg.beta2 * (a - te))
        if penalties is not None and penalties[i] is not None:
            delta = delta + penalties[i]
        pop.reputations[i, sel] = np.clip(
            pop.reputations[i, sel] - delta, 0.0, 1.0)
        pop.last_sel[i, sel] = t


# ---------------------------------------------------------------------- #
# Top-M visit-order prefix (host side)
# ---------------------------------------------------------------------- #
def _topm_prefix(keys: np.ndarray, m: int) -> np.ndarray:
    """First ``m`` positions of each row's visit order — the stable
    ascending argsort prefix (ties to the lower index) — in O(N + m log m)
    per row via argpartition + a pivot/tie fixup instead of a full
    O(N log N) sort."""
    R, _ = keys.shape
    out = np.empty((R, m), np.int64)
    for i in range(R):
        k = keys[i]
        part = np.argpartition(k, m - 1)[:m]
        pivot = k[part].max()
        strict = np.flatnonzero(k < pivot)
        ties = np.flatnonzero(k == pivot)[:m - strict.size]
        idx = np.concatenate([strict, ties])
        # stable argsort of the kept keys: equal keys keep their
        # ascending-index layout, reproducing the global visit order
        out[i] = idx[np.argsort(k[idx], kind="stable")]
    return out


# ---------------------------------------------------------------------- #
# "jax" layout: prefilter as ONE jitted vmapped (shardable) kernel
# ---------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("k", "n_sel", "m"))
def _prefilter_kernel(policy_id, rep, ages, divs, sizes, r_min, gains,
                      rand_rank, w_rep, w_div, gamma, bandwidth_hz,
                      p_watt, n0, *, k: int, n_sel: int, m: int):
    """One prefiltered round of every run: (R, N) in, (x, alpha, costs,
    values, forced, cert) out. The O(N) work (Eq. 2/3/9, top_k, the
    global fallback reductions) is population-parallel and shards over
    the mesh data axes; only the (R, M) sort + budget scan is serial."""

    def one(pid, rep, ages, divs, sizes, r_min, gains, rand_rank,
            w_rep, w_div):
        I = diversity_index_eq2(divs, sizes, ages, gamma)
        values = data_quality_value(rep, I, None, omega=(w_rep, w_div))
        costs = cost_bisect(gains, r_min, k, bandwidth_hz, p_watt, n0)
        costs_f = costs.astype(values.dtype)
        key = jnp.where(
            pid == 0, priority_key("dqs", values, costs_f, k),
            jnp.where(pid == 1, rand_rank.astype(values.dtype),
                      jnp.where(pid == 2,
                                priority_key("best_channel", values,
                                             costs_f, k, gains=gains),
                                costs_f)))
        # top_value rows pre-filter by value so the kept prefix contains
        # the exact top-n_sel selection
        key = jnp.where(pid == 4, -values, key)

        # visit-order prefix: top_k of the negated key returns the m
        # smallest keys ascending, ties to the lower index — exactly the
        # stable argsort prefix the exact path walks first
        _, kept = jax.lax.top_k(-key, m)
        c_kept = jnp.take(costs, kept)
        take = pack_scan(c_kept, k)
        x = jnp.zeros(costs.shape, bool).at[kept].set(take)
        alpha = jnp.where(x, costs_f / k, 0.0)

        # preservation certificate: remaining budget cannot admit any
        # dropped candidate (see module docstring)
        b_rem = k - jnp.where(take, c_kept, 0).sum()
        dmin = jnp.min(costs.at[kept].set(k + 2))
        cert = (b_rem < dmin) | (pid == 4)

        # dqs modified-greedy fallback — global O(N) reductions
        feas = costs <= k
        masked = jnp.where(feas, values, -jnp.inf)
        k_best = jnp.argmax(masked)
        use_fb = ((pid == 0) & feas.any()
                  & (masked[k_best] > (values * x).sum()))
        onehot_best = jnp.zeros_like(x).at[k_best].set(True)
        x = jnp.where(use_fb, onehot_best, x)
        alpha = jnp.where(use_fb,
                          jnp.where(onehot_best, costs_f / k, 0.0), alpha)

        # top_value override: top-n_sel by value (ties to lower index ==
        # the exact path's stable rank)
        _, topn = jax.lax.top_k(values, n_sel)
        x4 = jnp.zeros_like(x).at[topn].set(True)
        x = jnp.where(pid == 4, x4, x)
        alpha = jnp.where(pid == 4,
                          jnp.where(x4, 1.0 / max(n_sel, 1), 0.0), alpha)

        # degenerate round: force the single highest-value UE
        forced = ~x.any()
        onehot_f = jnp.zeros_like(x).at[jnp.argmax(values)].set(True)
        x = jnp.where(forced, onehot_f, x)
        alpha = jnp.where(forced, jnp.where(onehot_f, 1.0, 0.0), alpha)
        return x, alpha, costs, values, forced, cert

    return jax.vmap(one)(policy_id, rep, ages, divs, sizes, r_min, gains,
                         rand_rank, w_rep, w_div)


# ---------------------------------------------------------------------- #
# Entry point
# ---------------------------------------------------------------------- #
def _state_nbytes(state: ctl.ControlState) -> int:
    """Resident bytes of the (R, N) control-plane state — the same
    accounting as ``PopulationState.nbytes`` (telemetry gauge only)."""
    return sum(np.asarray(a).nbytes
               for a in (state.sizes, state.divs, state.r_min,
                         state.reputations, state.ages))


def prefilter_schedule_runs(state: ctl.ControlState, gains, rand_rank,
                            w_rep, w_div, m: Optional[int] = None,
                            kernel: Optional[str] = None, mesh=None):
    """Schedule round t of all R runs through the top-M prefilter.

    Same inputs/outputs as ``control.schedule_runs`` plus an ``info``
    dict: ``(x, alpha, costs, values, forced, info)`` with
    ``info = {"m", "n_escalated"}``. The schedule is IDENTICAL to the
    exact path for every run — certificate-passing rows by the
    preservation argument (module docstring), failing rows by
    escalation to ``schedule_runs`` itself.

    ``mesh`` (jax layout only) places the (R, N) operands with the
    population axis sharded over the mesh's data axes before the kernel
    runs, so XLA partitions the O(N) stages across devices.
    """
    cfg = state.cfg
    K = cfg.n_ues
    gains = np.asarray(gains, float)
    rand_rank = np.asarray(rand_rank)
    w_rep = np.asarray(w_rep, float)
    w_div = np.asarray(w_div, float)
    R = state.n_runs
    N = state.reputations.shape[1]
    m_eff = int(min(m if m is not None else default_m(cfg), N))
    assert m_eff >= cfg.min_selected, (m_eff, cfg.min_selected)
    with trace.span("schedule.prefilter") as sp:
        if m_eff >= N:      # no cut: the exact path IS the prefilter path
            out = ctl.schedule_runs(state, gains, rand_rank, w_rep, w_div,
                                    kernel=kernel)
            if trace.enabled():
                sp.set(m=N, runs=int(R), width=int(N), n_escalated=0)
                trace.gauge_set("population.nbytes",
                                float(_state_nbytes(state)))
            return (*out, {"m": N, "n_escalated": 0})

        kern = kernel or ctl.default_kernel()
        if kern == "jax":
            ops = [state.reputations, state.ages, state.divs, state.sizes,
                   state.r_min, gains, rand_rank]
            with enable_x64():
                if mesh is not None:
                    # placed INSIDE enable_x64: outside it device_put would
                    # canonicalize the float64 control state down to float32
                    # and silently break oracle bit-parity
                    sh = named(mesh, PartitionSpec(None, data_axes(mesh)))
                    ops = [jax.device_put(np.asarray(a), sh) for a in ops]
                x, alpha, costs, values, forced, cert = _prefilter_kernel(
                    state.policy_id, *ops, w_rep, w_div,
                    np.asarray(cfg.gamma, float), cfg.bandwidth_hz,
                    cfg.p_watt, cfg.n0_watt_hz,
                    k=K, n_sel=cfg.min_selected, m=m_eff)
            x, alpha = np.array(x), np.array(alpha)
            costs, values = np.array(costs).astype(int), np.array(values)
            forced, cert = np.array(forced), np.asarray(cert)
        else:
            x, alpha, costs, values, forced, cert = _prefilter_hybrid(
                state, gains, rand_rank, w_rep, w_div, m_eff)

        # escalate certificate failures to the exact path (still one batched
        # call over just the failing rows)
        bad = np.flatnonzero(~cert)
        if bad.size:
            sub = ctl.ControlState(
                policy_id=state.policy_id[bad], sizes=state.sizes[bad],
                divs=state.divs[bad], r_min=state.r_min[bad],
                reputations=state.reputations[bad], ages=state.ages[bad],
                cfg=cfg)
            xs, als, cs, vs, fs = ctl.schedule_runs(
                sub, gains[bad], rand_rank[bad], w_rep[bad], w_div[bad],
                kernel=kern)
            x[bad], alpha[bad], forced[bad] = xs, als, fs
            costs[bad], values[bad] = cs, vs
        if trace.enabled():
            sp.set(m=m_eff, runs=int(R), width=int(N),
                   n_escalated=int(bad.size))
            trace.counter_inc("population.escalations", int(bad.size))
            trace.gauge_set("population.nbytes",
                            float(_state_nbytes(state)))
        return (x, alpha, costs, values, forced,
                {"m": m_eff, "n_escalated": int(bad.size)})


def _prefilter_hybrid(state: ctl.ControlState, gains, rand_rank,
                      w_rep, w_div, m: int):
    """Hybrid (CPU) layout of the prefilter: batched-numpy elementwise
    math + argpartition prefix, the jitted Eq. 9 bisection and (R, M)
    budget scan — mirroring ``control._schedule_hybrid`` stage for
    stage so certificate-passing rows match it bit-for-bit."""
    cfg = state.cfg
    K = cfg.n_ues
    R = state.n_runs
    N = state.reputations.shape[1]
    pid = state.policy_id

    I = diversity_index_rows(state.divs, state.sizes, state.ages,
                             cfg.gamma)
    values = data_quality_value(state.reputations, I, cfg,
                                omega=(w_rep[:, None], w_div[:, None]))
    with enable_x64():
        costs = np.asarray(ctl._cost_kernel(
            gains, state.r_min, cfg.bandwidth_hz, cfg.p_watt,
            cfg.n0_watt_hz, k=K)).astype(int)
    costs_f = costs.astype(float)

    keys = np.empty((R, N))
    msk = pid == 0
    keys[msk] = priority_key("dqs", values[msk], costs_f[msk], K)
    msk = pid == 1
    keys[msk] = rand_rank[msk]
    msk = pid == 2
    keys[msk] = priority_key("best_channel", values[msk], costs_f[msk], K,
                             gains=gains[msk])
    msk = pid == 3
    keys[msk] = costs_f[msk]
    msk = pid == 4
    keys[msk] = -values[msk]

    kept = _topm_prefix(keys, m)                       # (R, m) visit order
    rows = np.arange(R)[:, None]
    c_kept = costs[rows, kept].astype(np.int32)
    take = np.asarray(ctl._pack_kernel(c_kept, k=K))
    x = np.zeros((R, N), bool)
    x[rows, kept] = take
    alpha = np.where(x, costs_f / K, 0.0)

    # preservation certificate
    b_rem = K - np.where(take, c_kept, 0).sum(-1)
    dropped = np.ones((R, N), bool)
    dropped[rows, kept] = False
    dmin = np.where(dropped, costs, K + 2).min(-1)
    cert = (b_rem < dmin) | (pid == 4)

    # dqs modified-greedy fallback — compressed pack sum, like the
    # hybrid exact path (bit parity on the '>' comparison)
    feas = costs <= K
    masked = np.where(feas, values, -np.inf)
    k_best = masked.argmax(-1)
    ridx = np.arange(R)
    pack_val = np.array([values[i][x[i]].sum() if pid[i] == 0 else 0.0
                         for i in range(R)])
    use_fb = ((pid == 0) & feas.any(-1)
              & (masked[ridx, k_best] > pack_val))
    fb = np.flatnonzero(use_fb)
    x[fb] = False
    x[fb, k_best[fb]] = True
    alpha[fb] = 0.0
    alpha[fb, k_best[fb]] = costs_f[fb, k_best[fb]] / K

    # top_value: first n_sel of the (-values)-ordered kept prefix ==
    # the exact stable argsort(-values)[:n] selection (m >= n_sel)
    tv = np.flatnonzero(pid == 4)
    if tv.size:
        n = cfg.min_selected
        xt = np.zeros((tv.size, N), bool)
        xt[np.arange(tv.size)[:, None], kept[tv, :n]] = True
        x[tv] = xt
        alpha[tv] = np.where(xt, 1.0 / max(n, 1), 0.0)

    # degenerate rounds
    forced = ~x.any(-1)
    fr = np.flatnonzero(forced)
    kf = values[fr].argmax(-1)
    x[fr] = False
    x[fr, kf] = True
    alpha[fr] = 0.0
    alpha[fr, kf] = 1.0
    return x, alpha, costs, values, forced, cert


# ---------------------------------------------------------------------- #
# Mesh plumbing: shard the population axis over the local devices
# ---------------------------------------------------------------------- #
def population_mesh(model_parallel: int = 1):
    """The host mesh (launch.mesh) the population axis shards over —
    axes ("data", "model") spanning every local device."""
    return make_host_mesh(model_parallel=model_parallel)


def shard_population(mesh, *arrays):
    """Place (R, N) control arrays with the population (trailing) axis
    sharded over the mesh's data axes (sharding.specs.named)."""
    sh = named(mesh, PartitionSpec(None, data_axes(mesh)))
    out = tuple(jax.device_put(np.asarray(a), sh) for a in arrays)
    return out if len(out) != 1 else out[0]


def bytes_per_device(pop: PopulationState, n_devices: int) -> int:
    """Resident population-state bytes per device when the N axis is
    sharded over ``n_devices`` (policy_id and cfg scalars replicate)."""
    return pop.nbytes() // max(n_devices, 1) + pop.policy_id.nbytes
