"""Benchmark harness — one entry per paper figure/table + framework benches.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig2,scheduler

Output: ``name,us_per_call,derived`` CSV rows.
  * fig2_*   — paper Fig. 2: data-quality selection strategies (omega sweep)
               under (6,2) / (8,4) label flips. derived = final global acc.
  * fig3_*   — paper Fig. 3: full DQS with the wireless model. derived =
               final global acc.
  * table1_setup — paper SS V-A protocol wiring (50 UEs, groups of 50, 5
               malicious). derived = mean c_k cost of a round.
  * scheduler — Alg. 2 microbenchmark at K=50. derived = objective.
  * kernels  — Pallas (interpret) vs jnp-oracle agreement + oracle timing.
  * roofline — reads results/dryrun_single.json; derived = dominant-term
               seconds per (arch, shape).

Reduced scale (n_train/rounds) keeps the full harness ~minutes on 1 CPU; the
full paper protocol lives in examples/poisoning_study.py.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

ROWS = []


def emit(name: str, us: float, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, *a, n=5, **kw):
    fn(*a, **kw)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*a, **kw)
    return (time.perf_counter() - t0) / n * 1e6, out


# ---------------------------------------------------------------------- #
def bench_fig2(pair, tag, n_train=12_000, rounds=8, seeds=(0, 1)):
    """Paper Fig. 2: select 5 UEs by V_k under different omega weightings —
    diversity-only (w1=0), reputation-only (w2=0), both equal."""
    from repro.federated.simulation import run_experiment
    for label, omega in [("div_only", (0.0, 1.0)), ("rep_only", (1.0, 0.0)),
                         ("both", (0.5, 0.5))]:
        t0 = time.perf_counter()
        accs = [run_experiment("top_value", pair, seed=s, omega=omega,
                               n_train=n_train, n_test=2000, rounds=rounds)["acc"]
                for s in seeds]
        us = (time.perf_counter() - t0) * 1e6 / len(seeds)
        emit(f"fig2_{tag}_{label}", us,
             round(float(np.mean([a[-1] for a in accs])), 4))


def bench_fig3(pair, tag, n_train=12_000, rounds=8, seeds=(0, 1)):
    """Paper Fig. 3: DQS under the wireless model (constrained regime)."""
    from repro.configs.base import FeelConfig
    from repro.federated.simulation import run_experiment
    cfg = FeelConfig(model_size_bits=5e6 * 8)
    for label, omega in [("div_only", (0.0, 1.0)), ("rep_only", (1.0, 0.0)),
                         ("both", (0.5, 0.5))]:
        t0 = time.perf_counter()
        accs = [run_experiment("dqs", pair, cfg=cfg, seed=s, omega=omega,
                               n_train=n_train, n_test=2000, rounds=rounds)["acc"]
                for s in seeds]
        us = (time.perf_counter() - t0) * 1e6 / len(seeds)
        emit(f"fig3_{tag}_{label}", us,
             round(float(np.mean([a[-1] for a in accs])), 4))


def bench_table1_setup():
    """Paper SS V-A/Table I wiring: one full scheduling round at K=50."""
    from repro.configs.base import FeelConfig
    from repro.core.wireless import WirelessModel
    cfg = FeelConfig()
    rng = np.random.default_rng(0)
    wm = WirelessModel(cfg, rng)
    sizes = rng.integers(1, 31, cfg.n_ues) * 50.0
    cpu = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, cfg.n_ues)

    def round_once():
        ch = wm.draw_channels()
        tt = wm.train_time(sizes, cpu)
        return wm.cost(ch.gains, tt)

    us, costs = _timeit(round_once, n=20)
    feas = costs[costs <= cfg.n_ues]
    emit("table1_cost_eval", us, round(float(feas.mean()), 3))


def bench_scheduler():
    """Alg. 2 at the paper's K=50 — scheduling must be cheap vs a 300s round."""
    from repro.configs.base import FeelConfig
    from repro.core.scheduler import dqs_schedule
    cfg = FeelConfig()
    rng = np.random.default_rng(0)
    values = rng.uniform(0, 1, cfg.n_ues)
    costs = rng.integers(1, 10, cfg.n_ues)
    us, s = _timeit(dqs_schedule, values, costs, cfg, n=200)
    emit("scheduler_dqs_k50", us, round(s.objective(), 4))


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)

    B, H, S, D = 1, 4, 512, 64
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = ops.flash_attention(q, k, v)
    err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, k, v))))
    us, _ = _timeit(lambda: jax.block_until_ready(
        ref.flash_attention_ref(q, k, v)), n=10)
    emit("kernel_flash_attn_err", us, f"{err:.2e}")

    x = jax.random.normal(ks[0], (2, 256, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 256, 4)))
    A = -jnp.exp(0.1 * jax.random.normal(ks[2], (4,)))
    Bc = jax.random.normal(ks[3], (2, 256, 4, 16))
    y = ops.ssd_scan(x, dt, A, Bc, Bc, chunk=64)
    yr, _ = ref.ssd_ref(x, dt, A, Bc, Bc)
    emit("kernel_ssd_err", 0.0, f"{float(jnp.max(jnp.abs(y - yr))):.2e}")

    st = jax.random.normal(ks[0], (8, 100_000))
    w = jnp.abs(jax.random.normal(ks[1], (8,)))
    agg = ops.weighted_aggregate(st, w)
    err = float(jnp.max(jnp.abs(agg - ref.weighted_aggregate_ref(st, w))))
    us, _ = _timeit(lambda: jax.block_until_ready(
        ref.weighted_aggregate_ref(st, w)), n=10)
    emit("kernel_fedavg_agg_err", us, f"{err:.2e}")


def bench_roofline(path="results/dryrun_single.json"):
    if not os.path.exists(path):
        emit("roofline_missing", 0.0, path)
        return
    with open(path) as f:
        recs = json.load(f)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            continue
        dom = r["dominant"]
        emit(f"roofline_{r['arch']}_{r['shape']}", r[dom] * 1e6,
             f"{dom}:{r[dom]:.3e}s;ratio:{(r.get('useful_flops_ratio') or 0):.3f}")


BENCHES = {
    "fig2": lambda: (bench_fig2((6, 2), "easy"), bench_fig2((8, 4), "hard")),
    "fig3": lambda: (bench_fig3((6, 2), "easy"), bench_fig3((8, 4), "hard")),
    "table1": bench_table1_setup,
    "scheduler": bench_scheduler,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
