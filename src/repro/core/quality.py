"""Data-quality value (paper §III-B.4, Eq. 3): V_k = w1 * R_k + w2 * I_k."""
from __future__ import annotations

import numpy as np

from repro.configs.base import FeelConfig


def data_quality_value(reputation: np.ndarray, diversity: np.ndarray,
                       cfg: FeelConfig) -> np.ndarray:
    return cfg.omega_rep * reputation + cfg.omega_div * diversity


def adaptive_weights(round_t: int, total_rounds: int,
                     cfg: FeelConfig) -> FeelConfig:
    """Beyond-paper extension motivated by the paper's own §V-B.2 observation:
    diversity matters early, reputation matters late. Linearly anneals
    (omega_div, omega_rep) from (1, 0)-leaning to (0, 1)-leaning over training.
    """
    import dataclasses
    frac = round_t / max(total_rounds - 1, 1)
    total = cfg.omega_rep + cfg.omega_div
    w_rep = total * (0.25 + 0.5 * frac)
    return dataclasses.replace(cfg, omega_rep=w_rep, omega_div=total - w_rep)
