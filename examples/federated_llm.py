"""DQS on federated LM fine-tuning (the ``lm_tiny`` task axis).

    PYTHONPATH=src python examples/federated_llm.py [--fast] [--skip-flash]

The paper's scheduler is model-free: Eqs. 1-3 and Algorithm 2 read only
reputations, histograms and channel states. This example runs the full
DQS stack on the char-LM task (``task="lm_tiny"``, 2-layer transformer,
per-token masked loss) under a *token-space* poisoning attack, and checks
the paper's claim transfers: DQS matches or beats random scheduling on
held-out LM loss.

Three legs:

1. DQS vs random under vocabulary collapse (every token rewritten to 0 on
   malicious clients). The collapse crushes the poisoned clients'
   Gini-Simpson token diversity (Eq. 2) so their data-quality value V_k
   drops, and the LM-sized model upload (82k params) over a 100 kHz cell
   makes the Eq. 9 knapsack *bind* — low-value UEs are actually displaced
   rather than packed into slack budget. (With the paper's literal 100 KB
   / 1 MHz MNIST setting the tiny LM shards leave bandwidth slack, every
   feasible UE is admitted, and all packing policies coincide.)

2. Loop-engine parity: the per-client ``engine="loop"`` oracle reproduces
   the vectorized cohort engine's loss/acc curves bit-for-bit on the LM
   task (the contract tests/test_task_lm.py pins at K=8).

3. Pallas flash attention: one tiny run under ``REPRO_USE_PALLAS=1``
   routes every training forward through the fused flash kernel
   (kernels/flash_attention.py; interpret mode on CPU — this leg is slow
   and deliberately small). Gradients flow through the custom-VJP
   wrapper in kernels/ops.py.

Writes results/federated_llm.json.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import FeelConfig
from repro.core import attacks as atk
from repro.federated.simulation import run_experiment, run_sweep
from repro.federated.task import as_task

# token-space analogue of the paper's label flip: malicious clients'
# streams collapse to a single symbol (watch pair (1, 0) tracks the
# attack's source/target accuracies through the standard metrics)
COLLAPSE = atk.AttackScenario(
    "token_collapse_all",
    data=atk.TokenFlip(tuple((s, 0) for s in range(1, 64))),
    watch=(1, 0))


def _lm_cfg(**kw):
    """Wireless regime where the knapsack binds for an 82k-param upload:
    model_size_bits is the actual lm_tiny parameter count x 32 bits and
    the cell bandwidth is 100 kHz, so honest UEs cost ~2-3 of the K=20
    bandwidth fractions and Algorithm 2 must choose by V_k/c_k."""
    base = dict(n_ues=20, n_malicious=6, deadline_s=60.0,
                model_size_bits=82240 * 32.0, bandwidth_hz=1e5)
    base.update(kw)
    return FeelConfig(**base)


def dqs_vs_random(seeds, rounds):
    print("== leg 1: DQS vs random under vocabulary collapse "
          f"(seeds={list(seeds)}, rounds={rounds}) ==")
    t0 = time.time()
    res = run_sweep(["dqs", "random"], seeds=seeds, cfg=_lm_cfg(),
                    tasks=["lm_tiny"], scenarios=[COLLAPSE],
                    n_train=2000, n_test=400, rounds=rounds)
    out = {}
    for policy in ("dqs", "random"):
        runs = res.select(policy=policy)
        loss = np.mean([r["loss"] for r in runs], axis=0)
        mal = np.mean([r["malicious_selected"] for r in runs], axis=0)
        out[policy] = {
            "loss": [round(float(x), 4) for x in loss],
            "end_loss_per_seed": [round(float(r["loss"][-1]), 4)
                                  for r in runs],
            "malicious_selected_mean": [round(float(m), 2) for m in mal]}
        print(f"  {policy:7s} held-out loss {out[policy]['loss']}")
        print(f"  {policy:7s} malicious selected/round "
              f"{out[policy]['malicious_selected_mean']}")
    d_end = np.mean(out["random"]["end_loss_per_seed"]) \
        - np.mean(out["dqs"]["end_loss_per_seed"])
    print(f"  DQS end-loss advantage over random: {d_end:+.4f} "
          f"({time.time() - t0:.0f}s)")
    assert d_end >= 0.0, (
        "DQS should match or beat random on held-out LM loss: "
        f"dqs={out['dqs']['end_loss_per_seed']} "
        f"random={out['random']['end_loss_per_seed']}")
    out["dqs_advantage"] = round(float(d_end), 4)
    return out


def loop_parity(rounds):
    print("== leg 2: loop-engine parity on lm_tiny ==")
    kw = dict(policy="dqs", scenario=atk.as_scenario("token_flip_1to5"),
              cfg=FeelConfig(n_ues=8, n_malicious=2, task="lm_tiny"),
              seed=0, n_train=960, n_test=240, rounds=rounds)
    vec = run_experiment(engine="vectorized", **kw)
    loop = run_experiment(engine="loop", **kw)
    for key in ("loss", "acc", "malicious_selected"):
        assert np.array_equal(np.asarray(vec[key]), np.asarray(loop[key]),
                              equal_nan=True), f"engine mismatch on {key}"
    print(f"  loop == vectorized on loss/acc/selection "
          f"(loss curve {[round(float(x), 4) for x in vec['loss']]})")
    return {"loss": [round(float(x), 6) for x in vec["loss"]],
            "bit_exact": True}


def flash_leg(rounds):
    print("== leg 3: flash-attention training forward "
          "(REPRO_USE_PALLAS=1, interpret mode — slow) ==")
    import jax
    t0 = time.time()
    os.environ["REPRO_USE_PALLAS"] = "1"
    jax.clear_caches()   # use_pallas() is read at trace time
    try:
        r = run_experiment(
            policy="dqs", scenario=atk.as_scenario("token_flip_1to5"),
            cfg=FeelConfig(n_ues=6, n_malicious=2, task="lm_tiny"),
            seed=0, n_train=480, n_test=120, rounds=rounds)
    finally:
        os.environ.pop("REPRO_USE_PALLAS", None)
        jax.clear_caches()
    assert np.all(np.isfinite(r["loss"])), \
        "flash path produced non-finite loss"
    print(f"  flash loss curve {[round(float(x), 4) for x in r['loss']]} "
          f"({time.time() - t0:.0f}s)")
    return {"loss": [round(float(x), 6) for x in r["loss"]]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced scale (2 seeds, 6 rounds, 1 flash round)")
    ap.add_argument("--skip-flash", action="store_true",
                    help="skip the (slow, interpret-mode) Pallas leg")
    args = ap.parse_args()
    seeds = [0, 1] if args.fast else [0, 1, 2]
    rounds = 6 if args.fast else 8

    tsk = as_task("lm_tiny")
    print(f"task={tsk.name}: vocab={tsk.n_symbols}, seq={tsk.seq}, "
          f"per-token masked loss; scheduler unchanged (model-free)\n")

    results = {"sweep": dqs_vs_random(seeds, rounds),
               "parity": loop_parity(2)}
    if not args.skip_flash:
        results["flash"] = flash_leg(1 if args.fast else 2)

    os.makedirs("results", exist_ok=True)
    with open("results/federated_llm.json", "w") as f:
        json.dump(results, f, indent=2)
    print("\nwrote results/federated_llm.json")


if __name__ == "__main__":
    main()
