"""DQS scheduler (paper Alg. 2) invariants + exact-knapsack comparison."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import FeelConfig
from repro.core.scheduler import (best_channel_schedule, brute_force_schedule,
                                  dqs_schedule, max_count_schedule,
                                  random_schedule, top_value_schedule)


def _cfg(k):
    return FeelConfig(n_ues=k)


@given(st.integers(0, 2**31 - 1), st.integers(5, 30))
@settings(max_examples=30, deadline=None)
def test_dqs_respects_budget_and_feasibility(seed, k):
    rng = np.random.default_rng(seed)
    values = rng.uniform(0, 2, k)
    costs = rng.integers(1, k + 2, k)          # k+1 == infeasible
    s = dqs_schedule(values, costs, _cfg(k))
    # (8c/8d): total bandwidth budget
    assert s.alpha.sum() <= 1.0 + 1e-9
    assert np.all((s.alpha >= 0) & (s.alpha <= 1))
    # selected UEs get exactly their cost in fractions; unselected get none
    np.testing.assert_allclose(s.alpha[s.x], costs[s.x] / k)
    assert np.all(s.alpha[~s.x] == 0)
    # infeasible UEs are never selected (deadline, 8b)
    assert not np.any(s.x[costs > k])


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_dqs_vs_bruteforce_small(seed):
    """Greedy is feasible and close to the exact knapsack optimum."""
    k = 8
    rng = np.random.default_rng(seed)
    values = rng.uniform(0.1, 1.0, k)
    costs = rng.integers(1, k + 1, k)
    g = dqs_schedule(values, costs, _cfg(k))
    b = brute_force_schedule(values, costs, _cfg(k))
    assert g.objective() <= b.objective() + 1e-9
    assert g.objective() >= 0.5 * b.objective() - 1e-9


def test_dqs_prefers_value_density():
    """The greedy order is V/c: a cheap high-value UE beats an expensive
    slightly-higher-value one when the budget only fits one."""
    k = 2
    values = np.array([1.0, 1.1])
    costs = np.array([1, 2])
    cfg = FeelConfig(n_ues=2)
    s = dqs_schedule(values, costs, cfg)
    assert s.x[0] and not s.x[1]      # budget 2: picks c=1 first, 1 left < 2


def test_all_policies_feasible():
    k = 20
    rng = np.random.default_rng(0)
    values = rng.uniform(0, 1, k)
    costs = rng.integers(1, 8, k)
    gains = rng.uniform(1e-12, 1e-8, k)
    cfg = _cfg(k)
    for s in [dqs_schedule(values, costs, cfg),
              random_schedule(values, costs, cfg, rng),
              best_channel_schedule(values, costs, cfg, gains),
              max_count_schedule(values, costs, cfg)]:
        assert s.alpha.sum() <= 1 + 1e-9
        assert not np.any(s.x[costs > k])


def test_max_count_maximises_count():
    k = 10
    rng = np.random.default_rng(1)
    values = rng.uniform(0, 1, k)
    costs = rng.integers(1, 5, k)
    cfg = _cfg(k)
    mc = max_count_schedule(values, costs, cfg)
    dq = dqs_schedule(values, costs, cfg)
    assert mc.x.sum() >= dq.x.sum()


def test_top_value_selects_n():
    cfg = FeelConfig(n_ues=50, min_selected=5)
    values = np.random.default_rng(2).uniform(0, 1, 50)
    s = top_value_schedule(values, cfg, 5)
    assert s.x.sum() == 5
    assert set(s.selected) == set(np.argsort(-values)[:5])
