"""Data poisoning attacks (paper §III-B.1).

Label-flipping: the adversary changes labels of a *source* class to a
*target* class while leaving features untouched — hard to detect from the
update alone. The paper studies the easiest and hardest MNIST pairs from
[Shen et al., ACSAC'16] / [Cao et al., ICPADS'19]: (6 -> 2) and (8 -> 4).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

EASY_PAIR = (6, 2)
HARD_PAIR = (8, 4)


@dataclasses.dataclass(frozen=True)
class LabelFlipAttack:
    source: int
    target: int
    flip_fraction: float = 1.0    # fraction of source-class samples flipped

    def apply(self, labels: np.ndarray,
              rng: np.random.Generator) -> np.ndarray:
        out = labels.copy()
        idx = np.flatnonzero(out == self.source)
        if self.flip_fraction < 1.0 and idx.size:
            n = int(round(self.flip_fraction * idx.size))
            idx = rng.choice(idx, size=n, replace=False)
        out[idx] = self.target
        return out


def pick_malicious(n_ues: int, n_malicious: int,
                   rng: np.random.Generator) -> np.ndarray:
    """Paper §V-A: in each run, n_malicious UEs chosen at random."""
    return rng.choice(n_ues, size=n_malicious, replace=False)


@dataclasses.dataclass(frozen=True)
class ModelPoisonAttack:
    """Model-poisoning (the paper's §VI future-work item): the malicious UE
    manipulates its *update* rather than its data —
    ``Omega' = g + scale * (Omega - g)``. scale = -1 is a sign-flip
    (gradient-ascent) attack; |scale| >> 1 is a boosted/backdoor-style attack
    [Bagdasaryan et al., AISTATS'20]."""
    scale: float = -1.0

    def apply(self, global_params, local_params):
        import jax
        return jax.tree.map(
            lambda g, l: g + self.scale * (l - g), global_params,
            local_params)
