"""Wireless model (paper Eq. 4-7, 9) properties."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import FeelConfig
from repro.core.wireless import WirelessModel, dbm_to_watt


def _wm(seed=0, **kw):
    cfg = FeelConfig(**kw)
    return WirelessModel(cfg, np.random.default_rng(seed)), cfg


def test_dbm():
    assert dbm_to_watt(0) == pytest.approx(1e-3)
    assert dbm_to_watt(30) == pytest.approx(1.0)


def test_rate_monotone_in_bandwidth():
    """Eq. 4: r(alpha) is increasing in alpha (log concavity)."""
    wm, _ = _wm()
    g = np.array([1e-9])
    alphas = np.linspace(0.01, 1.0, 50)
    r = wm.rate(g, alphas[None, :] * np.ones((1, 50)))[0]
    r = wm.rate(np.full(50, 1e-9), alphas)
    assert np.all(np.diff(r) > 0)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_cost_is_minimal(seed):
    """Eq. 9: c_k is the MINIMUM feasible fraction count."""
    wm, cfg = _wm(seed)
    ch = wm.draw_channels()
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 31, cfg.n_ues) * 50.0
    cpu = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, cfg.n_ues)
    tt = wm.train_time(sizes, cpu)
    costs = wm.cost(ch.gains, tt)
    r_min = wm.min_rate(tt)
    K = cfg.n_ues
    for k in range(K):
        c = costs[k]
        if c <= K:
            assert wm.rate(ch.gains[k:k+1], np.array([c / K]))[0] >= r_min[k]
            if c > 1:
                assert wm.rate(ch.gains[k:k+1],
                               np.array([(c - 1) / K]))[0] < r_min[k]
        else:
            assert wm.rate(ch.gains[k:k+1], np.array([1.0]))[0] < r_min[k]


def test_train_time_scales_with_data_and_epochs():
    wm, cfg = _wm()
    t1 = wm.train_time(np.array([100.0]), np.array([1e8]))
    t2 = wm.train_time(np.array([200.0]), np.array([1e8]))
    assert t2 == pytest.approx(2 * t1)
    wm2, _ = _wm(local_epochs=cfg.local_epochs * 2)
    assert wm2.train_time(np.array([100.0]), np.array([1e8])) \
        == pytest.approx(2 * t1)


def test_deadline_violation_infeasible():
    """A UE whose training alone blows T can never upload (cost K+1)."""
    wm, cfg = _wm()
    tt = np.full(cfg.n_ues, cfg.deadline_s + 1.0)
    costs = wm.cost(wm.draw_channels().gains, tt)
    assert np.all(costs == cfg.n_ues + 1)
