"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels are
validated against in tests, shape/dtype-swept)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q (B,H,S,D), k/v (B,H,T,D) -> (B,H,S,D). fp32 softmax."""
    B, H, S, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        i = jnp.arange(S)[:, None] + (T - S)     # right-aligned
        j = jnp.arange(T)[None, :]
        m = j <= i
        if window is not None:
            m &= (i - j) < window
        logits = jnp.where(m[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v)


def decode_attention_ref(q, k, v, length):
    """q (B,H,D); k/v (B,T,H,D); attend to positions < length. -> (B,H,D)."""
    B, H, D = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bhd,bthd->bht", q, k).astype(jnp.float32) * D ** -0.5
    mask = jnp.arange(T)[None, :] < length
    logits = jnp.where(mask[:, None, :] if mask.ndim == 2 else mask,
                       logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bht,bthd->bhd", p.astype(v.dtype), v)


def ssd_ref(x, dt, A, B_, C_):
    """Naive sequential SSD recurrence (independent of models.ssm).

    x (B,L,H,P); dt (B,L,H) fp32; A (H,); B_/C_ (B,L,H,N).
    h_t = exp(dt_t A) h_{t-1} + dt_t * B_t (x) x_t;  y_t = C_t . h_t
    Returns (y (B,L,H,P), final_state (B,H,N,P)).
    """
    Bb, L, H, P = x.shape
    N = B_.shape[-1]

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        decay = jnp.exp(dtt * A)                          # (B,H)
        upd = jnp.einsum("bhn,bhp->bhnp", bt.astype(jnp.float32),
                         (xt * dtt[..., None]).astype(jnp.float32))
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((Bb, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C_, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


# public-wrapper naming convention (repro.check kernel-ref-twin rule):
# every ops.<kernel> has a <kernel>_ref twin; ssd_ref predates the rule
ssd_scan_ref = ssd_ref


def moe_gemm_ref(buf, w):
    """(E,C,d) x (E,d,f) -> (E,C,f)."""
    return jnp.einsum("ecd,edf->ecf", buf, w)


def weighted_aggregate_ref(stacked, weights):
    """(N, M) x (N,) -> (M,): sum_i w_i x_i / sum_i w_i (FedAvg, Alg. 1)."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)
    return jnp.einsum("n,nm->m", w.astype(jnp.float32),
                      stacked.astype(jnp.float32)).astype(stacked.dtype)


def weighted_aggregate_tree_ref(updates_stacked, weights):
    """Leaf-wise FedAvg oracle: ``weighted_aggregate_ref`` over a pytree
    of stacked updates (the twin of ``ops.weighted_aggregate_tree``)."""
    def per(leaf):
        n = leaf.shape[0]
        return weighted_aggregate_ref(leaf.reshape(n, -1),
                                      weights).reshape(leaf.shape[1:])
    return jax.tree.map(per, updates_stacked)


def robust_aggregate_ref(stacked, n, *, trim=0, mode="trimmed_mean"):
    """(N, M), first n rows real -> (M,) coordinate-wise trimmed mean /
    median over the client axis (defense plane, core/defenses.py)."""
    x = stacked.astype(jnp.float32)
    row = jnp.arange(x.shape[0])[:, None]
    xs = jnp.sort(jnp.where(row < n, x, jnp.inf), axis=0)
    if mode == "trimmed_mean":
        keep = (row >= trim) & (row < n - trim)
        out = (jnp.sum(jnp.where(keep, xs, 0.0), axis=0)
               / jnp.float32(max(n - 2 * trim, 1)))
    else:
        out = (xs[(n - 1) // 2] + xs[n // 2]) * jnp.float32(0.5)
    return out.astype(stacked.dtype)
