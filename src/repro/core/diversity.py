"""Dataset diversity evaluation (paper §III-B.3, Eq. 2).

``I_k = sum_i gamma_i * v_i`` over normalised metrics
i in {elements diversity, dataset size, age}. For classification the elements
diversity is the Gini-Simpson index over label frequencies (paper §V-B.1,
following [10] arXiv:2102.09491).

``normalize_last`` / ``diversity_index_eq2`` are the pure-JAX twins used by
the batched control plane (core/control.py): the same Eq. 2, over a
trailing UE axis with arbitrary leading batch (run) axes, jit/vmap-able.
The numpy pair stays as the host oracle.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np


def gini_simpson(labels: np.ndarray, n_classes: int) -> float:
    """1 - sum p_c^2; 0 for a single-class set, (C-1)/C for uniform."""
    if labels.size == 0:
        return 0.0
    counts = np.bincount(labels.astype(int), minlength=n_classes)
    p = counts / counts.sum()
    return float(1.0 - np.sum(p * p))


def gini_simpson_hist(counts: np.ndarray) -> float:
    """``gini_simpson`` from a precomputed histogram — the form tasks whose
    symbols are not per-sample labels use (e.g. token histograms of an LM
    client's windows, federated/task.py). 0.0 for an empty histogram."""
    counts = np.asarray(counts, float)
    total = counts.sum()
    if total == 0:
        return 0.0
    p = counts / total
    return float(1.0 - np.sum(p * p))


def normalize_rows(values: np.ndarray) -> np.ndarray:
    """Min-max normalise a metric to [0, 1] along the last (UE) axis, any
    leading (run) batch axes — the ONE numpy definition of the Eq. 2
    normalisation (``normalize`` is its 1-D view; the 1e-12
    degenerate-span rule must stay in lockstep with ``normalize_last``
    or host/batched parity breaks)."""
    values = np.asarray(values, float)
    lo = values.min(-1, keepdims=True)
    hi = values.max(-1, keepdims=True)
    span = hi - lo
    return np.where(span < 1e-12, 1.0,
                    (values - lo) / np.where(span < 1e-12, 1.0, span))


def normalize(values: np.ndarray) -> np.ndarray:
    """Min-max normalise a metric across UEs to [0, 1]."""
    return normalize_rows(values)


def diversity_index_rows(element_diversity, dataset_sizes, ages,
                         gamma) -> np.ndarray:
    """Eq. 2 over (..., K) numpy arrays (leading run axes welcome); the
    three weighted terms accumulate left-to-right — the order every other
    implementation must match for bit-parity."""
    return (gamma[0] * normalize_rows(element_diversity)
            + gamma[1] * normalize_rows(dataset_sizes)
            + gamma[2] * normalize_rows(ages))


def diversity_index(element_diversity: np.ndarray,
                    dataset_sizes: np.ndarray,
                    ages: np.ndarray,
                    gamma: Sequence[float]) -> np.ndarray:
    """Eq. 2 across all K UEs. ``ages`` = rounds since last participation
    (higher -> staler -> more valuable to refresh)."""
    return diversity_index_rows(element_diversity, dataset_sizes, ages,
                                np.asarray(gamma, float))


# ---------------------------------------------------------------------- #
# Pure-JAX twins (batched "jax" kernel layout).
# ---------------------------------------------------------------------- #
def normalize_last(values):
    """``normalize_rows`` in jnp (last/UE axis, leading batch axes)."""
    lo = values.min(-1, keepdims=True)
    hi = values.max(-1, keepdims=True)
    return jnp.where(hi - lo < 1e-12, jnp.ones_like(values),
                     (values - lo) / (hi - lo))


def diversity_index_eq2(element_diversity, dataset_sizes, ages, gamma):
    """``diversity_index_rows`` in jnp — same left-to-right accumulation,
    so the two agree bit-for-bit in float64 (modulo XLA FMA contraction,
    see core/control.py)."""
    return (gamma[0] * normalize_last(element_diversity)
            + gamma[1] * normalize_last(dataset_sizes)
            + gamma[2] * normalize_last(ages))
