from repro.sharding.ctx import activation_specs, constrain
from repro.sharding.specs import (batch_specs, data_axes, named,
                                  opt_state_specs, param_specs)

__all__ = ["activation_specs", "constrain", "batch_specs", "data_axes",
           "named", "opt_state_specs", "param_specs"]
