"""Shared model building blocks: norms, RoPE, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------- #
# Initialisation
# ---------------------------------------------------------------------- #
def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (std * jax.random.truncated_normal(key, -3.0, 3.0, shape,
                                              jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (0.02 * jax.random.truncated_normal(key, -3.0, 3.0, shape,
                                               jnp.float32)).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------- #
# Norms (computed in fp32, cast back)
# ---------------------------------------------------------------------- #
def rms_norm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def gated_rms_norm(x, z, scale, eps=1e-5):
    """Mamba2 output norm: RMSNorm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    scale, eps)


# ---------------------------------------------------------------------- #
# RoPE
# ---------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, D/2)
    sin = jnp.sin(ang)[..., None, :]                            # (..., S, 1, D/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# MLP
# ---------------------------------------------------------------------- #
def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, (d_model, d_ff), dtype),
        "wu": dense_init(k2, (d_model, d_ff), dtype),
        "wd": dense_init(k3, (d_ff, d_model), dtype, fan_in=d_ff),
    }


def swiglu_apply(p, x):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    return h @ p["wd"]


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE; logits (..., V) fp32-safe."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
