"""Synthetic MNIST-like dataset (offline container — no downloads).

Deterministic class-structured 28x28 images: each digit class c has a set of
smooth prototype templates (random low-frequency blobs seeded per class);
samples are prototype + elastic jitter + pixel noise. The generator preserves
the properties the paper's experiments rely on: 10 classes, learnable with a
2-layer MLP to high accuracy, label flips measurably degrade the targeted
class.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

N_CLASSES = 10
IMG = 28


@dataclasses.dataclass
class Dataset:
    x: np.ndarray    # (N, 784) float32 in [0,1]
    y: np.ndarray    # (N,) int32

    def __len__(self):
        return self.x.shape[0]

    def subset(self, idx: np.ndarray) -> "Dataset":
        return Dataset(self.x[idx], self.y[idx])


def _class_prototypes(rng: np.random.Generator, n_proto: int = 4) -> np.ndarray:
    """(C, n_proto, 28, 28) smooth random blobs, distinct per class."""
    protos = np.zeros((N_CLASSES, n_proto, IMG, IMG), np.float32)
    yy, xx = np.mgrid[0:IMG, 0:IMG] / (IMG - 1)
    for c in range(N_CLASSES):
        for p in range(n_proto):
            img = np.zeros((IMG, IMG), np.float32)
            # 3-5 gaussian strokes at class-consistent anchor points
            n_blobs = 3 + (c % 3)
            for b in range(n_blobs):
                cx = 0.2 + 0.6 * ((c * 7 + b * 3 + p) % 10) / 9.0
                cy = 0.2 + 0.6 * ((c * 3 + b * 5) % 10) / 9.0
                sx = 0.05 + 0.08 * rng.uniform()
                sy = 0.05 + 0.08 * rng.uniform()
                img += np.exp(-((xx - cx) ** 2 / (2 * sx ** 2)
                                + (yy - cy) ** 2 / (2 * sy ** 2)))
            protos[c, p] = img / max(img.max(), 1e-6)
    return protos


def generate(n_train: int = 50_000, n_test: int = 10_000,
             seed: int = 0, noise: float = 0.15) -> Tuple[Dataset, Dataset]:
    """Paper §V-A sizes: 50,000 train / 10,000 test."""
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng)
    n_proto = protos.shape[1]

    def make(n):
        y = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
        p = rng.integers(0, n_proto, size=n)
        base = protos[y, p]                                  # (n, 28, 28)
        shift = rng.integers(-2, 3, size=(n, 2))
        imgs = np.empty_like(base)
        for i in range(n):                                   # cheap roll jitter
            imgs[i] = np.roll(np.roll(base[i], shift[i, 0], 0), shift[i, 1], 1)
        imgs = imgs + noise * rng.standard_normal(imgs.shape).astype(np.float32)
        x = np.clip(imgs, 0.0, 1.0).reshape(n, IMG * IMG).astype(np.float32)
        return Dataset(x, y)

    return make(n_train), make(n_test)
