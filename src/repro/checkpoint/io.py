"""Msgpack-based pytree checkpointing (offline container: no orbax).

Layout: <dir>/<step>/state.msgpack + meta.json. Arrays are stored as
(dtype, shape, raw bytes); bfloat16 round-trips via a uint16 view.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = "bfloat16"


def _encode_leaf(x):
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return {"dtype": _BF16, "shape": list(x.shape),
                "data": x.view(np.uint16).tobytes()}
    return {"dtype": str(x.dtype), "shape": list(x.shape),
            "data": x.tobytes()}


def _decode_leaf(d):
    if d["dtype"] == _BF16:
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])
    return jnp.asarray(arr)


def save_pytree(tree: Any, path: str) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {"leaves": [_encode_leaf(x) for x in leaves],
               "treedef": str(treedef)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_pytree(like: Any, path: str) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    new = [_decode_leaf(d) for d in payload["leaves"]]
    assert len(new) == len(leaves), (
        f"checkpoint has {len(payload['leaves'])} leaves, expected {len(leaves)}")
    return jax.tree.unflatten(treedef, new)


def save(ckpt_dir: str, step: int, state: Any, meta: Optional[dict] = None):
    d = os.path.join(ckpt_dir, f"{step:08d}")
    os.makedirs(d, exist_ok=True)
    save_pytree(state, os.path.join(d, "state.msgpack"))
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(n) for n in os.listdir(ckpt_dir) if n.isdigit()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None):
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    d = os.path.join(ckpt_dir, f"{step:08d}")
    state = load_pytree(like, os.path.join(d, "state.msgpack"))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    return state, meta
