"""Per-architecture smoke tests (assignment requirement): a REDUCED variant of
each family (2 layers, d_model<=512, <=4 experts) runs one forward + one train
step on CPU; output shapes asserted, no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig, get, list_archs, reduced
from repro.launch.steps import init_state, make_train_step
from repro.models import api

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.is_encoder_decoder:
        batch["src"] = jax.random.normal(key, (B, 16, cfg.d_model),
                                         jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = reduced(get(arch))
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    batch = _batch(cfg, key)
    loss, metrics = api.loss(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_descends(arch):
    cfg = reduced(get(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    tcfg = TrainConfig(optimizer="adamw", lr=5e-3, remat=False)
    key = jax.random.PRNGKey(1)
    params, opt_state, step = init_state(cfg, tcfg, key)
    train_step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, key)
    losses = []
    for _ in range(3):
        params, opt_state, step, m = train_step(params, opt_state, step, batch)
        losses.append(float(m["loss"]))
        assert jnp.isfinite(m["loss"]), f"{arch}: loss blew up"
        assert jnp.isfinite(m["grad_norm"])
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"
    assert int(step) == 3


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = reduced(get(arch))
    key = jax.random.PRNGKey(2)
    params = api.init(cfg, key)
    B, S = 2, 16
    cache = api.cache_init(cfg, B, S)
    logits, cache2 = api.decode_step(cfg, params, cache,
                                     jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["index"]) == 1


def test_param_count_reasonable():
    """Analytic param counts should match actual init within 5% (used by the
    roofline's 6*N*D)."""
    for arch in ARCHS:
        cfg = reduced(get(arch))
        params = api.init(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.05, \
            f"{arch}: est {est} vs actual {actual}"
