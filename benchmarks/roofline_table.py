"""Render EXPERIMENTS.md tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_table \
        --single results/dryrun_single.json --multi results/dryrun_multi.json
"""
from __future__ import annotations

import argparse
import json

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt(x):
    return f"{x:.2e}" if isinstance(x, float) else str(x)


def roofline_table(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "6ND/HLO | peak GiB/chip |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         SHAPE_ORDER.index(r["shape"])
                                         if r["shape"] in SHAPE_ORDER else 9)):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped: {r['reason'][:60]} | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | "
                        f"{r.get('error', '')[:60]} | | |")
            continue
        peak = (r.get("memory") or {}).get("peak_bytes")
        peak_s = f"{peak/2**30:.2f}" if peak else "n/a"
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['compute_s'])} | "
            f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
            f"**{r['dominant'].replace('_s','')}** | "
            f"{ratio:.3f} | {peak_s} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {_fmt(r['compute_s'])} | "
            f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
            f"**{r['dominant'].replace('_s','')}** | n/a | {peak_s} |")
    return "\n".join(rows)


def lowering_matrix(recs):
    archs = sorted({r["arch"] for r in recs})
    rows = ["| arch | " + " | ".join(SHAPE_ORDER) + " |",
            "|---|" + "---|" * len(SHAPE_ORDER)]
    idx = {(r["arch"], r["shape"]): r for r in recs}
    for a in archs:
        cells = []
        for s in SHAPE_ORDER:
            r = idx.get((a, s))
            cells.append({"ok": "✓", "skipped": "skip", None: "—"}.get(
                r["status"] if r else None, "✗"))
        rows.append(f"| {a} | " + " | ".join(cells) + " |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="results/dryrun_single.json")
    ap.add_argument("--multi", default="results/dryrun_multi.json")
    args = ap.parse_args()
    with open(args.single) as f:
        single = json.load(f)
    print("## Roofline (single pod 16x16, per-chip terms)\n")
    print(roofline_table(single))
    try:
        with open(args.multi) as f:
            multi = json.load(f)
        print("\n## Multi-pod (2x16x16) lowering matrix\n")
        print(lowering_matrix(multi))
    except FileNotFoundError:
        pass


if __name__ == "__main__":
    main()
