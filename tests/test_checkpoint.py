"""Checkpoint roundtrip (msgpack pytrees, bf16-safe)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.checkpoint.io import latest_step, load_pytree, save_pytree


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "d": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    p = str(tmp_path / "x.msgpack")
    save_pytree(t, p)
    out = load_pytree(t, p)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_step_management(tmp_path):
    d = str(tmp_path / "ckpt")
    t = _tree()
    save(d, 10, t, {"note": "first"})
    save(d, 20, t)
    assert latest_step(d) == 20
    state, meta = restore(d, t)
    assert meta["step"] == 20
    state, meta = restore(d, t, step=10)
    assert meta["note"] == "first"


def test_restore_empty(tmp_path):
    state, meta = restore(str(tmp_path / "none"), _tree())
    assert state is None and meta is None
