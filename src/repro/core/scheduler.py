"""Joint UE selection + bandwidth allocation (paper §IV, Algorithm 2).

Problem (8) — maximise ``sum_k x_k V_k`` subject to the round deadline (8b),
total bandwidth (8c/8d) and binary selection (8e) — is knapsack-equivalent
(NP-hard). DQS solves it greedily: compute each UE's bandwidth *cost* ``c_k``
(minimum number of uniform 1/K fractions meeting its minimum rate, Eq. 9),
order by ``V_k / c_k`` decreasing, and pack into the budget of K fractions.

Approximation guarantee: density-greedy alone can be arbitrarily bad (one
expensive high-value UE displaced by a cheap low-value one that blocks the
budget), so ``dqs_schedule`` finishes with the classic modified-greedy step —
take the better of the greedy pack and the single best feasible UE — which
guarantees ``objective >= OPT / 2`` (tests/test_scheduler.py pins this
against ``brute_force_schedule`` on random instances).

Baseline policies used by the paper's comparison figures are provided too,
plus a brute-force exact solver for small K (test oracle for the NP-hard
claim).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from repro.configs.base import FeelConfig
from repro.core.wireless import WirelessModel


@dataclasses.dataclass
class Schedule:
    x: np.ndarray          # (K,) bool selection
    alpha: np.ndarray      # (K,) bandwidth fractions, sum <= 1
    cost: np.ndarray       # (K,) c_k in fractions (K+1 = infeasible)
    value: np.ndarray      # (K,) V_k used for the decision

    @property
    def selected(self) -> np.ndarray:
        return np.flatnonzero(self.x)

    def objective(self) -> float:
        return float(self.value[self.x].sum())


def dqs_schedule(values: np.ndarray, costs: np.ndarray,
                 cfg: FeelConfig) -> Schedule:
    """Algorithm 2: greedy knapsack by V_k / c_k over a budget of K fractions,
    then the modified-greedy fallback (see module docstring): if the single
    best feasible UE beats the whole greedy pack, schedule it alone — this is
    what makes the 1/2-approximation bound hold."""
    K = cfg.n_ues
    order = np.argsort(-values / costs, kind="stable")
    x = np.zeros(K, bool)
    alpha = np.zeros(K)
    budget = K
    for k in order:
        c = int(costs[k])
        if c > K:                      # cannot meet the deadline at all
            continue
        if budget - c >= 0:
            x[k] = True
            alpha[k] = c / K
            budget -= c
        if budget <= 0:
            break
    feas = costs <= K
    if feas.any():
        k_best = int(np.flatnonzero(feas)[np.argmax(values[feas])])
        if values[k_best] > values[x].sum():
            x = np.zeros(K, bool)
            x[k_best] = True
            alpha = np.zeros(K)
            alpha[k_best] = costs[k_best] / K
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def brute_force_schedule(values: np.ndarray, costs: np.ndarray,
                         cfg: FeelConfig, max_k: int = 16) -> Schedule:
    """Exact knapsack by enumeration — oracle for tests (K <= max_k).

    Same semantics as the greedy path: K and the fraction budget come from
    ``cfg.n_ues`` (the seed ignored ``cfg`` and used ``len(values)``, which
    silently changed the budget whenever the two disagreed)."""
    K = cfg.n_ues
    assert len(values) == K, (len(values), K)
    assert K <= max_k, "brute force limited to small K"
    best, best_x = -1.0, np.zeros(K, bool)
    feas = [k for k in range(K) if costs[k] <= K]
    for r in range(len(feas) + 1):
        for combo in itertools.combinations(feas, r):
            c = sum(int(costs[k]) for k in combo)
            if c <= K:
                v = float(values[list(combo)].sum()) if combo else 0.0
                if v > best:
                    best = v
                    best_x = np.zeros(K, bool)
                    best_x[list(combo)] = True
    alpha = np.where(best_x, costs / K, 0.0)
    return Schedule(x=best_x, alpha=alpha, cost=costs, value=values)


# ---------------------------------------------------------------------- #
# Baseline policies (paper §II / §V comparisons)
# ---------------------------------------------------------------------- #
def random_schedule(values, costs, cfg, rng) -> Schedule:
    """Random feasible packing (ignores data quality)."""
    K = cfg.n_ues
    order = rng.permutation(K)
    x = np.zeros(K, bool)
    alpha = np.zeros(K)
    budget = K
    for k in order:
        c = int(costs[k])
        if c <= K and budget - c >= 0:
            x[k] = True
            alpha[k] = c / K
            budget -= c
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def best_channel_schedule(values, costs, cfg, gains) -> Schedule:
    """Nishio & Yonetani-style: prioritise good channels (min cost first)."""
    K = cfg.n_ues
    order = np.argsort(costs * K - gains / (gains.max() + 1e-12), kind="stable")
    x = np.zeros(K, bool)
    alpha = np.zeros(K)
    budget = K
    for k in order:
        c = int(costs[k])
        if c <= K and budget - c >= 0:
            x[k] = True
            alpha[k] = c / K
            budget -= c
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def max_count_schedule(values, costs, cfg) -> Schedule:
    """Zeng et al.-style: maximise the number of scheduled UEs."""
    K = cfg.n_ues
    order = np.argsort(costs, kind="stable")
    x = np.zeros(K, bool)
    alpha = np.zeros(K)
    budget = K
    for k in order:
        c = int(costs[k])
        if c <= K and budget - c >= 0:
            x[k] = True
            alpha[k] = c / K
            budget -= c
    return Schedule(x=x, alpha=alpha, cost=costs, value=values)


def top_value_schedule(values, costs, cfg, n: int) -> Schedule:
    """Paper §V-B.1: pick the n highest-V_k UEs (no wireless constraint).

    Selection ignores the channel entirely, but the round log must still
    report the UEs' *real* wireless costs — the seed fabricated
    ``costs = ones(K)``, so every ``top_value`` Schedule.cost misreported
    the channel state (``FeelServer._schedule`` now threads the actual
    Eq. 9 costs through)."""
    K = cfg.n_ues
    order = np.argsort(-values, kind="stable")[:n]
    x = np.zeros(K, bool)
    x[order] = True
    alpha = np.where(x, 1.0 / max(n, 1), 0.0)
    return Schedule(x=x, alpha=alpha, cost=np.asarray(costs), value=values)


POLICIES = {
    "dqs": dqs_schedule,
    "random": random_schedule,
    "best_channel": best_channel_schedule,
    "max_count": max_count_schedule,
}
