"""starcoder2-15b — dense code model with GQA + RoPE and native
sliding-window attention (4096) [arXiv:2402.19173].

40L, d_model 6144, 48H GQA kv=4, d_ff 24576, vocab 49152."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24_576,
    vocab_size=49_152,
    sliding_window=4096,             # native SWA -> long_500k runs natively
    rope_theta=100_000.0,
    citation="[arXiv:2402.19173]",
)
