"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Full-sequence path uses the chunked SSD algorithm: quadratic attention-like
intra-chunk term (MXU-friendly matmuls) + an inter-chunk state recurrence via
``lax.scan``. Decode is the O(1) state recurrence. The intra-chunk contraction
is the compute hot-spot mirrored by the Pallas ``ssd_scan`` kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of, gated_rms_norm


def ssm_init(key, cfg, d_model=None):
    s = cfg.ssm
    d = d_model or cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    # dt bias initialised so softplus(dt_bias) spans [dt_min, dt_max]
    u = np.random.RandomState(0).uniform(size=(nh,))
    dt0 = np.exp(u * (np.log(s.dt_max) - np.log(s.dt_min)) + np.log(s.dt_min))
    dt_bias = dt0 + np.log(-np.expm1(-dt0))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * s.n_groups * s.d_state + nh), dt),
        "conv_w": dense_init(ks[1], (s.conv_kernel, conv_ch), dt, fan_in=s.conv_kernel),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),                  # A = -exp(0) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "norm": jnp.ones((d_in,), dt),
        "out_proj": dense_init(ks[2], (d_in, d), dt, fan_in=d_in),
    }


def _split_proj(cfg, p, x):
    s = cfg.ssm
    d_in = s.expand * (p["out_proj"].shape[1])
    nh = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt, d_in, nh, gn


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over axis 1. xbc (B,L,ch); w (K,ch)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _heads(cfg, xbc, dt, p, d_in, nh, gn):
    s = cfg.ssm
    x_, B_, C_ = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    B, L = x_.shape[:2]
    x_ = x_.reshape(B, L, nh, s.head_dim)
    B_ = B_.reshape(B, L, s.n_groups, s.d_state)
    C_ = C_.reshape(B, L, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    Bh = jnp.repeat(B_, rep, axis=2)
    Ch = jnp.repeat(C_, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    return x_, Bh, Ch, dt, A


def ssd_chunked(x, dt, A, Bh, Ch, chunk, initial_state=None,
                compute_dtype=jnp.float32):
    """Chunked SSD. x (B,L,H,P); dt (B,L,H) fp32; A (H,); Bh/Ch (B,L,H,N).

    ``compute_dtype`` controls the materialised (Q x Q) decay/score tensors —
    the dominant HBM traffic (hillclimb lever; inter-chunk state stays fp32).
    Returns (y (B,L,H,P), final_state (B,H,N,P)).
    """
    B, L, H, P = x.shape
    N = Bh.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    nc = L // Q
    r = lambda t: t.reshape(B, nc, Q, *t.shape[2:])
    xc, dtc, Bc, Cc = r(x), r(dt), r(Bh), r(Ch)
    cdt = jnp.dtype(compute_dtype)

    dA = dtc * A                                                  # (B,nc,Q,H) <=0
    cum = jnp.cumsum(dA, axis=2)                                  # inclusive
    # intra-chunk (the Pallas ssd_scan kernel mirrors this contraction)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (B,nc,Q,Q,H) i,j
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(seg), 0.0).astype(cdt)       # (B,nc,Q,Q,H)
    xdt = (xc * dtc[..., None]).astype(cdt)
    G = jnp.einsum("bcqhn,bckhn->bcqkh", Cc.astype(cdt),
                   Bc.astype(cdt),
                   preferred_element_type=cdt)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", G * Lmat, xdt,
                         preferred_element_type=jnp.float32)

    # per-chunk local states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)                  # (B,nc,Q,H)
    S_local = jnp.einsum("bckhn,bckhp->bchnp",
                         (Bc * decay_end[..., None]).astype(jnp.float32),
                         xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    def step(S, inp):
        S_loc, dec = inp
        S_new = S * dec[:, :, None, None] + S_loc
        return S_new, S                                           # emit previous

    S0 = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    S_last, S_prev = jax.lax.scan(
        step, S0, (jnp.moveaxis(S_local, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                           # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp",
                         (Cc * jnp.exp(cum)[..., None]).astype(jnp.float32),
                         S_prev)
    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y.astype(x.dtype), S_last


def ssm_apply(cfg, p, x, *, initial_state=None):
    """Full-sequence Mamba2 block. Returns (y, (conv_state, ssm_state))."""
    s = cfg.ssm
    z, xbc, dt, d_in, nh, gn = _split_proj(cfg, p, x)
    conv_state = xbc[:, -(s.conv_kernel - 1):, :]                 # pre-activation
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x_, Bh, Ch, dtf, A = _heads(cfg, xbc, dt, p, d_in, nh, gn)
    y, state = ssd_chunked(x_, dtf, A, Bh, Ch, s.chunk,
                           initial_state=initial_state,
                           compute_dtype=s.compute_dtype)
    y = y + (p["D"][:, None] * x_.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*x.shape[:2], d_in)
    y = gated_rms_norm(y, z, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], (conv_state, state)


def ssm_decode(cfg, p, x, conv_state, ssm_state):
    """One-token recurrence. x (B,1,d); conv_state (B,K-1,ch);
    ssm_state (B,H,N,P) fp32. Returns (y, conv_state, ssm_state)."""
    s = cfg.ssm
    z, xbc, dt, d_in, nh, gn = _split_proj(cfg, p, x)
    window = jnp.concatenate([conv_state, xbc], axis=1)           # (B,K,ch)
    new_conv_state = window[:, 1:]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    x_, Bh, Ch, dtf, A = _heads(cfg, xbc1, dt, p, d_in, nh, gn)
    x_, Bh, Ch, dtf = x_[:, 0], Bh[:, 0], Ch[:, 0], dtf[:, 0]     # (B,H,*)
    decay = jnp.exp(dtf * A)                                      # (B,H)
    xdt = (x_ * dtf[..., None]).astype(jnp.float32)
    upd = jnp.einsum("bhn,bhp->bhnp", Bh.astype(jnp.float32), xdt)
    new_state = ssm_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), new_state)
    y = y + p["D"][:, None] * x_.astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, d_in).astype(x.dtype)
    y = gated_rms_norm(y, z, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_conv_state, new_state
