"""Wall-clock per FEEL round: sequential per-client loop vs the vectorized
cohort engine (federated/cohort.py), at the paper's K=50 and beyond.

    PYTHONPATH=src python -m benchmarks.bench_round                # K=50,200,500
    PYTHONPATH=src python -m benchmarks.bench_round --ks 500 \
        --engines unbucketed vectorized         # single pad vs 3 size buckets
    PYTHONPATH=src python -m benchmarks.bench_round --sweep        # run_sweep
    PYTHONPATH=src python -m benchmarks.bench_round --smoke        # CI gate

Methodology — each (engine, K) measurement runs the §V unit of work in a
FRESH subprocess (cold jit cache): ``--seeds`` independent experiments
(fresh partition each — the paper averages over independent runs) of
``--rounds`` rounds. This charges each engine what the protocol actually
charges it. The loop engine re-traces per *shape*: one ``mlp_sgd_epoch``
per distinct client dataset size and one eager evaluation program per
distinct per-UE test-subset size — and almost every shape is new again in
every fresh partition. The cohort engine compiles a handful of bucketed
(N, max_samples) programs that are shape-stable across seeds. The
per-round median (compiles mostly excluded) is reported alongside.

Engines: ``loop`` (sequential oracle), ``vectorized`` (size-bucketed
cohort engine, ``--buckets`` levels), ``unbucketed`` (vectorized with a
single global pad — the pre-bucketing baseline).

``--sweep`` instead measures a (policies x seeds) study end-to-end:
batched ``run_sweep`` vs the same grid as sequential ``run_experiment``
calls (each mode in a fresh subprocess).

``--smoke`` runs a tiny instance of both benchmarks with loud assertions
(bucketed padding waste must not exceed the single-pad waste; curves must
be finite) — wired into tier-1 via tests/test_bench_smoke.py so bench
regressions fail loudly.

CSV rows:

    engine,K,n_train,s_per_round,median_round_s,speedup,median_speedup,pad_waste
"""
import argparse
import json
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = r"""
import json, sys, time
import numpy as np
from repro.configs.base import FeelConfig
from repro.core.poisoning import EASY_PAIR, LabelFlipAttack, pick_malicious
from repro.data.partition import partition
from repro.data.synthetic_mnist import generate
from repro.federated.server import FeelServer

engine, k, n_train, n_test, rounds, seeds, n_buckets = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]), int(sys.argv[6]), int(sys.argv[7]))
cfg = FeelConfig(n_ues=k, n_malicious=max(k // 10, 1))
times, wastes = [], []
for seed in range(seeds):
    train, test = generate(n_train, n_test, seed=seed)
    rng = np.random.default_rng(seed)
    malicious = pick_malicious(cfg.n_ues, cfg.n_malicious, rng)
    clients = partition(train, cfg.n_ues, rng, malicious,
                        LabelFlipAttack(*EASY_PAIR))
    server = FeelServer(cfg, clients, test, rng, policy="dqs",
                        engine=engine, n_buckets=n_buckets)
    for t in range(rounds):
        t0 = time.perf_counter()
        server.run_round(t)
        times.append(time.perf_counter() - t0)
    wastes.extend(server.pad_waste)
print(json.dumps({"times": times, "waste": wastes}))
"""

_SWEEP_WORKER = r"""
import json, sys, time
import numpy as np
from repro.federated.simulation import run_experiment, run_sweep

mode, n_seeds, n_train, n_test, rounds = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]))
policies = ["dqs", "top_value"]
seeds = list(range(n_seeds))
t0 = time.perf_counter()
if mode == "sweep":
    res = run_sweep(policies, seeds=seeds, n_train=n_train, n_test=n_test,
                    rounds=rounds)
    accs = [r["acc"] for r in res.runs]
else:
    accs = [run_experiment(p, (6, 2), seed=s, n_train=n_train,
                           n_test=n_test, rounds=rounds)["acc"]
            for p in policies for s in seeds]
el = time.perf_counter() - t0
assert all(np.isfinite(a).all() for a in map(np.asarray, accs))
print(json.dumps({"s_total": el, "n_runs": len(accs)}))
"""

# engine CLI name -> (FeelServer engine, n_buckets override or None)
ENGINES = {"loop": ("loop", None),
           "vectorized": ("vectorized", None),
           "unbucketed": ("vectorized", 1)}


def _run_worker(code, argv, timeout=3600):
    r = subprocess.run(
        [sys.executable, "-c", code] + [str(a) for a in argv],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                             "")},
        timeout=timeout)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def _measure(name, k, n_train, n_test, rounds, seeds, buckets):
    engine, nb = ENGINES[name]
    out = _run_worker(_WORKER, [engine, k, n_train, n_test, rounds, seeds,
                                nb if nb is not None else buckets])
    times = out["times"]
    mean = sum(times) / len(times)
    median = sorted(times)[(len(times) - 1) // 2]   # lower-biased: keeps
    waste = (sum(out["waste"]) / len(out["waste"])  # compile rounds out
             if out["waste"] else float("nan"))
    return mean, median, times, waste


def _auto_n_train(k: int) -> int:
    # keep the partition pool >= the clients' demand so datasets stay
    # size-diverse (K=50 matches the paper's regime scaled to bench time);
    # cap at the paper's 50k corpus
    return min(50_000, max(10_000, 100 * k))


def bench_k(k, n_train, n_test, rounds, seeds, engines, buckets):
    nt = n_train or _auto_n_train(k)
    out = {}
    for name in engines:
        out[name] = _measure(name, k, nt, n_test, rounds, seeds, buckets)
        print(f"# {name} K={k} per-round s: "
              f"{[round(x, 2) for x in out[name][2]]}", file=sys.stderr)
    base = engines[0]
    cl, sl = out[base][:2]
    for name in engines:
        c, s, _, w = out[name]
        print(f"{name},{k},{nt},{c:.3f},{s:.3f},{cl / c:.2f},{sl / s:.2f},"
              f"{w:.2f}", flush=True)
    return out


def bench_sweep(n_seeds, n_train, n_test, rounds):
    """Batched run_sweep vs the same grid of sequential run_experiment
    calls — each mode cold, in a fresh subprocess."""
    print("mode,n_runs,s_total,speedup")
    res = {}
    for mode in ("sequential", "sweep"):
        res[mode] = _run_worker(_SWEEP_WORKER,
                                [mode, n_seeds, n_train, n_test, rounds])
    base = res["sequential"]["s_total"]
    for mode in ("sequential", "sweep"):
        r = res[mode]
        print(f"{mode},{r['n_runs']},{r['s_total']:.1f},"
              f"{base / r['s_total']:.2f}", flush=True)
    return base / res["sweep"]["s_total"]


def smoke():
    """Tiny end-to-end run of both benchmarks with loud assertions.

    K=40 is the smallest scale where size bucketing reliably beats the
    single global pad (below ~3x _N_BUCKET the cohort-axis padding of 2-3
    sub-cohorts outweighs the max_samples savings)."""
    out = bench_k(40, 4000, 300, 2, 1,
                  ["unbucketed", "vectorized"], buckets=3)
    w_un, w_b = out["unbucketed"][3], out["vectorized"][3]
    assert w_b <= w_un + 1e-9, (
        f"bucketed padding waste {w_b:.2f}x exceeds single-pad {w_un:.2f}x")
    assert all(t > 0 for name in out for t in out[name][2])
    speedup = bench_sweep(2, 3000, 300, 2)
    assert speedup > 0, speedup
    print(f"# smoke OK: waste {w_un:.2f}x -> {w_b:.2f}x, "
          f"sweep speedup {speedup:.2f}x", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", type=int, nargs="+", default=[50, 200, 500])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seeds", type=int, default=3,
                    help="independent fresh-partition runs per measurement")
    ap.add_argument("--n-train", type=int, default=None,
                    help="override the per-K automatic corpus size")
    ap.add_argument("--n-test", type=int, default=1_000)
    ap.add_argument("--engines", nargs="+", default=["loop", "vectorized"],
                    choices=sorted(ENGINES),
                    help="speedup columns are relative to the first")
    ap.add_argument("--buckets", type=int, default=3,
                    help="size-bucket count for the 'vectorized' engine "
                         "(the 'unbucketed' engine pins 1)")
    ap.add_argument("--sweep", action="store_true",
                    help="benchmark run_sweep vs sequential run_experiment "
                         "(uses --seeds as the seed count)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny asserted run of both benchmarks (CI gate)")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return
    if args.sweep:
        bench_sweep(args.seeds, args.n_train or 10_000, args.n_test,
                    args.rounds)
        return

    print("engine,K,n_train,s_per_round,median_round_s,"
          "speedup,median_speedup,pad_waste")
    for k in args.ks:
        out = bench_k(k, args.n_train, args.n_test, args.rounds,
                      args.seeds, args.engines, args.buckets)
        base, last = args.engines[0], args.engines[-1]
        if base != last:
            print(f"# K={k}: {last} per-round speedup over {base} "
                  f"{out[base][0] / out[last][0]:.2f}x", file=sys.stderr)


if __name__ == "__main__":
    main()
