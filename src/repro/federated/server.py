"""FEEL server (Alg. 1): per-round schedule -> local train -> evaluate ->
reputation update -> FedAvg aggregate.

The server sees only what the paper allows it to see: dataset *metadata*
(size, label histogram for the diversity index, staleness), self-reported
local accuracies, uploaded models evaluated on the public test set, and
channel state. It never touches raw client data.

Two execution engines implement Alg. 1 lines 9-14:

    "vectorized" (default) — the cohort engine (federated/cohort.py): the
        round's scheduled UEs are stacked into (N, max_samples, ...) arrays
        and trained in one jitted vmapped step; the per-model test
        evaluations run as a single vmap and aggregation goes through the
        stacked ``fedavg_stacked`` path.
    "loop" — the original sequential per-client loop, kept as the
        correctness oracle (tests/test_cohort.py pins the engines to the
        same accuracy curve).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import FeelConfig
from repro.core import (ReputationTracker, WirelessModel, data_quality_value,
                        diversity_index, dqs_schedule, gini_simpson,
                        top_value_schedule)
from repro.core.scheduler import (Schedule, best_channel_schedule,
                                  max_count_schedule, random_schedule)
from repro.data.partition import ClientData, label_histogram, pad_clients
from repro.data.synthetic_mnist import Dataset, N_CLASSES
from repro.federated import cohort
from repro.federated.aggregation import fedavg, fedavg_stacked
from repro.federated.client import local_train
from repro.models.mlp import mlp_accuracy, mlp_init


@dataclasses.dataclass
class RoundLog:
    round: int
    selected: np.ndarray
    global_acc: float
    n_malicious_selected: int
    objective: float
    values: np.ndarray
    reputations: np.ndarray
    source_acc: float = float("nan")   # accuracy on the attacked class


class FeelServer:
    """policy: 'dqs' | 'random' | 'best_channel' | 'max_count' | 'top_value'.
    'top_value' reproduces §V-B.1 (pure data-quality selection, no wireless).

    engine: 'vectorized' | 'loop' (see module docstring).
    """

    _N_BUCKET = 8   # cohort sizes are padded to a multiple of this with
                    # zero-weight null clients (shape-stable compiles)

    def __init__(self, cfg: FeelConfig, clients: List[ClientData],
                 test: Dataset, rng: np.random.Generator,
                 policy: str = "dqs", lr: float = 0.1,
                 adaptive_omega: bool = False, lie_boost: float = 0.0,
                 watch_class: Optional[int] = None, model_poison=None,
                 engine: str = "vectorized", batch_size: int = 50,
                 pad_to: Optional[int] = None):
        assert engine in ("vectorized", "loop"), engine
        self.cfg = cfg
        self.clients = clients
        self.test = test
        self.rng = rng
        self.policy = policy
        self.lr = lr
        self.adaptive_omega = adaptive_omega
        self.lie_boost = lie_boost
        self.watch_class = watch_class     # the attack's source class
        self.model_poison = model_poison
        self.engine = engine
        self.batch_size = batch_size
        self.pad_to = pad_to        # stable cohort shape across seeds

        self.wireless = WirelessModel(cfg, rng)
        self.reputation = ReputationTracker(cfg)
        self.params = mlp_init(jax.random.PRNGKey(int(rng.integers(1 << 31))))
        self.ages = np.ones(cfg.n_ues)          # rounds since last selected
        self.cpu_hz = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, cfg.n_ues)
        self.sizes = np.array([c.size for c in clients], float)
        # UEs report label histograms once (metadata); poisoned labels are
        # what the UE *believes*, so the histogram reflects the flip.
        self.divs = np.array([gini_simpson(c.data.y, N_CLASSES)
                              for c in clients])
        self.histograms = [label_histogram(c.data, N_CLASSES) for c in clients]
        # Interpretation decision (DESIGN.md): Eq. 1's acc_test is evaluated
        # on the test subset restricted to the classes a UE claims to hold —
        # otherwise the reputation punishes honest-but-skewed (non-IID) UEs
        # exactly as hard as poisoners, which contradicts the paper's Fig. 2.
        self._test_masks = [np.isin(test.y, np.flatnonzero(h > 0))
                            for h in self.histograms]
        self._test_mask_arr = np.stack(self._test_masks).astype(np.float32)
        self._tx = jax.numpy.asarray(test.x)
        self._ty = jax.numpy.asarray(test.y)
        # vectorized-engine state, built on first use: device-resident
        # padded client arrays / per-UE eval masks and the true sizes
        self._pd_dev = None
        self._mask_dev = None
        self._pd_sizes: Optional[np.ndarray] = None
        self.logs: List[RoundLog] = []

    # ------------------------------------------------------------------ #
    def _values(self, round_t: int) -> np.ndarray:
        cfg = self.cfg
        if self.adaptive_omega:
            from repro.core import adaptive_weights
            cfg = adaptive_weights(round_t, cfg.rounds, cfg)
        I = diversity_index(self.divs, self.sizes, self.ages, cfg.gamma)
        return data_quality_value(self.reputation.values, I, cfg)

    def _schedule(self, values: np.ndarray) -> Schedule:
        cfg = self.cfg
        gains = self.wireless.draw_channels().gains
        t_train = self.wireless.train_time(self.sizes, self.cpu_hz)
        costs = self.wireless.cost(gains, t_train)
        if self.policy == "dqs":
            return dqs_schedule(values, costs, cfg)
        if self.policy == "random":
            return random_schedule(values, costs, cfg, self.rng)
        if self.policy == "best_channel":
            return best_channel_schedule(values, costs, cfg, gains)
        if self.policy == "max_count":
            return max_count_schedule(values, costs, cfg)
        if self.policy == "top_value":
            return top_value_schedule(values, cfg, cfg.min_selected)
        raise KeyError(self.policy)

    # ------------------------------------------------------------------ #
    # Per-cohort execution engines: both return the stacked/list client
    # results as (acc_local, acc_test, aggregate-and-assign side effect).
    # ------------------------------------------------------------------ #
    def _run_cohort_loop(self, sel: np.ndarray) -> Tuple[np.ndarray,
                                                         np.ndarray]:
        cfg = self.cfg
        reports = [local_train(self.clients[k], self.params,
                               cfg.local_epochs, self.lr,
                               batch_size=self.batch_size,
                               lie_boost=self.lie_boost,
                               model_poison=self.model_poison) for k in sel]

        # server-side evaluation of every uploaded model (Alg. 1 line 14) on
        # the classes each UE claims to hold (see __init__ note)
        acc_test = np.empty(len(reports))
        for i, (r, k) in enumerate(zip(reports, sel)):
            m = self._test_masks[k]
            acc_test[i] = float(mlp_accuracy(
                r.params, jax.numpy.asarray(self.test.x[m]),
                jax.numpy.asarray(self.test.y[m]))) if m.any() else 0.0
        acc_local = np.array([r.acc_local for r in reports])

        self.params = fedavg([r.params for r in reports],
                             [r.n_samples for r in reports])
        return acc_local, acc_test

    def _run_cohort_vectorized(self, sel: np.ndarray) -> Tuple[np.ndarray,
                                                               np.ndarray]:
        cfg = self.cfg
        if self._pd_dev is None:
            pd = pad_clients(self.clients, multiple_of=self.batch_size,
                             pad_to=self.pad_to)
            # loop-engine parity contract: the loop's mlp_sgd_epoch DROPS a
            # tail batch (nb = n // batch_size) while the masked engine
            # would train it, so a non-dividing batch_size must fail loudly
            assert not np.any(pd.sizes % self.batch_size), (
                "vectorized engine requires batch_size to divide every "
                "client dataset size (the loop oracle drops tail batches)")
            # resident on device once (with one extra all-zero "null client"
            # row at index K); per-round cohort stacking is then a
            # device-side gather instead of a host copy + transfer. Only
            # the device copy is kept — the host copy would double the
            # padded dataset's footprint for the server's lifetime.
            zrow = lambda a: np.concatenate([a, np.zeros_like(a[:1])])
            self._pd_dev = tuple(jax.numpy.asarray(zrow(a))
                                 for a in (pd.x, pd.y, pd.mask))
            self._mask_dev = jax.numpy.asarray(zrow(self._test_mask_arr))
            self._pd_sizes = pd.sizes
        n = sel.size
        # bucket the cohort size to a multiple of 8 by padding with the
        # null client (mask all-zero -> training no-op, weight 0 below), so
        # rounds with new cohort sizes reuse the compiled step instead of
        # re-tracing — the exact pathology this engine replaces
        n_pad = -(-n // self._N_BUCKET) * self._N_BUCKET
        idx_np = np.concatenate(
            [sel, np.full(n_pad - n, len(self.clients), sel.dtype)])
        idx = jax.numpy.asarray(idx_np)
        xs = jax.numpy.take(self._pd_dev[0], idx, axis=0)
        ys = jax.numpy.take(self._pd_dev[1], idx, axis=0)
        ms = jax.numpy.take(self._pd_dev[2], idx, axis=0)
        stacked, acc = cohort.cohort_train(self.params, xs, ys, ms, self.lr,
                                           cfg.local_epochs, self.batch_size)
        acc_local = np.asarray(acc, float)[:n]

        mal = np.array([self.clients[k].malicious for k in sel])
        if self.model_poison is not None and mal.any():
            # same contract as the loop path: model_poison.apply() per
            # malicious client (cold path — robustness studies only)
            for i in np.flatnonzero(mal):
                poisoned = self.model_poison.apply(
                    self.params, cohort.unstack(stacked, int(i)))
                stacked = jax.tree.map(
                    lambda l, p, i=int(i): l.at[i].set(p), stacked, poisoned)
        if self.lie_boost:
            acc_local = np.where(
                mal, np.minimum(acc_local + self.lie_boost, 1.0), acc_local)

        masks = jax.numpy.take(self._mask_dev, idx, axis=0)
        acc_test = np.asarray(
            cohort.cohort_eval(stacked, self._tx, self._ty, masks),
            float)[:n]

        weights = np.zeros(n_pad)
        weights[:n] = self._pd_sizes[sel]
        self.params = fedavg_stacked(stacked, weights)
        return acc_local, acc_test

    # ------------------------------------------------------------------ #
    def run_round(self, t: int) -> RoundLog:
        cfg = self.cfg
        values = self._values(t)
        sched = self._schedule(values)
        sel = sched.selected
        if sel.size == 0:
            # Degenerate channel draw: no UE meets the deadline, so the
            # server forces the single highest-value UE. Rewrite the
            # schedule so the logged objective / selection vector describe
            # the actual participant set, not the empty one.
            k = int(np.argmax(values))
            sel = np.array([k])
            x = np.zeros(cfg.n_ues, bool)
            x[k] = True
            alpha = np.zeros(cfg.n_ues)
            alpha[k] = 1.0          # the forced UE gets the whole band
            sched = Schedule(x=x, alpha=alpha, cost=sched.cost,
                             value=sched.value)

        if self.engine == "vectorized":
            acc_local, acc_test = self._run_cohort_vectorized(sel)
        else:
            acc_local, acc_test = self._run_cohort_loop(sel)
        self.reputation.update(sel, acc_local, acc_test)

        g_acc = float(mlp_accuracy(self.params, self._tx, self._ty))
        src_acc = float("nan")
        if self.watch_class is not None:
            m = self.test.y == self.watch_class
            if m.any():
                src_acc = float(mlp_accuracy(
                    self.params, jax.numpy.asarray(self.test.x[m]),
                    jax.numpy.asarray(self.test.y[m])))

        # ages: selected reset, others grow (staleness metric of Eq. 2)
        self.ages += 1.0
        self.ages[sel] = 1.0

        log = RoundLog(
            round=t, selected=sel, global_acc=g_acc,
            n_malicious_selected=sum(self.clients[k].malicious for k in sel),
            objective=sched.objective(), values=values.copy(),
            reputations=self.reputation.values.copy(), source_acc=src_acc)
        self.logs.append(log)
        return log

    def run(self, rounds: Optional[int] = None) -> List[RoundLog]:
        for t in range(rounds or self.cfg.rounds):
            self.run_round(t)
        return self.logs
