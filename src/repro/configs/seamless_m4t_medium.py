"""seamless-m4t-medium — encoder-decoder multimodal backbone
[arXiv:2308.11596].

Transformer backbone only (assignment carve-out): the speech frontend
(mel-spectrogram + conv feature extractor) is a stub; ``input_specs`` supplies
precomputed frame embeddings (B, S_src, 1024). 12L encoder + 12L decoder,
d_model 1024, 16H (kv=16), d_ff 4096, vocab 256206.

long_500k is SKIPPED for this arch (DESIGN.md §Arch-applicability): a
524288-token *target* sequence is not meaningful for a speech enc-dec."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    encoder_layers=12,
    is_encoder_decoder=True,
    frontend="audio",
    citation="[arXiv:2308.11596]",
)
