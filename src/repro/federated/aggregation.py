"""FedAvg aggregation (Alg. 1 line 13): g <- sum_k (D_k / D_t) * Omega_k.

The list form (``fedavg``) and the stacked cohort form (``fedavg_stacked``)
share one normalisation and one combine path, so they agree bit-for-bit
(tests/test_fedavg.py pins this): weights are normalised in float64 on the
host when concrete (float32 under trace) and the weighted sum always
accumulates in float32.

``fedavg_stacked`` is the cohort engine's aggregation route. With
``kernel=True`` (or ``REPRO_USE_PALLAS=1``) the stacked pytree is flattened
into a single (N, M) matrix and reduced by the Pallas ``weighted_aggregate``
kernel — interpret mode off-TPU, Mosaic on TPU; otherwise an equivalent XLA
reduction runs leaf-wise.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def normalize_weights(weights) -> jnp.ndarray:
    """(N,) weights -> (N,) float32 fractions summing to 1.

    Concrete inputs normalise in float64 on the host (stable against
    accumulation order, then one rounding to float32); traced inputs fall
    back to float32 jnp ops — the only option under jit with x64 disabled.
    """
    if isinstance(weights, jax.core.Tracer):
        w = jnp.asarray(weights, jnp.float32)
        return w / jnp.maximum(w.sum(), 1e-9)
    w = np.asarray(weights, np.float64)
    s = w.sum()
    assert s > 0, "empty aggregation"
    return jnp.asarray((w / s).astype(np.float32))


@jax.jit
def _combine_tree(stacked, w):
    """Leaf-wise (N, ...) x normalised (N,) -> (...), float32 accumulation.
    Jitted once per (structure, N): the round loop calls this every round."""
    def combine(leaf):
        wf = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * wf,
                       axis=0).astype(leaf.dtype)
    return jax.tree.map(combine, stacked)


def fedavg(updates: Sequence, weights: Sequence[float]):
    """Weighted average of parameter pytrees (list form). Stacks the updates
    and delegates to ``fedavg_stacked`` — one code path for both forms."""
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
    return fedavg_stacked(stacked, weights)


def fedavg_stacked(stacked, weights, kernel: Optional[bool] = None):
    """Aggregate updates stacked on axis 0 (device-cohort layout):
    leaf (N, ...) x weights (N,) -> (...).

    kernel — True routes through the Pallas ``weighted_aggregate`` kernel on
    the flattened parameter vector; None defers to ``ops.use_pallas()``.
    """
    w = normalize_weights(weights)
    if kernel is None:
        from repro.kernels import ops
        kernel = ops.use_pallas()
    if kernel:
        from repro.kernels import ops
        leaves, treedef = jax.tree.flatten(stacked)
        n = leaves[0].shape[0]
        flat = jnp.concatenate(
            [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)
        agg = ops.weighted_aggregate(flat, w, assume_normalized=True)
        out, off = [], 0
        for l in leaves:
            m = int(np.prod(l.shape[1:], dtype=np.int64))
            out.append(agg[off:off + m].reshape(l.shape[1:]).astype(l.dtype))
            off += m
        return jax.tree.unflatten(treedef, out)
    return _combine_tree(stacked, w)
