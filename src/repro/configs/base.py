"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. Families:
  dense   — pre-norm decoder-only transformer with GQA attention
  moe     — dense attention + mixture-of-experts MLPs (shared + routed)
  ssm     — attention-free Mamba2/SSD stack
  hybrid  — Jamba-style interleave of Mamba2 and attention layers + MoE
  audio   — encoder-decoder backbone consuming precomputed frame embeddings
  vlm     — early-fusion decoder (VQ image tokens share the text vocab)
  mlp     — the paper's MNIST MLP (federated learning experiments)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (GShard-style capacity, sort-based dispatch)."""
    n_routed: int                 # routed experts
    top_k: int
    d_ff_expert: int              # hidden width of each routed expert
    n_shared: int = 0             # always-active shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    normalize_gates: bool = True  # renormalize top-k gate probs (DeepSeek style)
    # >1: group-local dispatch — tokens are grouped (aligned with the data
    # axis), sort/scatter happen within a group, and only the expert einsum
    # crosses shards (all-to-all). 0/1 = single global dispatch (SPMD-hostile
    # scatter; kept as the recorded baseline). See EXPERIMENTS.md §Perf.
    dispatch_groups: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD sub-config."""
    d_state: int = 128            # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    n_groups: int = 1             # G (B/C groups)
    conv_kernel: int = 4
    chunk: int = 256              # SSD chunk length Q
    dt_min: float = 0.001
    dt_max: float = 0.1
    # dtype of the materialised intra-chunk decay/score tensors (hillclimb
    # lever: bf16 halves the dominant HBM traffic; state stays fp32)
    compute_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention sub-config [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm | mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                     # dense-MLP hidden width (0 for pure-SSM)
    vocab_size: int
    citation: str = ""

    head_dim: int = 0             # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False         # Chameleon-style query/key RMSNorm
    norm_eps: float = 1e-5

    # Sliding-window attention. ``sliding_window`` applies to ALL shapes
    # (StarCoder2 native). ``long_context_window`` is the explicit variant used
    # only for the long_500k shape on otherwise-full-attention archs; None
    # means the arch either handles long context natively (ssm/hybrid) or
    # skips the shape (enc-dec).
    sliding_window: Optional[int] = None
    long_context_window: Optional[int] = None

    # MoE
    moe: Optional[MoEConfig] = None
    moe_layer_period: int = 1     # apply MoE every p-th layer (Jamba: 2)
    first_dense_layers: int = 0   # DeepSeek: first k layers use dense MLP

    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    attn_layer_period: int = 0    # hybrid: one attention layer per p layers
    attn_layer_offset: int = 4    # position of the attention layer in a block

    # Encoder-decoder (audio)
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    frontend: str = "none"        # none | audio | vlm  (stubs per carve-out)

    # DeepSeek extras
    mla: Optional[MLAConfig] = None
    mtp: bool = False             # depth-1 multi-token-prediction head

    dtype: str = "bfloat16"
    # Scan super-block length; derived in __post_init__ if 0.
    block_len: int = 0
    # lax.scan unroll factor for the layer scan (dry-run cost extraction uses
    # fully-unrolled short variants; production configs keep 1).
    scan_unroll: int = 1

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.block_len == 0:
            p = 1
            if self.attn_layer_period:
                p = max(p, self.attn_layer_period)
            if self.moe is not None:
                p = max(p, self.moe_layer_period)
            object.__setattr__(self, "block_len", p)

    # ------------------------------------------------------------------ #
    # Layer-pattern helpers
    # ------------------------------------------------------------------ #
    @property
    def scanned_layers(self) -> int:
        return self.n_layers - self.first_dense_layers

    @property
    def n_blocks(self) -> int:
        assert self.scanned_layers % self.block_len == 0, (
            f"{self.name}: {self.scanned_layers} layers not divisible by "
            f"block_len {self.block_len}")
        return self.scanned_layers // self.block_len

    def layer_kind(self, idx_in_block: int) -> dict:
        """Describe sub-layer ``idx_in_block`` of a scan super-block."""
        if self.family == "ssm":
            return {"mixer": "ssm", "mlp": "none"}
        mixer = "attn"
        if self.attn_layer_period:
            mixer = ("attn" if idx_in_block % self.attn_layer_period
                     == self.attn_layer_offset % self.attn_layer_period
                     else "ssm")
        mlp = "dense"
        if self.moe is not None and (idx_in_block % self.moe_layer_period
                                     == self.moe_layer_period - 1):
            mlp = "moe"
        if self.family == "ssm":
            mlp = "none"
        return {"mixer": mixer, "mlp": mlp}

    def block_pattern(self) -> Tuple[dict, ...]:
        return tuple(self.layer_kind(i) for i in range(self.block_len))

    # ------------------------------------------------------------------ #
    # Analytic parameter counts (for MODEL_FLOPS = 6 N D roofline term)
    # ------------------------------------------------------------------ #
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim
        n = 0
        # embeddings + head (untied)
        n += 2 * self.vocab_size * d
        for b in range(self.n_blocks):
            for k in self.block_pattern():
                n += self._mixer_params(k["mixer"])
                n += self._mlp_params(k["mlp"], active_only)
                n += 2 * d  # two rms-norm scales
        for _ in range(self.first_dense_layers):
            n += self._mixer_params("attn")
            n += self._dense_mlp_params(self.d_ff)
            n += 2 * d
        if self.is_encoder_decoder:
            for _ in range(self.encoder_layers):
                n += self._mixer_params("attn") + self._dense_mlp_params(self.d_ff)
                n += 2 * d
            # cross attention per decoder layer
            n += self.n_layers * (self._mixer_params("attn") + d)
        n += d  # final norm
        if self.mtp:
            n += (self._mixer_params("attn") + self._dense_mlp_params(self.d_ff)
                  + 2 * d * d + 3 * d)     # combine-proj (2d x d) + norms
        return n

    def _mixer_params(self, kind: str) -> int:
        d, hd = self.d_model, self.head_dim
        if kind == "attn":
            if self.mla is not None:
                m = self.mla
                qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                n = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_hd
                n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                n += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                n += self.n_heads * m.v_head_dim * d
                return n
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o
        if kind == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            proj_in = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
            conv = (d_in + 2 * s.n_groups * s.d_state) * s.conv_kernel
            out = d_in * d
            extra = nh * 3 + d_in  # A_log, D, dt_bias, gated-norm scale
            return proj_in + conv + out + extra
        return 0

    def _dense_mlp_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff  # SwiGLU: gate, up, down

    def _mlp_params(self, kind: str, active_only: bool) -> int:
        if kind == "none":
            return 0
        if kind == "dense":
            return self._dense_mlp_params(self.d_ff)
        m = self.moe
        per = self._dense_mlp_params(m.d_ff_expert)
        router = self.d_model * m.n_routed
        n_exp = (m.top_k if active_only else m.n_routed) + m.n_shared
        return n_exp * per + router


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / training-loop hyper-parameters."""
    optimizer: str = "adamw"      # sgd | momentum | adam | adamw | adafactor
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    remat: bool = True


@dataclass(frozen=True)
class FeelConfig:
    """Federated-edge-learning round configuration (the paper's Table I)."""
    n_ues: int = 50               # K
    n_malicious: int = 5
    # Candidate population size N (DESIGN.md §12). ``n_ues`` stays the
    # bandwidth budget K — the Eq. 9 fraction denominator, the Alg. 2
    # knapsack capacity — while the scheduler ranks over all N candidates.
    # None pins the legacy N == K regime (every pre-population caller).
    population: Optional[int] = None
    rounds: int = 15              # t_max
    local_epochs: int = 3         # epsilon (paper leaves it unspecified)
    deadline_s: float = 300.0     # T
    bandwidth_hz: float = 1e6     # B
    model_size_bits: float = 100e3 * 8   # s = 100 Ko
    tx_power_dbm: float = -23.0   # P_k
    noise_dbm_hz: float = -174.0  # N0
    pathloss_exp: float = 3.76    # alpha (not given in paper; 3GPP UMa value)
    cell_side_m: float = 500.0
    min_selected: int = 5         # N in Algorithm 1
    # data-quality weights
    omega_rep: float = 0.5        # omega_1
    omega_div: float = 0.5        # omega_2
    gamma: Tuple[float, float, float] = (1 / 3, 1 / 3, 1 / 3)
    eta: float = 1.0              # reputation rate (paper: eta = 1)
    # beta_i are unspecified in the paper; weighted toward the server-side
    # test gap, the stronger poisoning signal (see EXPERIMENTS.md)
    beta1: float = 0.2            # weight of (acc_local - avg_acc)
    beta2: float = 0.8            # weight of (acc_local - acc_test)
    # threat-model metrics (core/attacks.py): the attacked class counts as
    # recovered once the source->target attack success rate stays below
    # this threshold (feeds ``recovery_rounds``)
    recovery_threshold: float = 0.5
    # default defense policy (core/defenses.py registry name) — the server
    # resolves it when no explicit ``defense=`` is given, so a config can
    # pin a defended baseline; sweeps vary defenses per run via
    # ``run_sweep(defenses=[...])`` while sharing one config
    defense: str = "none"
    # default task (federated/task.py registry name): the model/data pair
    # the federated round trains — "mnist_mlp" (the paper's §V protocol)
    # or "lm_tiny" (federated LM fine-tuning). Sweeps vary tasks per run
    # via ``run_sweep(tasks=[...])``; the batched control plane treats
    # configs differing only in ``task`` as compatible (core/control.py).
    task: str = "mnist_mlp"
    # --- execution mode (federated/async_engine.py, DESIGN.md §13) ---
    # "sync" runs Alg. 1 as lockstep rounds; "async" runs the
    # event-driven engine: each scheduled UE's upload arrives at a
    # simulated per-UE time from the Eq. 6/7 latency model, the server
    # aggregates on a buffer/deadline trigger with staleness-discounted
    # weights, and the next wave is dispatched right after each
    # aggregation (cohort selection overlaps in-flight training).
    mode: str = "sync"
    # aggregate once this many uploads are buffered; None waits for every
    # in-flight upload (the synchronous lockstep limit)
    async_buffer: Optional[int] = None
    # also aggregate at dispatch_time + deadline sim-seconds with whatever
    # has arrived (None = no deadline trigger)
    async_deadline: Optional[float] = None
    # staleness-discount base (core/control.py::staleness_discount): an
    # upload computed on a model ``a`` aggregations old weighs
    # sizes * async_staleness**a. a = 0 gives exactly 1.0 — the FedAvg
    # weight, bit-for-bit — which is what makes the synchronous engine
    # the zero-latency oracle.
    async_staleness: float = 0.5
    # scales every simulated upload latency; 0.0 is the zero-latency
    # oracle limit where mode="async" must reproduce mode="sync" exactly
    async_latency_scale: float = 1.0
    # AR(1)/Gauss-Markov small-scale fading correlation rho across
    # consecutive channel draws (core/wireless.py): 0.0 keeps the legacy
    # memoryless Rayleigh draw bit-for-bit; rho in (0, 1) gives each UE
    # persistent block-fading state with stationary |h|^2 ~ Exp(1).
    channel_corr: float = 0.0
    # client compute model (Eq. 6). zeta/f are unspecified in the paper;
    # calibrated so t_train spans [~1s, ~375s] against T=300s — large datasets
    # on slow UEs can blow the deadline, which is exactly the paper's
    # motivation for joint selection + bandwidth allocation.
    cycles_per_bit: float = 2e3   # zeta_k
    cpu_hz_min: float = 5e7       # f_k drawn uniformly in [min, max]
    cpu_hz_max: float = 5e8
    sample_bits: float = 28 * 28 * 8

    # ------------------------------------------------------------------ #
    # Derived linear-scale wireless constants. Both control planes — the
    # host-numpy oracle (core/wireless.py) and the batched JAX kernel
    # (core/control.py) — must feed Eq. 4/9 the exact same float64
    # scalars, so the dBm -> watt conversion lives here, once
    # (``dbm_to_watt`` below; wireless.py re-exports it).
    # ------------------------------------------------------------------ #
    @property
    def n_population(self) -> int:
        """Candidate population size N (defaults to the budget K)."""
        n = self.population if self.population is not None else self.n_ues
        assert n >= self.n_ues, (
            f"population {n} smaller than the bandwidth budget K="
            f"{self.n_ues}")
        return n

    @property
    def p_watt(self) -> float:
        """Uplink transmit power P_k in watts."""
        return dbm_to_watt(self.tx_power_dbm)

    @property
    def n0_watt_hz(self) -> float:
        """Noise power spectral density N0 in W/Hz."""
        return dbm_to_watt(self.noise_dbm_hz)


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) / 1000.0
