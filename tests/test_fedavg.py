"""FedAvg aggregation (Alg. 1 line 13) + the Pallas aggregation kernel path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federated.aggregation import fedavg, fedavg_stacked
from repro.kernels import ops
from repro.models.mlp import mlp_init


def _params(seed):
    return mlp_init(jax.random.PRNGKey(seed))


def test_single_client_identity():
    p = _params(0)
    out = fedavg([p], [123.0])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(p)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_weighted_mean():
    p0, p1 = _params(0), _params(1)
    out = fedavg([p0, p1], [3.0, 1.0])
    expect = jax.tree.map(lambda a, b: 0.75 * a + 0.25 * b, p0, p1)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_allclose(a, b, rtol=1e-5)


def test_stacked_matches_list():
    """Regression (tolerance-tight): the list and stacked forms share one
    normalisation (float64 on host) and one float32 combine path, so they
    agree exactly — the seed normalised in different dtypes and drifted by
    ~1e-8, which pure-rtol comparison amplified on near-zero params."""
    ps = [_params(i) for i in range(4)]
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    a = fedavg_stacked(stacked, w)
    b = fedavg(ps, [1, 2, 3, 4])
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_stacked_matches_list_uneven_weights():
    """Same check with weights whose normalisation is inexact in float32."""
    ps = [_params(i) for i in range(3)]
    w = [7.0, 11.0, 3.0]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    a = fedavg_stacked(stacked, jnp.asarray(w))
    b = fedavg(ps, w)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_stacked_kernel_path_matches_xla_path():
    """The Pallas flattened-kernel route of fedavg_stacked (interpret mode
    off-TPU) agrees with the XLA reduction route."""
    ps = [_params(i) for i in range(4)]
    w = jnp.asarray([2.0, 5.0, 1.0, 4.0])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    a = fedavg_stacked(stacked, w, kernel=True)
    b = fedavg_stacked(stacked, w, kernel=False)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-7)


def test_kernel_tree_aggregate_matches():
    ps = [_params(i) for i in range(3)]
    w = jnp.asarray([5.0, 1.0, 2.0])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    a = ops.weighted_aggregate_tree(stacked, w)
    b = fedavg_stacked(stacked, w)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)
