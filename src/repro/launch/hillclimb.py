"""Perf hillclimbing driver (§Perf): run named optimization variants of one
(arch x shape) pair, record hypothesis -> change -> before/after.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch deepseek-v3-671b --shape train_4k \
        --variants baseline,moe_disp --out results/perf.json

Variants (composable with '+', e.g. moe_disp+chunk128):
  baseline       the sweep configuration, unchanged
  moe_disp       pin the MoE dispatch buffer + hidden activations to the
                 expert sharding (all-to-all routing instead of replication)
  chunk128/chunk512/chunk64   SSD chunk-length override
  bf16_opt       momentum (bf16-friendly) instead of adamw — isolates
                 optimizer-state collectives
  no_zero        disable ZeRO sharding of optimizer moments (trades memory
                 for fewer per-step gathers)   [train shapes]
  seq_model      decode caches: sequence over model axis only
  remat_off      disable activation rematerialisation  [train shapes]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402

import numpy as np       # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get                # noqa: E402
from repro.launch.dryrun import lower_pair, print_rec  # noqa: E402
from repro.sharding.specs import data_axes   # noqa: E402


def _moe_disp_specs(mesh, cfg):
    if cfg.moe is None:
        return {}
    E = cfg.moe.n_routed
    total = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    dax = data_axes(mesh)
    if E % total == 0:
        e_ax = (*dax, "model")
        return {"moe_disp": P(e_ax, None, None),
                "moe_hidden": P(e_ax, None, None)}
    return {"moe_disp": P("model", None, None),
            "moe_hidden": P("model", None, dax if len(dax) > 1 else dax[0])}


def apply_variant(name: str, cfg, kwargs: dict):
    """Mutate (cfg, lower_pair kwargs) for one atomic variant."""
    if name == "baseline":
        return cfg
    if name == "moe_disp":
        prev = kwargs.get("extra_specs_fn")
        def fn(mesh, c, prev=prev):
            out = dict(prev(mesh, c) or {}) if prev else {}
            out.update(_moe_disp_specs(mesh, c))
            return out
        kwargs["extra_specs_fn"] = fn
        return cfg
    if name.startswith("chunk"):
        q = int(name[len("chunk"):])
        assert cfg.ssm is not None, "chunk variant needs an SSM config"
        return dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=q))
    if name == "ssd_bf16":
        assert cfg.ssm is not None
        return dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, compute_dtype="bfloat16"))
    if name.startswith("moe_local"):
        g = int(name[len("moe_local"):])
        assert cfg.moe is not None
        prev = kwargs.get("extra_specs_fn")
        def fn(mesh, c, prev=prev):
            out = dict(prev(mesh, c) or {}) if prev else {}
            E = c.moe.n_routed
            total = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
            dax = data_axes(mesh)
            e_ax = (*dax, "model") if E % total == 0 else "model"
            dx = dax if len(dax) > 1 else dax[0]
            out["moe_disp4a"] = P(dx, "model", None, None)
            out["moe_disp4"] = P(None, e_ax, None, None)
            out["moe_hidden4"] = P(None, e_ax, None, None)
            out["moe_out4"] = P(None, e_ax, None, None)
            out["moe_local"] = P(dx, None, "model")
            return out
        kwargs["extra_specs_fn"] = fn
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=g))
    if name == "bf16_opt":
        kwargs["optimizer_override"] = "momentum"
        return cfg
    if name == "f32_params":
        return dataclasses.replace(cfg, dtype="float32")
    if name == "pad_vocab":
        v = ((cfg.vocab_size + 255) // 256) * 256
        return dataclasses.replace(cfg, vocab_size=v)
    if name == "donate":
        os.environ["REPRO_DONATE"] = "1"
        return cfg
    if name == "remat_off":
        # TrainConfig remat is fixed inside make_train_step via dryrun's
        # TrainConfig(optimizer=...); emulate by optimizer override trick is
        # not enough — handled via env knob below.
        os.environ["REPRO_REMAT_OFF"] = "1"
        return cfg
    raise KeyError(f"unknown variant '{name}'")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for variant in args.variants.split(","):
        cfg = get(args.arch)
        kwargs: dict = {}
        for atom in variant.split("+"):
            cfg = apply_variant(atom, cfg, kwargs)
        rec = lower_pair(args.arch, args.shape, args.multi_pod,
                         extra_tags={"variant": variant},
                         cfg_override=cfg, **kwargs)
        print_rec(rec)
        results = [r for r in results
                   if (r["arch"], r["shape"], r.get("variant"), r["mesh"])
                   != (rec["arch"], rec["shape"], variant, rec["mesh"])]
        results.append(rec)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
