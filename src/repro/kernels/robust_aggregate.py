"""Pallas TPU robust aggregation for the defense plane (core/defenses.py):
coordinate-wise trimmed mean / median over N stacked client updates,
flattened to (N, M) — the same block layout as ``weighted_aggregate``.

Grid (n_m,) over the parameter dimension; each step loads an (N, block_m)
tile, pushes the padding rows (row >= n) to the top of a full in-register
odd-even transposition sort over the small stacked-client axis (N <= ~128
uploads — the compare-exchange network is statically unrolled, mirroring
the unrolled weight loop of ``weighted_aggregate``), then reduces the
selected rank window:

    trimmed_mean — mean of ranks [b, n-b)   (b values dropped per end)
    median       — midpoint of ranks (n-1)//2 and n//2

``n`` (real row count) and ``b`` (per-end trim count) ride in SMEM, so one
compiled kernel serves every cohort size at a fixed (N, M) padding. The
reduction is bandwidth-bound like FedAvg (reads N x M, writes M); the sort
adds O(N^2) VPU min/max per tile, which stays VMEM-resident at the default
block_m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _robust_kernel(nb_ref, x_ref, o_ref, *, n_rows, mode):
    n = nb_ref[0]
    b = nb_ref[1]
    x = x_ref[...].astype(jnp.float32)                    # (N, bm)
    row = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    x = jnp.where(row < n, x, jnp.inf)    # padding sorts past rank n-1

    # full odd-even transposition sort along the client axis: N passes of
    # statically unrolled compare-exchanges on (bm,) lanes
    for p in range(n_rows):
        for i in range(p % 2, n_rows - 1, 2):
            a, c = x[i], x[i + 1]
            x = x.at[i].set(jnp.minimum(a, c))
            x = x.at[i + 1].set(jnp.maximum(a, c))

    if mode == "trimmed_mean":
        keep = (row >= b) & (row < n - b)
        acc = jnp.sum(jnp.where(keep, x, 0.0), axis=0)
        o_ref[...] = (acc / jnp.maximum(n - 2 * b, 1)
                      .astype(jnp.float32)).astype(o_ref.dtype)
    else:   # median
        lo = jnp.sum(jnp.where(row == (n - 1) // 2, x, 0.0), axis=0)
        hi = jnp.sum(jnp.where(row == n // 2, x, 0.0), axis=0)
        o_ref[...] = ((lo + hi) * 0.5).astype(o_ref.dtype)


def robust_aggregate(stacked, n, *, trim=0, mode="trimmed_mean",
                     block_m=2048, interpret=False):
    """stacked (N, M) float, first ``n`` rows real -> (M,) robust reduce.

    trim — rows dropped per end (``mode="trimmed_mean"`` only; the caller
    computes it from its trim fraction so kernel and oracle agree on the
    integer rank window). ``n``/``trim`` ride in SMEM — one compiled
    kernel per (N, M, mode, block_m), NOT per cohort size.
    """
    assert mode in ("trimmed_mean", "median"), mode
    N = stacked.shape[0]
    assert 0 < n <= N and 0 <= 2 * trim < n, (n, N, trim)
    return _robust_call(stacked, jnp.asarray([n, trim], jnp.int32),
                        mode=mode, block_m=block_m, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("mode", "block_m",
                                             "interpret"))
def _robust_call(stacked, nb, *, mode, block_m, interpret):
    N, M = stacked.shape
    block_m = min(block_m, M)
    pad = (-M) % block_m
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    Mp = M + pad

    kernel = functools.partial(_robust_kernel, n_rows=N, mode=mode)
    out = pl.pallas_call(
        kernel,
        grid=(Mp // block_m,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((N, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Mp,), stacked.dtype),
        interpret=interpret,
    )(nb, stacked)
    return out[:M]
