"""Wall-clock per FEEL round: sequential per-client loop vs the vectorized
cohort engine (federated/cohort.py), at the paper's K=50 and beyond.

    PYTHONPATH=src python -m benchmarks.bench_round                # K=50,200,500
    PYTHONPATH=src python -m benchmarks.bench_round --ks 500 \
        --engines unbucketed vectorized         # single pad vs 3 size buckets
    PYTHONPATH=src python -m benchmarks.bench_round --sweep        # run_sweep
    PYTHONPATH=src python -m benchmarks.bench_round --control \
        --ks 50 500 2000                        # host vs batched control plane
    PYTHONPATH=src python -m benchmarks.bench_round --attacks      # threat plane
    PYTHONPATH=src python -m benchmarks.bench_round --llm          # LM task plane
    PYTHONPATH=src python -m benchmarks.bench_round --population   # N-scaling
    PYTHONPATH=src python -m benchmarks.bench_round --smoke        # CI gate

Methodology — each (engine, K) measurement runs the §V unit of work in a
FRESH subprocess (cold jit cache): ``--seeds`` independent experiments
(fresh partition each — the paper averages over independent runs) of
``--rounds`` rounds. This charges each engine what the protocol actually
charges it. The loop engine re-traces per *shape*: one ``mlp_sgd_epoch``
per distinct client dataset size and one eager evaluation program per
distinct per-UE test-subset size — and almost every shape is new again in
every fresh partition. The cohort engine compiles a handful of bucketed
(N, max_samples) programs that are shape-stable across seeds. The
per-round median (compiles mostly excluded) is reported alongside.

Engines: ``loop`` (sequential oracle), ``vectorized`` (size-bucketed
cohort engine, ``--buckets`` levels), ``unbucketed`` (vectorized with a
single global pad — the pre-bucketing baseline).

``--sweep`` instead measures a (policies x seeds) study end-to-end:
batched ``run_sweep`` vs the same grid as sequential ``run_experiment``
calls (each mode in a fresh subprocess).

``--control`` measures the control plane alone — the per-round schedule
phase (Eq. 2/3 values -> Eq. 9 costs -> policy selection) of a
``--control-runs``-run sweep, host numpy per run vs ONE batched
``core.control.schedule_runs`` call (steady state, jit warm) — at each
``--ks``, asserts the two planes pick identical UEs, and writes the rows
to ``results/BENCH_control.json`` (the control-plane perf trajectory).

``--attacks`` measures the threat-model plane: the masked batched
``_apply_attacks`` (one masked tree_map) vs the replaced
per-malicious-client ``.at[i].set`` dispatch loop at growing n_malicious
(bit-equality asserted; the masked path must be flat, the loop linear),
plus a 4-scenario heterogeneous ``run_sweep`` (label flip, feature noise,
free-rider, sign-flip) stacked vs sequential — written to
``results/BENCH_attacks.json``.

``--defenses`` measures the defense plane: every robust aggregator
(trimmed mean, median, norm clip, Krum) applied to a K-row stacked update
matrix, host compressed-numpy oracle vs the batched jnp twin, swept over
K and over n_malicious at K=64 (host/batched parity asserted per cell;
the batched path must be flat in n_malicious) — written to
``results/BENCH_defenses.json``.

``--llm`` measures the LM task plane: per-round cost of federated
``lm_tiny`` fine-tuning, loop vs vectorized cohort engine at K in {8, 16},
each engine with and without ``REPRO_USE_PALLAS=1`` (flash-attention
training forwards; interpret mode on CPU — path-exercise rows, not perf
claims). Loop/vectorized held-out loss is asserted bit-equal per cell —
written to ``results/BENCH_llm.json``.

``--population`` measures the population plane (DESIGN.md §12): the
per-round scheduling cost over N candidate UEs at N in {1e4, 1e5, 1e6} —
exact O(N log N) path vs the schedule-preserving top-M prefilter (both
kernel layouts; prefilter == exact selection asserted in every timed
cell) — plus the exact jax kernel re-benched with the population axis
sharded over a forced 2-device host mesh (the ``default_kernel``
multi-device crossover) — written to ``results/BENCH_population.json``.

``--smoke`` runs a tiny instance of every benchmark with loud assertions
(bucketed padding waste must not exceed the single-pad waste; curves must
be finite) — wired into tier-1 via tests/test_bench_smoke.py so bench
regressions fail loudly.

Every ``results/BENCH_*.json`` artifact goes through ONE writer
(``write_bench_json``) with a shared schema: ``{"bench": <name>, "meta":
{commit, python, jax, numpy, timestamp}, ...payload}``. Only canonical
grids overwrite the tracked artifacts — ad-hoc sizes print and skip.

CSV rows:

    engine,K,n_train,s_per_round,median_round_s,speedup,median_speedup,pad_waste
"""
import argparse
import datetime
import json
import os
import platform
import subprocess
import sys

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _bench_meta():
    """Environment/commit metadata stamped into every BENCH_* artifact."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(os.path.abspath(__file__))
        ).stdout.strip() or "unknown"
    except OSError:
        commit = "unknown"

    def ver(pkg):
        try:
            import importlib.metadata
            return importlib.metadata.version(pkg)
        except Exception:
            return "unknown"

    return {"commit": commit, "python": platform.python_version(),
            "jax": ver("jax"), "numpy": ver("numpy"),
            "timestamp": datetime.datetime.now(datetime.timezone.utc)
            .isoformat(timespec="seconds")}


def write_bench_json(name, payload, canonical=True, results_dir=None):
    """The ONE writer for results/BENCH_<name>.json.

    Shared schema: {"bench": ..., "meta": _bench_meta(), **payload}. A
    non-canonical run (ad-hoc --ks / sizes) must not clobber the tracked
    measurement, so it prints and skips instead.

    Every canonical write ALSO appends the record as one line to
    ``results/BENCH_history.jsonl`` — the commit+env-keyed trend log
    (the meta block carries commit, python/jax/numpy versions and a UTC
    timestamp), so re-running any bench on a new commit grows per-bench
    perf history instead of overwriting it. When the span tracer is on
    (REPRO_TRACE=1, DESIGN.md §14) the history line additionally carries
    the tracer's per-phase wall-time summary under ``"trace"`` — the
    per-phase trend rides the same log as the headline numbers.

    ``results_dir`` overrides the repo results/ directory (tests). The
    caller's ``payload`` dict is never mutated (tests/test_bench_writer.py
    regression: the old code popped "bench" out of the caller's dict).
    """
    if not canonical:
        print(f"# non-canonical sizes; results/BENCH_{name}.json left "
              "untouched", file=sys.stderr)
        return
    results = results_dir or os.path.join(os.path.dirname(__file__), "..",
                                          "results")
    path = os.path.join(results, f"BENCH_{name}.json")
    payload = dict(payload)
    record = {"bench": payload.pop("bench", name),
              "meta": _bench_meta(), **payload}
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    hist = record
    try:
        from repro.obs import trace
        if trace.enabled():
            phases = trace.phase_summary()
            if phases:
                hist = {**record, "trace": phases}
    except ImportError:
        pass
    with open(os.path.join(results, "BENCH_history.jsonl"), "a") as f:
        f.write(json.dumps(hist, separators=(",", ":")) + "\n")
    print(f"# wrote {os.path.normpath(path)} (+history)", file=sys.stderr)

_WORKER = r"""
import json, sys
import numpy as np
from repro.configs.base import FeelConfig
from repro.core.poisoning import EASY_PAIR, LabelFlipAttack, pick_malicious
from repro.data.partition import partition
from repro.data.synthetic_mnist import generate
from repro.federated.server import FeelServer
from repro.obs import trace

engine, k, n_train, n_test, rounds, seeds, n_buckets = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]), int(sys.argv[6]), int(sys.argv[7]))
cfg = FeelConfig(n_ues=k, n_malicious=max(k // 10, 1))
# the tracer IS the timer: per-round wall times are the "round" spans'
# durations (keeps the trace path honest under the parity matrix), and
# REPRO_TRACE_FILE (if set by the driver) flushes the full trace at exit
trace.configure(enabled=True)
wastes = []
for seed in range(seeds):
    train, test = generate(n_train, n_test, seed=seed)
    rng = np.random.default_rng(seed)
    malicious = pick_malicious(cfg.n_ues, cfg.n_malicious, rng)
    clients = partition(train, cfg.n_ues, rng, malicious,
                        LabelFlipAttack(*EASY_PAIR))
    server = FeelServer(cfg, clients, test, rng, policy="dqs",
                        engine=engine, n_buckets=n_buckets)
    for t in range(rounds):
        server.run_round(t)
    wastes.extend(server.pad_waste)
times = [sp.dur for sp in trace.tracer().spans if sp.name == "round"]
assert len(times) == rounds * seeds, (len(times), rounds, seeds)
print(json.dumps({"times": times, "waste": wastes,
                  "trace": trace.phase_summary()}))
"""

_SWEEP_WORKER = r"""
import json, sys, time
import numpy as np
from repro.federated.simulation import run_experiment, run_sweep

mode, n_seeds, n_train, n_test, rounds = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]))
policies = ["dqs", "top_value"]
seeds = list(range(n_seeds))
t0 = time.perf_counter()
if mode == "sweep":
    res = run_sweep(policies, seeds=seeds, n_train=n_train, n_test=n_test,
                    rounds=rounds)
    accs = [r["acc"] for r in res.runs]
else:
    accs = [run_experiment(p, (6, 2), seed=s, n_train=n_train,
                           n_test=n_test, rounds=rounds)["acc"]
            for p in policies for s in seeds]
el = time.perf_counter() - t0
assert all(np.isfinite(a).all() for a in map(np.asarray, accs))
print(json.dumps({"s_total": el, "n_runs": len(accs)}))
"""

_CONTROL_WORKER = r"""
import json, sys, time
import numpy as np
from repro.configs.base import FeelConfig
from repro.core import control as ctl
from repro.core.diversity import diversity_index
from repro.core.quality import data_quality_value
from repro.core.scheduler import (POLICIES, POLICY_IDS, Schedule,
                                  greedy_pack, top_value_schedule)
from repro.core.wireless import WirelessModel

k, n_runs, rounds = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
cfg = FeelConfig(n_ues=k, n_malicious=max(k // 10, 1))
rng = np.random.default_rng(0)
policies = [list(POLICY_IDS)[i % len(POLICY_IDS)] for i in range(n_runs)]
wms = [WirelessModel(cfg, np.random.default_rng(1000 + i))
       for i in range(n_runs)]
sizes = (rng.integers(1, 31, (n_runs, k)) * 50).astype(float)
cpu = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, (n_runs, k))
divs = rng.uniform(0.0, 0.9, (n_runs, k))
r_min = np.stack([wms[i].min_rate(wms[i].train_time(sizes[i], cpu[i]))
                  for i in range(n_runs)])
state = ctl.ControlState(
    policy_id=np.array([POLICY_IDS[p] for p in policies], np.int32),
    sizes=sizes, divs=divs, r_min=r_min,
    reputations=rng.uniform(0.0, 1.0, (n_runs, k)),
    ages=np.ones((n_runs, k)), cfg=cfg)
t_train = np.stack([wms[i].train_time(sizes[i], cpu[i])
                    for i in range(n_runs)])
omega = np.full(n_runs, cfg.omega_rep), np.full(n_runs, cfg.omega_div)

def draw(round_seed):
    g = np.stack([wms[i].rng.exponential(1.0, k) * wms[i].distances
                  ** (-cfg.pathloss_exp) for i in range(n_runs)])
    rr = np.stack([np.argsort(np.random.default_rng((round_seed, i))
                              .permutation(k)) for i in range(n_runs)])
    return g, rr

def host_round(gains, rr, cost_fn="cost"):
    xs = []
    for i, p in enumerate(policies):
        I = diversity_index(divs[i], sizes[i], state.ages[i], cfg.gamma)
        values = data_quality_value(state.reputations[i], I, cfg)
        costs = getattr(wms[i], cost_fn)(gains[i], t_train[i])
        if p == "top_value":
            s = top_value_schedule(values, costs, cfg, cfg.min_selected)
        elif p == "random":
            # consume the SAME shared permutation draw the batched plane
            # gets (rr is the inverse permutation): identical work +
            # decisions, so the parity gate covers all five policies
            x, alpha = greedy_pack(np.argsort(rr[i]), costs, k)
            s = Schedule(x=x, alpha=alpha, cost=costs, value=values)
        elif p == "best_channel":
            s = POLICIES[p](values, costs, cfg, gains[i])
        else:
            s = POLICIES[p](values, costs, cfg)
        x = s.x.copy()
        if not x.any():                       # forced-round rewrite
            x[np.argmax(values)] = True
        xs.append(x)
    return np.stack(xs)

def batched_round(gains, rr):
    x, *_ = ctl.schedule_runs(state, gains, rr, omega[0], omega[1])
    return x

# parity gate (all five policies) — doubles as the jit warmup. host_scan
# is the seed's control plane: per-run python + the dense (K, K) Eq. 9
# rate matrix (cost_scan); host is the post-bisection per-run oracle.
g0, rr0 = draw(0)
xh, xb = host_round(g0, rr0), batched_round(g0, rr0)
assert np.array_equal(xh, xb), "host/batched selection mismatch"
assert np.array_equal(xh, host_round(g0, rr0, "cost_scan")), "scan mismatch"

t_scan = t_host = t_batched = 0.0
scan_rounds = max(1, rounds // 3)           # O(K^2): keep its share small
for t in range(scan_rounds):
    g, rr = draw(t + 1)
    t0 = time.perf_counter(); host_round(g, rr, "cost_scan")
    t_scan += time.perf_counter() - t0
for t in range(rounds):
    g, rr = draw(t + 1)
    t0 = time.perf_counter(); host_round(g, rr)
    t1 = time.perf_counter(); batched_round(g, rr)
    t_host += t1 - t0; t_batched += time.perf_counter() - t1
print(json.dumps({"host_scan_ms": t_scan / scan_rounds * 1e3,
                  "host_ms": t_host / rounds * 1e3,
                  "batched_ms": t_batched / rounds * 1e3}))
"""

_ATTACKS_WORKER = r"""
import json, sys, time
import numpy as np, jax, jax.numpy as jnp

mode = sys.argv[1]
if mode == "apply":
    # masked batched _apply_attacks vs the per-client .at[i].set oracle:
    # the masked path must be O(1) in n_malicious; the oracle dispatches
    # one tree_map per malicious client. Bit-equality asserted per size.
    from repro.core import attacks as atk
    from repro.models.mlp import mlp_init

    n_rows, reps = int(sys.argv[2]), int(sys.argv[3])
    params = mlp_init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    leaves, treedef = jax.tree.flatten(params)
    stacked = jax.tree.unflatten(treedef, [
        jnp.asarray(rng.normal(size=(n_rows,) + l.shape)
                    .astype(np.float32)) for l in leaves])
    attack = atk.ModelAttack(scale=-1.0)

    def oracle(mal):
        out = stacked
        for i in np.flatnonzero(mal):
            poisoned = attack.apply_loop(
                params, jax.tree.map(lambda l, i=int(i): l[i], out))
            out = jax.tree.map(lambda l, p, i=int(i): l.at[i].set(p),
                               out, poisoned)
        return out

    def sync(t):
        jax.block_until_ready(jax.tree.leaves(t))
        return t

    rows = []
    for n_mal in sorted({1, 4, 16, n_rows // 2}):
        mal = np.zeros(n_rows, bool)
        mal[:n_mal] = True
        a = sync(attack.apply_stacked(stacked, params, mal))
        b = sync(oracle(mal))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                "masked/oracle attack application mismatch"
        for _ in range(3):                       # dispatch-cache warmup
            sync(attack.apply_stacked(stacked, params, mal))
        t0 = time.perf_counter()
        for _ in range(reps):
            sync(attack.apply_stacked(stacked, params, mal))
        t_masked = (time.perf_counter() - t0) / reps * 1e3
        t0 = time.perf_counter()
        for _ in range(reps):
            sync(oracle(mal))
        t_loop = (time.perf_counter() - t0) / reps * 1e3
        rows.append({"n_malicious": n_mal, "loop_ms": round(t_loop, 3),
                     "masked_ms": round(t_masked, 3)})
    print(json.dumps({"apply": rows}))
else:
    # heterogeneous scenario sweep: 4 distinct threat models, stacked in
    # ONE run_sweep vs sequential (fresh subprocess per mode, cold jit —
    # the same methodology as --sweep); accs returned for the parent's
    # cross-mode divergence assertion.
    from repro.federated.simulation import run_sweep

    n_train, rounds = int(sys.argv[2]), int(sys.argv[3])
    scns = ["flip_6to2", "noise_0.8", "free_rider", "sign_flip"]
    n_test = max(n_train // 10, 200)
    t0 = time.perf_counter()
    res = run_sweep(["dqs"], seeds=[0], scenarios=scns, n_train=n_train,
                    n_test=n_test, rounds=rounds,
                    stack_runs=(mode == "sweep_stacked"))
    el = time.perf_counter() - t0
    accs = [r["acc"] for r in res.runs]
    assert all(np.isfinite(a).all() for a in map(np.asarray, accs))
    print(json.dumps({"s_total": round(el, 2), "n_scenarios": len(scns),
                      "accs": accs}))
"""

_DEFENSES_WORKER = r"""
import json, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.core import defenses as dfs
from repro.models.mlp import mlp_init

k, reps = int(sys.argv[1]), int(sys.argv[2])
n_mals = [int(x) for x in sys.argv[3].split(",")]
rng = np.random.default_rng(0)
template = mlp_init(jax.random.PRNGKey(0))
leaves, treedef = jax.tree.flatten(template)
weights = (rng.integers(1, 31, k) * 50).astype(float)

def mk_updates(n_mal):
    # honest uploads cluster near the global model, malicious sit far out
    # (so Krum/clip actually have something to reject/clip)
    rows = []
    for i in range(k):
        s = 5.0 if i < n_mal else 0.1
        rows.append(jax.tree.unflatten(treedef, [
            (np.asarray(l) + s * rng.normal(size=l.shape))
            .astype(np.float32) for l in leaves]))
    return rows

AGGS = {"trimmed_mean": dfs.TrimmedMean(0.2), "median": dfs.Median(),
        "norm_clip": dfs.NormClip(1.0), "krum": dfs.Krum()}

def sync(t):
    jax.block_until_ready(jax.tree.leaves(t))
    return t

rows_out = []
for n_mal in n_mals:
    params_list = mk_updates(n_mal)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *params_list)
    sync(stacked)
    for name, agg in AGGS.items():
        # parity gate before timing: decisions exact, payload to 2e-6
        h, hs = dfs.aggregate_host(agg, params_list, weights, template,
                                   n_mal)
        b, bs = dfs.aggregate_stacked(agg, stacked, weights, template, k,
                                      n_mal)
        for x, y in zip(jax.tree.leaves(sync(h)), jax.tree.leaves(sync(b))):
            assert np.allclose(np.asarray(x), np.asarray(y), atol=2e-6), \
                f"host/batched {name} aggregate mismatch"
        assert (hs.n_clipped, hs.n_rejected) == (bs.n_clipped,
                                                 bs.n_rejected), name
        for _ in range(2):            # dispatch-cache warmup
            sync(dfs.aggregate_stacked(agg, stacked, weights, template,
                                       k, n_mal)[0])
        t0 = time.perf_counter()
        for _ in range(reps):
            sync(dfs.aggregate_stacked(agg, stacked, weights, template,
                                       k, n_mal)[0])
        t_b = (time.perf_counter() - t0) / reps * 1e3
        t0 = time.perf_counter()
        for _ in range(reps):
            sync(dfs.aggregate_host(agg, params_list, weights, template,
                                    n_mal)[0])
        t_h = (time.perf_counter() - t0) / reps * 1e3
        rows_out.append({"aggregator": name, "K": k, "n_malicious": n_mal,
                         "host_ms": round(t_h, 3),
                         "batched_ms": round(t_b, 3)})
print(json.dumps({"rows": rows_out}))
"""

_ASYNC_WORKER = r"""
import json, sys, time
import numpy as np
from repro.configs.base import FeelConfig
from repro.launch.serve import simulate

mode, scenario, k, n_train, n_test, rounds = (
    sys.argv[1], sys.argv[2], int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]), int(sys.argv[6]))
cfg = FeelConfig(n_ues=k, n_malicious=max(k // 8, 1),
                 min_selected=min(5, k))
kw = dict(cfg=cfg, scenario=scenario, rounds=rounds, n_train=n_train,
          n_test=n_test, seed=0)

# parity gate in EVERY timed cell: the zero-latency async engine must be
# bit-equal to the synchronous oracle (DESIGN.md S13) before the cell's
# timing is trusted
sync = simulate(mode="sync", **kw)
zero = simulate(mode="async", buffer=None, deadline=None,
                latency_scale=0.0, **kw)
for f in ("acc", "rep_gap", "objective"):
    a, b = np.asarray(sync[f], float), np.asarray(zero[f], float)
    assert np.array_equal(a, b, equal_nan=True), \
        f"zero-latency async != sync on {f}"

if mode == "sync":
    # the lockstep limit, but event-priced: full-wave triggers at real
    # Eq. 6/7 latencies give the synchronous baseline a sim-time axis
    spec = dict(buffer=None, deadline=None, latency_scale=1.0)
elif mode == "async_buffer":
    spec = dict(buffer=max(2, k // 8), deadline=None, latency_scale=1.0,
                staleness=0.5, channel_corr=0.3)
elif mode == "async_deadline":
    spec = dict(buffer=None, deadline=60.0, latency_scale=1.0,
                staleness=0.5, channel_corr=0.3)
else:
    raise KeyError(mode)
t0 = time.perf_counter()
res = simulate(mode="async", **spec, **kw)
wall = time.perf_counter() - t0
assert np.isfinite(np.asarray(res["acc"], float)).all()
st = np.asarray(res["sim_time"], float)
assert st.size == rounds and np.all(np.diff(st) >= 0), st
print(json.dumps({"acc": res["acc"], "sim_time": res["sim_time"],
                  "trigger": res["trigger"],
                  "n_uploads": res["n_uploads"],
                  "mean_age": res["mean_age"], "wall_s": wall,
                  "final_acc": res["acc"][-1]}))
"""


# engine CLI name -> (FeelServer engine, n_buckets override or None)
ENGINES = {"loop": ("loop", None),
           "vectorized": ("vectorized", None),
           "unbucketed": ("vectorized", 1)}

# argparse defaults of the default (engines) mode — ALSO the canonical
# grid that overwrites results/BENCH_engines.json, so the two can never
# drift apart (cf. CONTROL_KS / ATTACK_DEFAULTS / DEFENSE_KS)
ENGINE_DEFAULTS = {"ks": [50, 200, 500], "rounds": 3, "seeds": 3,
                   "engines": ["loop", "vectorized"], "buckets": 3}


def _run_worker(code, argv, timeout=3600, extra_env=None):
    r = subprocess.run(
        [sys.executable, "-c", code] + [str(a) for a in argv],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                             ""),
             **(extra_env or {})},
        timeout=timeout)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def _measure(name, k, n_train, n_test, rounds, seeds, buckets):
    engine, nb = ENGINES[name]
    out = _run_worker(_WORKER, [engine, k, n_train, n_test, rounds, seeds,
                                nb if nb is not None else buckets])
    times = out["times"]
    mean = sum(times) / len(times)
    median = sorted(times)[(len(times) - 1) // 2]   # lower-biased: keeps
    waste = (sum(out["waste"]) / len(out["waste"])  # compile rounds out
             if out["waste"] else float("nan"))
    return mean, median, times, waste


def _auto_n_train(k: int) -> int:
    # keep the partition pool >= the clients' demand so datasets stay
    # size-diverse (K=50 matches the paper's regime scaled to bench time);
    # cap at the paper's 50k corpus
    return min(50_000, max(10_000, 100 * k))


def bench_k(k, n_train, n_test, rounds, seeds, engines, buckets):
    nt = n_train or _auto_n_train(k)
    out = {}
    for name in engines:
        out[name] = _measure(name, k, nt, n_test, rounds, seeds, buckets)
        print(f"# {name} K={k} per-round s: "
              f"{[round(x, 2) for x in out[name][2]]}", file=sys.stderr)
    base = engines[0]
    cl, sl = out[base][:2]
    for name in engines:
        c, s, _, w = out[name]
        print(f"{name},{k},{nt},{c:.3f},{s:.3f},{cl / c:.2f},{sl / s:.2f},"
              f"{w:.2f}", flush=True)
    return out


SWEEP_DEFAULTS = (3, 10_000, 1_000, 3)    # n_seeds, n_train, n_test, rounds


def bench_sweep(n_seeds, n_train, n_test, rounds, write_json=True):
    """Batched run_sweep vs the same grid of sequential run_experiment
    calls — each mode cold, in a fresh subprocess."""
    print("mode,n_runs,s_total,speedup")
    res = {}
    for mode in ("sequential", "sweep"):
        res[mode] = _run_worker(_SWEEP_WORKER,
                                [mode, n_seeds, n_train, n_test, rounds])
    base = res["sequential"]["s_total"]
    for mode in ("sequential", "sweep"):
        r = res[mode]
        print(f"{mode},{r['n_runs']},{r['s_total']:.1f},"
              f"{base / r['s_total']:.2f}", flush=True)
    if write_json:
        write_bench_json(
            "sweep",
            {"bench": "batched_sweep_vs_sequential",
             "rows": [{"mode": m, "n_runs": res[m]["n_runs"],
                       "s_total": res[m]["s_total"]}
                      for m in ("sequential", "sweep")]},
            canonical=(n_seeds, n_train, n_test,
                       rounds) == SWEEP_DEFAULTS)
    return base / res["sweep"]["s_total"]


CONTROL_KS = (50, 500, 2000)      # the tracked BENCH_control.json grid


def bench_control(ks, n_runs, rounds, write_json=True):
    """Host vs batched control plane: per-round schedule phase of an
    ``n_runs``-run sweep at each K (fresh subprocess per K; the worker
    asserts selection parity across ALL five policies before timing).

    The JSON trajectory artifact is only (over)written for the canonical
    ``CONTROL_KS`` grid — an ad-hoc ``--ks 8`` sanity run must not clobber
    the tracked measurement."""
    print("control,K,n_runs,host_scan_ms,host_ms,batched_ms,"
          "speedup_vs_scan,speedup")
    rows = []
    for k in ks:
        out = _run_worker(_CONTROL_WORKER, [k, n_runs, rounds])
        speedup = out["host_ms"] / out["batched_ms"]
        vs_scan = out["host_scan_ms"] / out["batched_ms"]
        rows.append({"K": k, "n_runs": n_runs,
                     "host_scan_ms": round(out["host_scan_ms"], 3),
                     "host_ms": round(out["host_ms"], 3),
                     "batched_ms": round(out["batched_ms"], 3),
                     "speedup_vs_scan": round(vs_scan, 2),
                     "speedup": round(speedup, 2)})
        print(f"control,{k},{n_runs},{out['host_scan_ms']:.2f},"
              f"{out['host_ms']:.2f},{out['batched_ms']:.2f},"
              f"{vs_scan:.2f},{speedup:.2f}", flush=True)
    if write_json:
        write_bench_json("control",
                         {"bench": "control_plane_schedule_phase",
                          "unit": "ms_per_round_all_runs", "rows": rows},
                         canonical=tuple(ks) == CONTROL_KS)
    return rows


ATTACK_DEFAULTS = (64, 50, 4000, 3)   # n_rows, reps, n_train, rounds


def bench_attacks(n_rows=64, reps=50, n_train=4000, rounds=3,
                  write_json=True):
    """Threat-model plane bench: (1) the masked batched ``_apply_attacks``
    vs the replaced per-malicious-client ``.at[i].set`` dispatch loop at
    growing n_malicious (bit-equality asserted in the worker — the masked
    path must be flat in n_malicious, the loop linear), and (2) a
    4-scenario heterogeneous sweep, stacked vs sequential.

    The JSON artifact (results/BENCH_attacks.json) is only written for
    the canonical default sizes."""
    out = _run_worker(_ATTACKS_WORKER, ["apply", n_rows, reps])
    print("attacks,n_rows,n_malicious,loop_ms,masked_ms,speedup")
    for r in out["apply"]:
        print(f"attacks,{n_rows},{r['n_malicious']},{r['loop_ms']:.3f},"
              f"{r['masked_ms']:.3f},"
              f"{r['loop_ms'] / r['masked_ms']:.2f}", flush=True)
    res = {m: _run_worker(_ATTACKS_WORKER, [m, n_train, rounds])
           for m in ("sweep_stacked", "sweep_sequential")}
    for a, b in zip(res["sweep_stacked"]["accs"],
                    res["sweep_sequential"]["accs"]):
        assert np.allclose(a, b, atol=1e-7), \
            "stacked/sequential scenario-sweep divergence"
    sw = {"n_scenarios": res["sweep_stacked"]["n_scenarios"],
          "stacked_s": res["sweep_stacked"]["s_total"],
          "sequential_s": res["sweep_sequential"]["s_total"]}
    print("attacks_sweep,n_scenarios,stacked_s,sequential_s,speedup")
    print(f"attacks_sweep,{sw['n_scenarios']},{sw['stacked_s']:.2f},"
          f"{sw['sequential_s']:.2f},"
          f"{sw['sequential_s'] / sw['stacked_s']:.2f}", flush=True)
    out["sweep"] = sw
    if write_json:
        write_bench_json("attacks",
                         {"bench": "threat_model_plane",
                          "apply_unit": "ms_per_application",
                          "apply": out["apply"], "sweep": sw},
                         canonical=(n_rows, reps, n_train,
                                    rounds) == ATTACK_DEFAULTS)
    return out


DEFENSE_KS = (16, 64, 128)        # the tracked BENCH_defenses.json K grid
DEFENSE_NMALS = (1, 4, 16, 32)    # n_malicious sweep at K=64


def bench_defenses(ks=DEFENSE_KS, n_mals=DEFENSE_NMALS, reps=10,
                   write_json=True):
    """Defense plane: every robust aggregator applied to a K-row stacked
    update matrix — host compressed oracle vs the batched jnp twin
    (parity asserted in the worker before timing). Two sweeps: cost vs K
    (n_malicious = K/8) and cost vs n_malicious at K=64, where the
    batched path must stay flat (the acceptance claim of
    results/BENCH_defenses.json)."""
    print("defense,aggregator,K,n_malicious,host_ms,batched_ms,speedup")
    rows = []

    def run(k, mals):
        out = _run_worker(_DEFENSES_WORKER,
                          [k, reps, ",".join(map(str, mals))])
        for r in out["rows"]:
            rows.append(r)
            print(f"defense,{r['aggregator']},{r['K']},{r['n_malicious']},"
                  f"{r['host_ms']:.2f},{r['batched_ms']:.2f},"
                  f"{r['host_ms'] / r['batched_ms']:.2f}", flush=True)

    # the n_malicious sweep runs at K=64 when the grid has it (the
    # tracked flatness claim), else at the grid's largest K
    nmal_k = 64 if 64 in ks else max(ks)
    for k in ks:
        if k == nmal_k:
            run(k, sorted(set(int(m) for m in n_mals if m < k)
                          | {max(k // 8, 1)}))
        else:
            run(k, [max(k // 8, 1)])
    if write_json:
        write_bench_json(
            "defenses",
            {"bench": "defense_plane_robust_aggregation",
             "unit": "ms_per_aggregation", "rows": rows},
            canonical=(tuple(ks) == DEFENSE_KS
                       and tuple(n_mals) == DEFENSE_NMALS))
    return rows


ASYNC_DEFAULTS = (16, 8000, 800, 8)   # k, n_train, n_test, rounds


def bench_async(k=16, n_train=8000, n_test=800, rounds=8,
                scenarios=("none", "stale_rider_2"), write_json=True):
    """Async engine plane: accuracy vs SIMULATED wall-clock for the
    {sync, async-buffer, async-deadline} triggers crossed with threat
    scenarios (federated/async_engine.py, DESIGN.md S13). Every cell's
    worker first pins the zero-latency parity gate (mode="async" at
    latency_scale=0 bit-equal to mode="sync") and only then times the
    cell; the "sync" cell itself is the event-priced lockstep limit, so
    all three curves share one simulated-clock axis. The JSON artifact
    (results/BENCH_async.json) is only written for the canonical default
    sizes."""
    print("async,mode,scenario,rounds,sim_s,final_acc,mean_age,wall_s")
    cells = []
    for scn in scenarios:
        for mode in ("sync", "async_buffer", "async_deadline"):
            out = _run_worker(_ASYNC_WORKER,
                              [mode, scn, k, n_train, n_test, rounds])
            cells.append({"mode": mode, "scenario": scn, **out})
            print(f"async,{mode},{scn},{rounds},{out['sim_time'][-1]:.1f},"
                  f"{out['final_acc']:.4f},"
                  f"{float(np.mean(out['mean_age'])):.2f},"
                  f"{out['wall_s']:.1f}", flush=True)
    if write_json:
        write_bench_json(
            "async",
            {"bench": "async_engine_acc_vs_sim_time",
             "K": k, "n_train": n_train, "n_test": n_test,
             "rounds": rounds, "cells": cells},
            canonical=((k, n_train, n_test, rounds) == ASYNC_DEFAULTS
                       and tuple(scenarios) == ("none", "stale_rider_2")))
    return cells


_POPULATION_WORKER = r"""
import json, sys, time
import numpy as np
from repro.configs.base import FeelConfig
from repro.core import control as ctl
from repro.core import population as pop
from repro.core.scheduler import POLICY_IDS
from repro.core.wireless import WirelessModel

mode, n, k, n_runs, rounds = (sys.argv[1], int(sys.argv[2]),
                              int(sys.argv[3]), int(sys.argv[4]),
                              int(sys.argv[5]))
cfg = FeelConfig(n_ues=k, n_malicious=max(k // 10, 1), population=n)
rng = np.random.default_rng(0)
policies = [list(POLICY_IDS)[i % len(POLICY_IDS)] for i in range(n_runs)]
wm = WirelessModel(cfg, np.random.default_rng(1))
sizes = (rng.integers(1, 31, (n_runs, n)) * 50).astype(float)
cpu = rng.uniform(cfg.cpu_hz_min, cfg.cpu_hz_max, (n_runs, n))
state = ctl.ControlState(
    policy_id=np.array([POLICY_IDS[p] for p in policies], np.int32),
    sizes=sizes, divs=rng.uniform(0.0, 0.9, (n_runs, n)),
    r_min=np.stack([wm.min_rate(wm.train_time(sizes[i], cpu[i]))
                    for i in range(n_runs)]),
    reputations=rng.uniform(0.0, 1.0, (n_runs, n)),
    ages=np.ones((n_runs, n)), cfg=cfg)
omega = np.full(n_runs, cfg.omega_rep), np.full(n_runs, cfg.omega_div)

def draw(t):
    g = np.stack([wm.rng.exponential(1.0, n) * wm.distances
                  ** (-cfg.pathloss_exp) for _ in range(n_runs)])
    rr = np.stack([np.argsort(np.random.default_rng((t, i)).permutation(n))
                   for i in range(n_runs)])
    return g, rr

if mode == "mesh":
    # exact N-wide schedule_runs on the forced multi-device host mesh:
    # hybrid (host numpy, cannot shard) vs the jitted jax kernel with the
    # population axis GSPMD-sharded over the mesh data axes — the
    # measurement behind default_kernel()'s multi-device "jax" choice
    import jax
    from jax.experimental import enable_x64
    mesh = pop.population_mesh()
    n_dev = len(jax.devices())

    def jax_round(g, rr):
        with enable_x64():
            ops = pop.shard_population(
                mesh, state.reputations, state.ages, state.divs,
                state.sizes, state.r_min, g, rr)
            out = ctl._schedule_kernel(
                state.policy_id, *ops, omega[0], omega[1],
                np.asarray(cfg.gamma, float), cfg.bandwidth_hz,
                cfg.p_watt, cfg.n0_watt_hz, k=k, n_sel=cfg.min_selected)
            return np.asarray(out[0])

    g0, rr0 = draw(0)
    xh = ctl.schedule_runs(state, g0, rr0, *omega, kernel="hybrid")[0]
    assert np.array_equal(jax_round(g0, rr0), xh), "mesh/hybrid mismatch"
    t_h = t_j = 0.0
    for t in range(rounds):
        g, rr = draw(t + 1)
        t0 = time.perf_counter()
        xh = ctl.schedule_runs(state, g, rr, *omega, kernel="hybrid")[0]
        t1 = time.perf_counter()
        xj = jax_round(g, rr)
        t_j += time.perf_counter() - t1; t_h += t1 - t0
        assert np.array_equal(xh, xj), "mesh/hybrid selection mismatch"
    print(json.dumps({"devices": n_dev,
                      "hybrid_ms": t_h / rounds * 1e3,
                      "jax_ms": t_j / rounds * 1e3}))
else:
    # exact O(N) path vs the top-M prefilter (both layouts); prefilter ==
    # exact selection asserted in EVERY timed cell (the preservation
    # certificate + escalation guarantee, core/population.py)
    def exact(g, rr):
        return ctl.schedule_runs(state, g, rr, *omega, kernel="hybrid")

    def pre(g, rr, kern):
        return pop.prefilter_schedule_runs(state, g, rr, *omega,
                                           kernel=kern)

    g0, rr0 = draw(0)                     # warmup + parity gate
    x0 = exact(g0, rr0)[0]
    for kern in ("hybrid", "jax"):
        assert np.array_equal(pre(g0, rr0, kern)[0], x0), kern
    times = {"exact": 0.0, "hybrid": 0.0, "jax": 0.0}
    esc = {"hybrid": 0, "jax": 0}
    m = pop.default_m(cfg)
    for t in range(rounds):
        g, rr = draw(t + 1)
        t0 = time.perf_counter()
        xe = exact(g, rr)[0]
        times["exact"] += time.perf_counter() - t0
        for kern in ("hybrid", "jax"):
            t0 = time.perf_counter()
            xp, _, _, _, _, info = pre(g, rr, kern)
            times[kern] += time.perf_counter() - t0
            assert np.array_equal(xp, xe), (kern, t)
            esc[kern] += info["n_escalated"]
            m = info["m"]
    # selection-tail micro-bench: both paths share the irreducibly O(N)
    # feature math (diversity / quality / Eq. 9 bisection — every
    # scheduler must read the N-wide inputs once), so the SUB-linear
    # claim lives in the stage the prefilter actually shrinks: the
    # visit-order sort + budget pack, O(N log N + N) exact vs
    # O(N) argpartition + O(M log M + M) prefiltered. Timed here on
    # precomputed dqs keys/costs (key choice does not change sort cost).
    from jax.experimental import enable_x64
    from repro.core.diversity import diversity_index_rows
    from repro.core.quality import data_quality_value
    g, _ = draw(rounds + 1)
    I = diversity_index_rows(state.divs, state.sizes, state.ages,
                             cfg.gamma)
    values = data_quality_value(state.reputations, I, cfg,
                                omega=(omega[0][:, None],
                                       omega[1][:, None]))
    with enable_x64():
        costs = np.asarray(ctl._cost_kernel(
            g, state.r_min, cfg.bandwidth_hz, cfg.p_watt,
            cfg.n0_watt_hz, k=k)).astype(np.int32)
    keys = -(values / costs)
    rows_i = np.arange(n_runs)[:, None]
    order = np.argsort(keys, axis=-1, kind="stable")     # warm both pack
    np.asarray(ctl._pack_kernel(np.take_along_axis(costs, order, -1),
                                k=k))                    # shapes (jit)
    np.asarray(ctl._pack_kernel(costs[rows_i, pop._topm_prefix(keys, m)],
                                k=k))
    t_et = t_pt = 0.0
    for _ in range(rounds):
        t0 = time.perf_counter()
        order = np.argsort(keys, axis=-1, kind="stable")
        np.asarray(ctl._pack_kernel(np.take_along_axis(costs, order, -1),
                                    k=k))
        t1 = time.perf_counter()
        kept = pop._topm_prefix(keys, m)
        np.asarray(ctl._pack_kernel(costs[rows_i, kept], k=k))
        t_pt += time.perf_counter() - t1; t_et += t1 - t0
    bytes1 = pop.PopulationState.from_control(state).nbytes()
    print(json.dumps({
        "exact_ms": times["exact"] / rounds * 1e3,
        "prefilter_hybrid_ms": times["hybrid"] / rounds * 1e3,
        "prefilter_jax_ms": times["jax"] / rounds * 1e3,
        "exact_tail_ms": t_et / rounds * 1e3,
        "prefilter_tail_ms": t_pt / rounds * 1e3,
        "m": m, "escalated_per_round": (esc["hybrid"] + esc["jax"])
        / (2.0 * rounds), "state_bytes": bytes1}))
"""

_LLM_WORKER = r"""
import json, sys
import numpy as np
from repro.configs.base import FeelConfig
from repro.core.attacks import as_scenario
from repro.core.poisoning import pick_malicious
from repro.federated.server import FeelServer
from repro.federated.task import as_task
from repro.obs import trace

engine, k, n_train, n_test, rounds = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]))
task = as_task("lm_tiny")
cfg = FeelConfig(n_ues=k, n_malicious=max(k // 4, 1), task="lm_tiny")
scn = as_scenario("token_flip_1to5")
train, test = task.generate_data(n_train, n_test, 0)
rng = np.random.default_rng(0)
malicious = pick_malicious(k, cfg.n_malicious, rng)
clients = task.partition_clients(train, k, rng, malicious, scn.data)
server = FeelServer(cfg, clients, test, rng, policy="dqs", engine=engine,
                    scenario=scn)
trace.configure(enabled=True)   # per-round times = the "round" spans
losses = []
for t in range(rounds):
    log = server.run_round(t)
    losses.append(log.global_loss)
assert all(np.isfinite(l) for l in losses), losses
times = [sp.dur for sp in trace.tracer().spans if sp.name == "round"]
assert len(times) == rounds, (len(times), rounds)
print(json.dumps({"times": times, "loss": losses,
                  "trace": trace.phase_summary()}))
"""

LLM_KS = (8, 16)          # the tracked BENCH_llm.json K grid
LLM_DEFAULTS = (LLM_KS, 2)


def bench_llm(ks=LLM_KS, rounds=2, flash=True, write_json=True):
    """LM-task plane: per-round cost of federated lm_tiny fine-tuning,
    loop vs vectorized cohort engine at each K, each engine also under
    ``REPRO_USE_PALLAS=1`` (training forwards through the Pallas flash
    kernel). Loop/vectorized loss parity is asserted bitwise per (K,
    flash) cell — the LM engine-parity contract of tests/test_task_lm.py
    at bench scale. On CPU the flash rows run the kernel in interpret
    mode (~50x XLA), so they are path-exercise measurements, not perf
    claims, and run a single round."""
    print("llm,engine,K,flash,n_train,s_per_round,loss_r0")
    rows = []
    for k in ks:
        n_train, n_test = k * 60, 120
        for use_flash in ((False, True) if flash else (False,)):
            env = {"REPRO_USE_PALLAS": "1"} if use_flash else None
            r = 1 if use_flash else rounds
            out = {eng: _run_worker(_LLM_WORKER,
                                    [eng, k, n_train, n_test, r],
                                    extra_env=env)
                   for eng in ("loop", "vectorized")}
            assert np.array_equal(out["loop"]["loss"],
                                  out["vectorized"]["loss"]), \
                f"LM engine loss divergence at K={k} flash={use_flash}"
            for eng in ("loop", "vectorized"):
                mean = sum(out[eng]["times"]) / len(out[eng]["times"])
                rows.append({"engine": eng, "K": k, "flash": use_flash,
                             "n_train": n_train,
                             "s_per_round": round(mean, 3),
                             "loss_r0": round(out[eng]["loss"][0], 6)})
                print(f"llm,{eng},{k},{int(use_flash)},{n_train},"
                      f"{mean:.3f},{out[eng]['loss'][0]:.4f}", flush=True)
    if write_json:
        write_bench_json(
            "llm", {"bench": "lm_task_per_round", "rows": rows},
            canonical=(tuple(ks), rounds) == LLM_DEFAULTS and flash)
    return rows


POPULATION_NS = (10_000, 100_000, 1_000_000)   # tracked N grid
POPULATION_DEFAULTS = (POPULATION_NS, 64, 5, 3)    # ns, K, n_runs, rounds
POPULATION_MESH_DEVICES = 2


def bench_population(ns=POPULATION_NS, k=64, n_runs=5, rounds=3,
                     mesh_devices=POPULATION_MESH_DEVICES,
                     write_json=True):
    """Population plane (DESIGN.md §12): per-round scheduling cost over N
    candidate UEs — the exact O(N log N) path vs the schedule-preserving
    top-M prefilter (hybrid + jax layouts) — at each N (fresh subprocess
    per N, cold jit; the worker asserts prefilter == exact selection in
    EVERY timed cell). A second worker re-benches the exact
    ``schedule_runs`` on a forced ``mesh_devices``-device host mesh:
    hybrid (host numpy, unshardable) vs the jax kernel with the
    population axis GSPMD-sharded — the measurement behind
    ``default_kernel()`` choosing "jax" on any multi-device mesh.

    results/BENCH_population.json is only (over)written for the
    canonical grid, where the acceptance claims are asserted below:
    (a) the full prefilter round beats the exact path at EVERY N, and
    (b) the selection tail (visit-order sort + budget pack — the stage
    the prefilter shrinks from O(N log N + N) to O(N) + O(M log M + M))
    grows SUB-linearly in the exact path's cost over the N span: its
    share of the exact tail must shrink as N grows. Raw wall-clock of
    ANY O(N) DRAM-resident stage on this box grows slightly
    super-linearly once it falls out of cache, so sub-linearity is
    asserted against the exact path, not against raw N; and total round
    time cannot be sub-linear on either path — the Eq. 2/3/9 feature
    math reads every one of the N candidates once, an irreducibly
    linear floor both paths share."""
    print("population,N,K,n_runs,exact_ms,prefilter_hybrid_ms,"
          "prefilter_jax_ms,exact_tail_ms,prefilter_tail_ms,m,"
          "escalated_per_round,bytes_per_device")
    rows = []
    for n in ns:
        out = _run_worker(_POPULATION_WORKER,
                          ["paths", n, k, n_runs, rounds])
        bpd = out["state_bytes"] // mesh_devices
        rows.append({"N": n, "K": k, "n_runs": n_runs,
                     "exact_ms": round(out["exact_ms"], 3),
                     "prefilter_hybrid_ms":
                         round(out["prefilter_hybrid_ms"], 3),
                     "prefilter_jax_ms":
                         round(out["prefilter_jax_ms"], 3),
                     "exact_tail_ms": round(out["exact_tail_ms"], 3),
                     "prefilter_tail_ms":
                         round(out["prefilter_tail_ms"], 3),
                     "m": out["m"],
                     "escalated_per_round": out["escalated_per_round"],
                     "state_bytes": out["state_bytes"],
                     "bytes_per_device": bpd})
        r = rows[-1]
        print(f"population,{n},{k},{n_runs},{r['exact_ms']:.2f},"
              f"{r['prefilter_hybrid_ms']:.2f},"
              f"{r['prefilter_jax_ms']:.2f},{r['exact_tail_ms']:.2f},"
              f"{r['prefilter_tail_ms']:.2f},{r['m']},"
              f"{r['escalated_per_round']:.2f},{bpd}", flush=True)
    mesh_rows = []
    print("population_mesh,N,devices,hybrid_ms,jax_ms,speedup")
    for n in [n for n in ns if n <= 100_000]:
        out = _run_worker(
            _POPULATION_WORKER, ["mesh", n, k, n_runs, rounds],
            extra_env={"XLA_FLAGS": "--xla_force_host_platform_"
                                    f"device_count={mesh_devices}"})
        mesh_rows.append({"N": n, "devices": out["devices"],
                          "hybrid_ms": round(out["hybrid_ms"], 3),
                          "jax_ms": round(out["jax_ms"], 3)})
        print(f"population_mesh,{n},{out['devices']},"
              f"{out['hybrid_ms']:.2f},{out['jax_ms']:.2f},"
              f"{out['hybrid_ms'] / out['jax_ms']:.2f}", flush=True)
    canonical = (tuple(ns), k, n_runs, rounds) == POPULATION_DEFAULTS
    if canonical and len(rows) >= 2:
        # the acceptance claims: (a) the prefilter beats the exact path
        # in every cell, and (b) its selection tail (the stage the top-M
        # cut shrinks) grows sub-linearly in the exact path's cost over
        # the N span (shrinking share of the exact tail) — see the
        # docstring for why raw-N wall-clock ratios are not the claim
        for r in rows:
            assert r["prefilter_hybrid_ms"] < r["exact_ms"], r
            assert r["prefilter_tail_ms"] < r["exact_tail_ms"], r
        tail_pre = (rows[-1]["prefilter_tail_ms"]
                    / rows[0]["prefilter_tail_ms"])
        tail_exact = (rows[-1]["exact_tail_ms"]
                      / rows[0]["exact_tail_ms"])
        assert tail_pre < tail_exact, (tail_pre, tail_exact)
    if write_json:
        write_bench_json(
            "population",
            {"bench": "population_plane_schedule_scaling",
             "unit": "ms_per_round_all_runs", "rows": rows,
             "mesh": mesh_rows}, canonical=canonical)
    return rows, mesh_rows


def smoke():
    """Tiny end-to-end run of both benchmarks with loud assertions.

    K=40 is the smallest scale where size bucketing reliably beats the
    single global pad (below ~3x _N_BUCKET the cohort-axis padding of 2-3
    sub-cohorts outweighs the max_samples savings)."""
    out = bench_k(40, 4000, 300, 2, 1,
                  ["unbucketed", "vectorized"], buckets=3)
    w_un, w_b = out["unbucketed"][3], out["vectorized"][3]
    assert w_b <= w_un + 1e-9, (
        f"bucketed padding waste {w_b:.2f}x exceeds single-pad {w_un:.2f}x")
    assert all(t > 0 for name in out for t in out[name][2])
    speedup = bench_sweep(2, 3000, 300, 2, write_json=False)
    assert speedup > 0, speedup
    # control plane: the worker's internal parity assertion (host ==
    # batched selections for all five policies) is the actual gate
    ctl_rows = bench_control([50], n_runs=6, rounds=3, write_json=False)
    assert all(r["host_ms"] > 0 and r["batched_ms"] > 0 for r in ctl_rows)
    # threat-model plane: the worker asserts masked == per-client-loop
    # attack application bitwise and stacked == sequential scenario sweep
    atk_out = bench_attacks(n_rows=16, reps=3, n_train=2500, rounds=2,
                            write_json=False)
    assert all(r["masked_ms"] > 0 for r in atk_out["apply"])
    # defense plane: the worker asserts host == batched robust
    # aggregation (decisions exact, payload 2e-6) for every aggregator
    def_rows = bench_defenses(ks=[8], n_mals=[2], reps=2,
                              write_json=False)
    # 4 aggregators x the {requested 2, default k//8=1} n_malicious grid
    assert len(def_rows) == 8 and all(r["batched_ms"] > 0
                                      for r in def_rows)
    # LM task plane: the in-bench assertion (loop == vectorized loss,
    # bitwise) is the gate; flash rows stay out of smoke — the CPU
    # interpret-mode kernel is ~50x XLA and belongs to the manual --llm run
    llm_rows = bench_llm(ks=[4], rounds=1, flash=False, write_json=False)
    assert len(llm_rows) == 2 and all(r["s_per_round"] > 0
                                      for r in llm_rows)
    # population plane: the worker asserts prefilter == exact selection
    # in every timed cell (incl. the forced 2-device mesh row)
    pop_rows, pop_mesh = bench_population(ns=[2000], k=16, n_runs=5,
                                          rounds=1, write_json=False)
    assert (pop_rows[0]["exact_ms"] > 0
            and pop_rows[0]["prefilter_hybrid_ms"] > 0
            and pop_rows[0]["prefilter_jax_ms"] > 0
            and pop_rows[0]["prefilter_tail_ms"] > 0)
    assert pop_mesh and pop_mesh[0]["devices"] == 2, pop_mesh
    # async plane: every cell's worker runs the zero-latency parity gate
    # (async == sync bitwise) before timing — that assertion is the gate
    async_cells = bench_async(k=8, n_train=2000, n_test=300, rounds=2,
                              scenarios=("stale_rider_2",),
                              write_json=False)
    assert len(async_cells) == 3 and all(
        np.isfinite(c["final_acc"]) for c in async_cells), async_cells
    # observability plane (DESIGN.md §14): a traced engines cell — the
    # worker hands its trace back through REPRO_TRACE_FILE and the report
    # must see schedule/train phase timings plus roofline context for both
    import tempfile
    from repro.obs import report as obs_report
    with tempfile.TemporaryDirectory() as td:
        tpath = os.path.join(td, "trace.jsonl")
        _run_worker(_WORKER, ["vectorized", 8, 1200, 200, 2, 1, 3],
                    extra_env={"REPRO_TRACE": "1",
                               "REPRO_TRACE_FILE": tpath})
        rep = obs_report.summarize(tpath)
    for ph in ("round", "schedule", "train", "eval"):
        assert ph in rep["phases"], (ph, sorted(rep["phases"]))
    for ph in ("schedule", "train"):
        assert ph in rep["roofline"], (ph, sorted(rep["roofline"]))
    n_spans = int(sum(p["count"] for p in rep["phases"].values()))
    print(f"trace,{n_spans},{len(rep['phases'])},"
          f"{rep['phases']['round']['total_s']:.3f},"
          f"{len(rep['compile_offenders'])}", flush=True)
    print(f"# smoke OK: waste {w_un:.2f}x -> {w_b:.2f}x, "
          f"sweep speedup {speedup:.2f}x, "
          f"control speedup {ctl_rows[0]['speedup']:.2f}x, "
          f"attack apply masked {atk_out['apply'][-1]['masked_ms']:.2f}ms "
          f"vs loop {atk_out['apply'][-1]['loop_ms']:.2f}ms, "
          f"defense agg host {def_rows[0]['host_ms']:.2f}ms "
          f"vs batched {def_rows[0]['batched_ms']:.2f}ms",
          file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", type=int, nargs="+",
                    default=ENGINE_DEFAULTS["ks"])
    ap.add_argument("--rounds", type=int,
                    default=ENGINE_DEFAULTS["rounds"])
    ap.add_argument("--seeds", type=int, default=ENGINE_DEFAULTS["seeds"],
                    help="independent fresh-partition runs per measurement")
    ap.add_argument("--n-train", type=int, default=None,
                    help="override the per-K automatic corpus size")
    ap.add_argument("--n-test", type=int, default=1_000)
    ap.add_argument("--engines", nargs="+",
                    default=ENGINE_DEFAULTS["engines"],
                    choices=sorted(ENGINES),
                    help="speedup columns are relative to the first")
    ap.add_argument("--buckets", type=int,
                    default=ENGINE_DEFAULTS["buckets"],
                    help="size-bucket count for the 'vectorized' engine "
                         "(the 'unbucketed' engine pins 1)")
    ap.add_argument("--sweep", action="store_true",
                    help="benchmark run_sweep vs sequential run_experiment "
                         "(uses --seeds as the seed count)")
    ap.add_argument("--control", action="store_true",
                    help="benchmark the control plane: host vs batched "
                         "schedule phase at each --ks; writes "
                         "results/BENCH_control.json")
    ap.add_argument("--control-runs", type=int, default=12,
                    help="number of stacked runs for --control (a 'sweep' "
                         "of ~ policies x seeds)")
    ap.add_argument("--attacks", action="store_true",
                    help="benchmark the threat-model plane: masked batched "
                         "attack application vs the per-malicious-client "
                         "dispatch loop, plus a 4-scenario heterogeneous "
                         "sweep; writes results/BENCH_attacks.json")
    ap.add_argument("--defenses", action="store_true",
                    help="benchmark the defense plane: robust aggregators "
                         "host vs batched, vs K and vs n_malicious at "
                         "K=64; writes results/BENCH_defenses.json")
    ap.add_argument("--llm", action="store_true",
                    help="benchmark the LM task plane: lm_tiny per-round "
                         "cost, loop vs vectorized engine, flash on/off; "
                         "writes results/BENCH_llm.json")
    ap.add_argument("--population", action="store_true",
                    help="benchmark the population plane: exact O(N) "
                         "schedule vs the top-M prefilter at N in "
                         "{1e4,1e5,1e6} plus the sharded-mesh jax "
                         "re-bench; writes results/BENCH_population.json")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="benchmark the async event engine: accuracy vs "
                         "simulated wall-clock for {sync, async-buffer, "
                         "async-deadline} x scenarios with a zero-latency "
                         "parity gate per cell; writes "
                         "results/BENCH_async.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny asserted run of every benchmark (CI gate)")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return
    if args.async_:
        bench_async(*ASYNC_DEFAULTS)
        return
    if args.population:
        bench_population()
        return
    if args.llm:
        bench_llm()
        return
    if args.defenses:
        bench_defenses()
        return
    if args.attacks:
        bench_attacks(*ATTACK_DEFAULTS)
        return
    if args.control:
        bench_control(args.ks, args.control_runs, max(args.rounds, 3))
        return
    if args.sweep:
        bench_sweep(args.seeds, args.n_train or 10_000, args.n_test,
                    args.rounds)
        return

    print("engine,K,n_train,s_per_round,median_round_s,"
          "speedup,median_speedup,pad_waste")
    rows_json = []
    for k in args.ks:
        out = bench_k(k, args.n_train, args.n_test, args.rounds,
                      args.seeds, args.engines, args.buckets)
        for name in args.engines:
            mean, med, _, waste = out[name]
            rows_json.append({"engine": name, "K": k,
                              "s_per_round": round(mean, 3),
                              "median_round_s": round(med, 3),
                              "pad_waste": round(waste, 3)
                              if np.isfinite(waste) else None})
        base, last = args.engines[0], args.engines[-1]
        if base != last:
            print(f"# K={k}: {last} per-round speedup over {base} "
                  f"{out[base][0] / out[last][0]:.2f}x", file=sys.stderr)
    write_bench_json(
        "engines", {"bench": "cohort_engine_per_round", "rows": rows_json},
        canonical=(args.n_train is None
                   and all(getattr(args, k) == v
                           for k, v in ENGINE_DEFAULTS.items())))


if __name__ == "__main__":
    main()
