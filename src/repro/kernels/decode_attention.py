"""Pallas TPU flash-decode: single-query attention over a long KV cache.

One new token attends to a cache of T positions (validity bounded by
``length``). Grid (B, H, n_kv) with the cache dimension innermost; the online
softmax state lives in VMEM scratch. The cache is laid out (B, T, H, D) — the
same layout the serving cache uses — and tiled (block_k, D) per step, so HBM
reads are contiguous along the cache. This is the decode-side hot spot of
decode_32k / long_500k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale, block_k, n_kv):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (1, D)
    k = k_ref[0, :, 0].astype(jnp.float32)                # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, :, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l)[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, length, *, block_k=256, interpret=False):
    """q (B,H,D); k/v (B,T,H,D); attend to cache positions < length.
    Returns (B,H,D)."""
    B, H, D = q.shape
    T = k.shape[1]
    block_k = min(block_k, T)
    assert T % block_k == 0
    n_kv = T // block_k
    scale = D ** -0.5
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_kv=n_kv)
    q4 = q[:, :, None, :]                                  # (B,H,1,D)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(length, q4, k, v)
    return out
