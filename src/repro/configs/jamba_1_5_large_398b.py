"""jamba-1.5-large-398b — hybrid Mamba+attention MoE [arXiv:2403.19887].

72L = 9 super-blocks x 8 layers; 1 attention layer per 8 (1:7 interleave,
attention at in-block offset 4); MoE MLP every 2nd layer, 16 experts top-2;
d_model 8192, 64H GQA kv=8, d_ff 24576, vocab 65536.

TPU adaptation note (DESIGN.md §3): the Mamba layers use the Mamba2/SSD
chunked formulation (MXU-friendly matmul chunks) rather than Mamba-1's
hardware-aware CUDA selective scan — same recurrence family, TPU-native
schedule."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    moe=MoEConfig(n_routed=16, top_k=2, d_ff_expert=24_576),
    moe_layer_period=2,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=8, chunk=256),
    attn_layer_period=8,
    attn_layer_offset=4,
    citation="[arXiv:2403.19887]",
)
