from repro.data.partition import (ClientData, GROUP_SIZE, label_histogram,
                                  pad_clients, partition)
from repro.data.synthetic_mnist import Dataset, N_CLASSES, generate
from repro.data.tokens import (TokenDataset, batches, make_stream,
                               make_windows, zipf_probs)

__all__ = ["ClientData", "GROUP_SIZE", "label_histogram", "pad_clients",
           "partition", "Dataset", "N_CLASSES", "generate", "TokenDataset",
           "batches", "make_stream", "make_windows", "zipf_probs"]
