"""Vectorized cohort execution engine (Alg. 1, all scheduled UEs at once).

The paper trains every scheduled UE independently per round; the seed
implemented that as a sequential Python loop (`FeelServer.run_round` ->
`local_train`) that re-traced `mlp_sgd_epoch` for every distinct client
dataset size. Here the round's cohort is stacked into (N, max_samples, ...)
arrays (see ``data.partition.pad_clients`` for the padding/masking
contract) and all N local trainings run in ONE jitted, vmapped program:

    cohort_train — vmap of (masked epochs + masked local accuracy) over the
        leading client axis; global params are broadcast in, per-client
        trained params come back stacked on axis 0, ready for
        ``fedavg_stacked`` / the Pallas ``weighted_aggregate`` kernel.
    cohort_eval  — one vmapped pass scoring every uploaded model on the
        (per-UE masked) public test set, replacing the server's per-model
        evaluation loop (Alg. 1 line 14).

Shapes are cohort-size dependent, so each distinct (N, max_samples) pair
compiles once and is cached for all later rounds; padding max_samples to a
round-stable value keeps the number of distinct shapes small.

Size-bucketed sub-cohorts: padding every client to the *global* maximum
wastes ~2x the real sample count under the paper's 1-30 group allocation,
so the server splits a round's cohort into 2-3 ``max_samples`` buckets
(``data.partition.bucket_levels`` — quantized so compiles stay cached),
trains each bucket with ``cohort_train``, and merges the per-bucket stacks
back into selection order (``merge_stacks``) for ONE ``fedavg_stacked``
call whose weights span all buckets. ``cohort_train_multi`` is the
multi-run variant (per-row parameters) used by the batched sweep runner in
``federated/simulation.py`` — seeds/policies become one more slice of the
client axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.mlp import (mlp_accuracy_masked, mlp_apply,
                              mlp_sgd_epoch_masked)


@partial(jax.jit, static_argnames=("epochs", "batch_size"))
def cohort_train(params, x, y, mask, lr, epochs: int, batch_size: int = 50):
    """Train the whole cohort in one vmapped step.

    params — global model (broadcast to every client);
    x (N, S, D), y (N, S), mask (N, S) — the padded, stacked cohort.
    Returns (stacked_params with leaves (N, ...), acc_local (N,)) where
    acc_local is each client's self-reported accuracy on its own (valid)
    samples after local training (Alg. 1 line 11).
    """
    def one(xi, yi, mi):
        # fori_loop (not Python unrolling) keeps the traced epoch body
        # single-copy — compile time is the cohort engine's main fixed cost
        p = jax.lax.fori_loop(
            0, epochs,
            lambda _, q: mlp_sgd_epoch_masked(q, xi, yi, mi, lr, batch_size),
            params)
        return p, mlp_accuracy_masked(p, xi, yi, mi)

    return jax.vmap(one)(x, y, mask)


@partial(jax.jit, static_argnames=("epochs", "batch_size"))
def cohort_train_multi(stacked_params, x, y, mask, lr, epochs: int,
                       batch_size: int = 50):
    """``cohort_train`` with *per-client* parameters (leaves (N, ...)).

    The batched sweep runner's entry point: rows gathered from different
    runs (policy x seed x attack-pair) carry different global models, so the
    run axis folds into the client vmap axis — one compiled program trains
    an arbitrary mix of runs as long as the padded (N, S) shape matches.
    Row results are independent, so a row trains identically whether its
    run's cohort is stacked alone or with other runs.
    """
    def one(p, xi, yi, mi):
        q = jax.lax.fori_loop(
            0, epochs,
            lambda _, r: mlp_sgd_epoch_masked(r, xi, yi, mi, lr, batch_size),
            p)
        return q, mlp_accuracy_masked(q, xi, yi, mi)

    return jax.vmap(one)(stacked_params, x, y, mask)


def pad_count(n: int, multiple: int = 8) -> int:
    """Cohort-axis padding target: next power of two below ``multiple``
    (1, 2, 4), multiples of ``multiple`` above. Keeps the set of compiled
    cohort shapes small WITHOUT ballooning small sub-cohorts — padding a
    2-row bucket to 8 rows would quadruple its training work, which at
    small K costs more than size-bucketing saves."""
    assert n >= 1
    if n >= multiple:
        return -(-n // multiple) * multiple
    p = 1
    while p < n:
        p *= 2
    return p


def merge_stacks(stacked_list, order=None):
    """Concatenate per-bucket stacked pytrees on axis 0; ``order`` (optional
    int array) then permutes rows — the bucketed engine uses it to restore
    the schedule's selection order so FedAvg accumulates in exactly the
    order the loop oracle uses (bit-for-bit parity)."""
    merged = (stacked_list[0] if len(stacked_list) == 1 else
              jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0),
                           *stacked_list))
    if order is not None:
        idx = jnp.asarray(order)
        merged = jax.tree.map(lambda l: jnp.take(l, idx, axis=0), merged)
    return merged


def pad_stacked(stacked, n_total: int):
    """Zero-pad a stacked pytree's leading axis to ``n_total`` rows.

    Null rows get weight 0 in ``fedavg_stacked`` (exact +0.0 contribution)
    and an all-zero eval mask (score 0.0, discarded), so padding the cohort
    axis to a stable multiple keeps compiled eval/aggregate programs
    cache-hot without perturbing results.
    """
    def pad(l):
        n = l.shape[0]
        if n == n_total:
            return l
        return jnp.concatenate(
            [l, jnp.zeros((n_total - n,) + l.shape[1:], l.dtype)], axis=0)
    return jax.tree.map(pad, stacked)


def broadcast_params(params, n: int):
    """Tile a single parameter pytree to (n, ...) rows (sweep stacking)."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p, (n,) + p.shape),
                        params)


@jax.jit
def cohort_eval(stacked_params, x, y, masks):
    """Score every uploaded model on the public test set in one vmap.

    stacked_params — leaves (N, ...); x (T, D), y (T,) — the full test set;
    masks (N, T) — per-UE evaluation masks (the server restricts Eq. 1's
    acc_test to the classes a UE claims to hold). Returns (N,) accuracies,
    0.0 where a mask is empty.
    """
    def one(p, m):
        correct = (jnp.argmax(mlp_apply(p, x), -1) == y).astype(jnp.float32)
        return jnp.sum(correct * m) / jnp.maximum(jnp.sum(m), 1.0)

    return jax.vmap(one)(stacked_params, masks)


@jax.jit
def cohort_eval_rows(stacked_params, x, y_rows, masks):
    """``cohort_eval`` with per-row labels: y_rows (N, T).

    The sweep's metric phase uses it to score the attack success rate —
    a row whose labels are relabelled to the attack's target class over
    the source-class mask — alongside the plain accuracy rows, in the
    same vmapped call.
    """
    def one(p, yr, m):
        correct = (jnp.argmax(mlp_apply(p, x), -1) == yr).astype(jnp.float32)
        return jnp.sum(correct * m) / jnp.maximum(jnp.sum(m), 1.0)

    return jax.vmap(one)(stacked_params, y_rows, masks)


def unstack(stacked_params, i: int):
    """Extract client ``i``'s parameter pytree from the stacked cohort."""
    return jax.tree.map(lambda l: l[i], stacked_params)
