"""The repo's only sanctioned wall-clock site (DESIGN.md §14).

Everything under ``src/repro`` that wants real time goes through
``wall_clock()`` — the ``repro.check`` nondeterminism lint rejects
direct ``time.time`` / ``time.perf_counter`` / ``time.monotonic``
calls anywhere else in the package.  The clock is monotonic: telemetry
measures durations, never calendar time, so suspend/NTP steps cannot
produce negative spans.

``utc_stamp()`` exists for sink *metadata only* (trace files are keyed
commit+env+timestamp the way ``BENCH_history.jsonl`` lines are); it
must never feed a traced value or a simulation input.
"""
from __future__ import annotations

import datetime
import time


def wall_clock() -> float:
    """Monotonic wall-clock seconds (arbitrary epoch, durations only)."""
    return time.monotonic()


def utc_stamp() -> str:
    """ISO-8601 UTC timestamp for sink metadata records."""
    return datetime.datetime.now(datetime.timezone.utc).isoformat()
