"""Transformer super-blocks: init / apply for the repeating layer pattern of
each architecture, plus the scan-over-blocks drivers.

A *super-block* is ``cfg.block_len`` consecutive layers (1 for homogeneous
stacks; 8 for Jamba's [7 x mamba + 1 x attn] interleave; 2 when MoE alternates
with dense MLPs). Parameters and decode caches are stacked over
``cfg.n_blocks`` and driven by ``lax.scan`` so compiled HLO stays proportional
to one super-block.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import ssm as ssm_mod
from repro.models import moe as moe_mod
from repro.models.common import dtype_of, ones, rms_norm, swiglu_apply, swiglu_init
from repro.sharding.ctx import constrain


# ---------------------------------------------------------------------- #
# Init
# ---------------------------------------------------------------------- #
def layer_init(key, cfg, kind: dict, cross_attention: bool = False):
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    d = cfg.d_model
    p: dict = {"norm1": ones((d,), dt)}
    if kind["mixer"] == "attn":
        p["mixer"] = (mla_mod.mla_init(ks[0], cfg) if cfg.mla is not None
                      else attn.attn_init(ks[0], cfg))
    else:
        p["mixer"] = ssm_mod.ssm_init(ks[0], cfg)
    if cross_attention:
        p["norm_x"] = ones((d,), dt)
        p["cross"] = attn.attn_init(ks[3], cfg)
    if kind["mlp"] != "none":
        p["norm2"] = ones((d,), dt)
        p["mlp"] = (moe_mod.moe_init(ks[1], cfg) if kind["mlp"] == "moe"
                    else swiglu_init(ks[1], d, cfg.d_ff, dt))
    return p


def block_init(key, cfg, cross_attention: bool = False):
    pat = cfg.block_pattern()
    ks = jax.random.split(key, len(pat))
    return {"layers": tuple(layer_init(k, cfg, kind, cross_attention)
                            for k, kind in zip(ks, pat))}


def stacked_blocks_init(key, cfg, n_blocks: Optional[int] = None,
                        cross_attention: bool = False):
    n = n_blocks if n_blocks is not None else cfg.n_blocks
    ks = jax.random.split(key, n)
    blocks = [block_init(k, cfg, cross_attention) for k in ks]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


# ---------------------------------------------------------------------- #
# Cache init (decode)
# ---------------------------------------------------------------------- #
def layer_cache_init(cfg, kind: dict, batch: int, cache_len: int,
                     cross_len: int = 0):
    dt = dtype_of(cfg)
    c: dict = {}
    if kind["mixer"] == "attn":
        if cfg.mla is not None:
            m = cfg.mla
            c["ckv"] = jnp.zeros((batch, cache_len, m.kv_lora_rank), dt)
            c["kr"] = jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dt)
        else:
            hd = cfg.head_dim
            c["k"] = jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt)
            c["v"] = jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt)
        if cross_len:
            hd = cfg.head_dim
            c["xk"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), dt)
            c["xv"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), dt)
    else:
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        ch = d_in + 2 * s.n_groups * s.d_state
        c["conv"] = jnp.zeros((batch, s.conv_kernel - 1, ch), dt)
        c["state"] = jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32)
    return c


def block_cache_init(cfg, batch: int, cache_len: int, cross_len: int = 0):
    return {"layers": tuple(layer_cache_init(cfg, kind, batch, cache_len,
                                             cross_len)
                            for kind in cfg.block_pattern())}


def stacked_cache_init(cfg, batch: int, cache_len: int, n_blocks=None,
                       cross_len: int = 0):
    n = n_blocks if n_blocks is not None else cfg.n_blocks
    one = block_cache_init(cfg, batch, cache_len, cross_len)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one)


# ---------------------------------------------------------------------- #
# Apply: full-sequence (train / prefill)
# ---------------------------------------------------------------------- #
def layer_apply(cfg, p, kind, h, *, window=None, enc_out=None,
                return_cache=False):
    """Pre-norm layer. Returns (h, aux, cache)."""
    aux = 0.0
    cache: dict = {}
    hin = rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind["mixer"] == "attn":
        if cfg.mla is not None:
            y, (ckv, kr) = mla_mod.mla_apply(cfg, p["mixer"], hin, window=window)
            if return_cache:
                cache.update(ckv=ckv, kr=kr)
        else:
            y, (k, v) = attn.attn_apply(cfg, p["mixer"], hin, window=window)
            if return_cache:
                cache.update(k=k, v=v)
    else:
        y, (conv_state, state) = ssm_mod.ssm_apply(cfg, p["mixer"], hin)
        if return_cache:
            cache.update(conv=conv_state, state=state)
    h = h + y
    if enc_out is not None and "cross" in p:
        hx = rms_norm(h, p["norm_x"], cfg.norm_eps)
        xkv = attn.encoder_kv(cfg, p["cross"], enc_out)
        h = h + attn.cross_attn_apply(cfg, p["cross"], hx, xkv)
        if return_cache and kind["mixer"] == "attn":
            cache.update(xk=xkv[0], xv=xkv[1])
    if kind["mlp"] != "none":
        h2 = rms_norm(h, p["norm2"], cfg.norm_eps)
        if kind["mlp"] == "moe":
            y2, aux = moe_mod.moe_apply(cfg, p["mlp"], h2)
        else:
            y2 = swiglu_apply(p["mlp"], h2)
        h = h + y2
    return constrain(h, "act"), aux, cache


def block_apply(cfg, bp, h, *, window=None, enc_out=None, return_cache=False):
    aux_total = 0.0
    caches = []
    for p, kind in zip(bp["layers"], cfg.block_pattern()):
        h, aux, cache = layer_apply(cfg, p, kind, h, window=window,
                                    enc_out=enc_out, return_cache=return_cache)
        aux_total += aux
        caches.append(cache)
    return h, aux_total, {"layers": tuple(caches)}


def scan_blocks(cfg, stacked, h, *, window=None, enc_out=None,
                return_cache=False, remat=False):
    """Scan full-sequence blocks. Returns (h, aux, stacked_cache|None)."""
    def body(carry, bp):
        h, aux = carry
        h2, a, cache = block_apply(cfg, bp, h, window=window, enc_out=enc_out,
                                   return_cache=return_cache)
        return (h2, aux + a), (cache if return_cache else 0.0)

    if remat:
        body = jax.checkpoint(body)
    (h, aux), caches = jax.lax.scan(body, (h, 0.0), stacked,
                                    unroll=cfg.scan_unroll)
    return h, aux, (caches if return_cache else None)


# ---------------------------------------------------------------------- #
# Apply: single-token decode
# ---------------------------------------------------------------------- #
def layer_decode(cfg, p, kind, h, cache, index, *, slot_pos=None,
                 window=None):
    new_cache = dict(cache)
    hin = rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind["mixer"] == "attn":
        if cfg.mla is not None:
            y, ckv, kr, _ = mla_mod.mla_decode(cfg, p["mixer"], hin,
                                               cache["ckv"], cache["kr"],
                                               index, slot_pos=slot_pos,
                                               window=window)
            new_cache.update(ckv=ckv, kr=kr)
        else:
            y, k, v, _ = attn.attn_decode(cfg, p["mixer"], hin,
                                          cache["k"], cache["v"], index,
                                          slot_pos=slot_pos, window=window)
            new_cache.update(k=k, v=v)
    else:
        y, conv, state = ssm_mod.ssm_decode(cfg, p["mixer"], hin,
                                            cache["conv"], cache["state"])
        new_cache.update(conv=conv, state=state)
    h = h + y
    if "cross" in p and "xk" in cache:
        hx = rms_norm(h, p["norm_x"], cfg.norm_eps)
        h = h + attn.cross_attn_apply(cfg, p["cross"], hx,
                                      (cache["xk"], cache["xv"]))
    if kind["mlp"] != "none":
        h2 = rms_norm(h, p["norm2"], cfg.norm_eps)
        if kind["mlp"] == "moe":
            y2, _ = moe_mod.moe_apply(cfg, p["mlp"], h2)
        else:
            y2 = swiglu_apply(p["mlp"], h2)
        h = h + y2
    return constrain(h, "dec"), new_cache


def block_decode(cfg, bp, h, bcache, index, *, slot_pos=None, window=None):
    new = []
    for p, kind, cache in zip(bp["layers"], cfg.block_pattern(),
                              bcache["layers"]):
        h, c = layer_decode(cfg, p, kind, h, cache, index, slot_pos=slot_pos,
                            window=window)
        new.append(c)
    return h, {"layers": tuple(new)}


def scan_blocks_decode(cfg, stacked, h, caches, index, *, slot_pos=None,
                       window=None):
    def body(h, xs):
        bp, bcache = xs
        h, newc = block_decode(cfg, bp, h, bcache, index, slot_pos=slot_pos,
                               window=window)
        return h, newc
    h, new_caches = jax.lax.scan(body, h, (stacked, caches),
                                 unroll=cfg.scan_unroll)
    return h, new_caches
