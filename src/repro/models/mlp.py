"""The paper's experimental model: a two-fully-connected-layer MLP for
(synthetic) MNIST, trained with FedAvg (Section V: "simple multi-layer
perceptron (MLP) model with two fully connected layers").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def mlp_init(key, n_in: int = 28 * 28, n_hidden: int = 64, n_out: int = 10,
             dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w1": dense_init(k1, (n_in, n_hidden), dtype),
        "b1": jnp.zeros((n_hidden,), dtype),
        "w2": dense_init(k2, (n_hidden, n_out), dtype),
        "b2": jnp.zeros((n_out,), dtype),
    }


def mlp_apply(params, x):
    """x (B, 784) -> logits (B, 10)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, batch):
    logits = mlp_apply(params, batch["x"])
    labels = batch["y"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def mlp_accuracy(params, x, y):
    return jnp.mean((jnp.argmax(mlp_apply(params, x), -1) == y).astype(jnp.float32))


from functools import partial


@partial(jax.jit, static_argnums=(4,))
def mlp_sgd_epoch(params, x, y, lr, batch_size: int = 50):
    """One epoch of mini-batch SGD over a client dataset (used by the
    federated client loop; dataset is padded to a multiple of batch_size)."""
    n = x.shape[0]
    nb = max(n // batch_size, 1)

    def body(params, i):
        xb = jax.lax.dynamic_slice_in_dim(x, i * batch_size, batch_size)
        yb = jax.lax.dynamic_slice_in_dim(y, i * batch_size, batch_size)
        g = jax.grad(mlp_loss)(params, {"x": xb, "y": yb})
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, 0.0

    params, _ = jax.lax.scan(body, params, jnp.arange(nb))
    return params


# ---------------------------------------------------------------------- #
# Masked variants — the vectorized cohort engine's contract: client
# datasets are zero-padded to a uniform length with a {0,1} validity mask;
# a padded sample must contribute *exactly* zero gradient so the padded run
# reproduces the unpadded one. For a fully valid batch the masked mean
# reduces to ``jnp.mean`` (mask sum == batch_size), so batches the plain
# epoch would see are numerically identical, and a fully padded batch is a
# strict no-op (zero gradient -> params unchanged bit-for-bit).
# ---------------------------------------------------------------------- #
def mlp_loss_masked(params, batch):
    """Mean cross-entropy over the valid samples of a batch.

    batch["m"] (B,) float validity mask; padding rows carry m == 0.
    """
    logits = mlp_apply(params, batch["x"])
    labels, m = batch["y"], batch["m"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.sum((logz - ll) * m) / jnp.maximum(jnp.sum(m), 1.0)


def mlp_accuracy_masked(params, x, y, m):
    """Accuracy over the valid samples only (0.0 when the mask is empty)."""
    correct = (jnp.argmax(mlp_apply(params, x), -1) == y).astype(jnp.float32)
    return jnp.sum(correct * m) / jnp.maximum(jnp.sum(m), 1.0)


@partial(jax.jit, static_argnums=(5,))
def mlp_sgd_epoch_masked(params, x, y, m, lr, batch_size: int = 50):
    """Masked twin of ``mlp_sgd_epoch`` over a padded client dataset.

    x (S, D), y (S,), m (S,) with S a multiple of batch_size; batches that
    fall entirely in the padding leave params untouched. The batch grid is
    a reshape (row-major, so batch i covers the same rows the plain epoch
    slices) scanned on the leading axis — cheaper to trace/compile under
    vmap than per-step dynamic slicing, with identical values.
    """
    n = x.shape[0]
    assert n % batch_size == 0, (
        f"padded length {n} must be a multiple of batch_size {batch_size} "
        "(pad_clients(multiple_of=batch_size) guarantees this)")
    nb = n // batch_size
    xb = x.reshape(nb, batch_size, -1)
    yb = y.reshape(nb, batch_size)
    mb = m.reshape(nb, batch_size)

    def body(params, batch):
        bx, by, bm = batch
        g = jax.grad(mlp_loss_masked)(params, {"x": bx, "y": by, "m": bm})
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, 0.0

    params, _ = jax.lax.scan(body, params, (xb, yb, mb))
    return params
