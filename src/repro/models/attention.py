"""GQA attention (train / prefill / decode) with optional QKV-bias, QK-norm,
sliding-window masks and ring-buffer decode caches.

All functions are pure; parameters are plain pytrees. The jnp path here is the
oracle; ``repro.kernels`` provides Pallas TPU implementations of the same math
(flash attention / flash decode) validated against this module.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, dtype_of, ones, rms_norm


# ---------------------------------------------------------------------- #
# Params
# ---------------------------------------------------------------------- #
def attn_init(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dt),
        "wk": dense_init(ks[1], (d, hkv * hd), dt),
        "wv": dense_init(ks[2], (d, hkv * hd), dt),
        "wo": dense_init(ks[3], (hq * hd, d), dt, fan_in=hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = ones((hd,), dt)
        p["k_norm"] = ones((hd,), dt)
    return p


def _project_qkv(cfg, p, x):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------- #
# Core scaled-dot-product with GQA grouping
# ---------------------------------------------------------------------- #
def sdpa(q, k, v, mask, scale: Optional[float] = None):
    """q (B,S,Hq,D), k/v (B,T,Hkv,D), mask broadcastable to (B,1,1,S,T)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq * D)


def causal_window_mask(S: int, window: Optional[int], offset=0):
    """(1,1,1,S,S) causal (+ optional sliding-window) mask."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window is not None:
        m &= (i - j) < window
    return m[None, None, None]


# ---------------------------------------------------------------------- #
# Full-sequence apply (train / prefill)
# ---------------------------------------------------------------------- #
def attn_apply(cfg, p, x, *, window=None, positions=None):
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(S)[None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = _full_attention(cfg, q, k, v, window)
    return out @ p["wo"], (k, v)


def _full_attention(cfg, q, k, v, window):
    """Dispatch between the jnp oracle and the Pallas flash kernel
    (REPRO_USE_PALLAS=1; on CPU the kernel runs in interpret mode)."""
    from repro.kernels import ops
    B, S = q.shape[:2]
    if ops.use_pallas() and S % 8 == 0:
        G = q.shape[2] // k.shape[2]
        kk = jnp.repeat(k, G, axis=2) if G > 1 else k
        vv = jnp.repeat(v, G, axis=2) if G > 1 else v
        out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
            vv.transpose(0, 2, 1, 3), causal=True, window=window,
            block_q=min(128, S), block_k=min(128, S))
        return out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    mask = causal_window_mask(S, window)
    return sdpa(q, k, v, mask)


def cross_attn_apply(cfg, p, x, kv_cache):
    """Decoder cross-attention; kv_cache = (k, v) from the encoder (no mask)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = kv_cache
    mask = jnp.ones((1, 1, 1, 1, k.shape[1]), bool)
    out = sdpa(q, k, v, mask)
    return out @ p["wo"]


def encoder_kv(cfg, p, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, S, _ = enc_out.shape
    hd = cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------- #
# Single-token decode
# ---------------------------------------------------------------------- #
def attn_decode(cfg, p, x, cache_k, cache_v, index, *, slot_pos=None,
                window=None):
    """One decode step.

    x (B,1,d); cache_k/v (B,C,Hkv,D) where C = max_seq (linear cache,
    slot_pos None) or C = window (ring buffer, slot_pos (C,) absolute
    positions of each slot, -1 when empty). ``index`` is the absolute position
    of the new token. Keys are stored *rotated* (RoPE applied at write time).
    Returns (y, new_k, new_v).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x)            # (B,1,H*,D)
    pos = jnp.full((B, 1), index)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    C = cache_k.shape[1]
    slot = index % C if slot_pos is not None else index
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)

    if slot_pos is not None:
        new_slot_pos = slot_pos.at[slot].set(index)
        valid = new_slot_pos >= 0
    else:
        j = jnp.arange(C)
        valid = j <= index
        if window is not None:
            valid &= j > index - window
        new_slot_pos = None
    mask = valid[None, None, None, None, :]      # (1,1,1,1,C)
    out = sdpa(q, cache_k, cache_v, mask)
    return out @ p["wo"], cache_k, cache_v, new_slot_pos
