"""Event-driven asynchronous FEEL engine (DESIGN.md §13, ROADMAP item 3).

The synchronous engine runs Alg. 1 as lockstep rounds: every scheduled UE's
upload lands before the next schedule is drawn. Real edge fleets trickle
in — the paper's own Eq. 5-7 cost model already prices a *per-UE* latency
(train time from the cycles/bit model + transmission time at the allocated
bandwidth fraction), the synchronous engine just never uses it as a clock.
This engine does:

    dispatch  — draw the next wave's schedule (the server's own
        ``_schedule_round``: either control plane, any policy) over the
        UEs with no upload in flight, train the whole wave at once from
        the CURRENT global params (the vectorized cohort engine is
        reused verbatim), and push one arrival event per scheduled UE at
        ``t_sim + latency`` where latency = (Eq. 6 train time + Eq. 7
        upload time at the wave's Eq. 9 bandwidth split) scaled by
        ``cfg.async_latency_scale``.
    arrive    — pop events in (arrival_time, dispatch_seq) order into the
        aggregation buffer, advancing the simulated clock.
    aggregate — on a trigger (buffer fill / deadline / drain, see below),
        FedAvg the buffered uploads with staleness-discounted weights
        ``sizes * decay**age`` (core/control.py::staleness_discount),
        where age = current aggregation version minus the version the
        upload was computed on. Aggregation bumps the model version,
        finalizes Eq. 1 reputation for exactly the aggregated UEs, logs a
        RoundLog, and immediately dispatches the next wave — cohort
        selection overlaps the still-in-flight training of earlier waves.

Triggers: ``cfg.async_buffer = B`` aggregates as soon as B uploads are
buffered; ``cfg.async_deadline = d`` also flushes a non-empty buffer at
dispatch_time + d sim-seconds; ``async_buffer=None`` waits for every
in-flight upload (the synchronous lockstep limit). A non-empty buffer
with an empty event heap and no deadline flushes as a "drain".

Busy masking: a UE with an upload in flight (heap or buffer) must not be
re-scheduled. Its channel gain is zeroed for the schedule draw
(``FeelServer._mask_unavailable`` — an arithmetic mask, not an RNG op, so
the host stream of record is untouched): zero gain makes Eq. 9 infeasible
(cost K+1) and every channel-aware packing skips it. Channel-blind
selections (``top_value``, the forced-round rewrite) are post-filtered on
the busy mask at dispatch.

Zero-latency oracle discipline (the engine's parity contract, pinned by
tests/test_async.py): at ``async_latency_scale = 0.0`` with per-wave
triggers (``async_buffer=None``, no deadline) every wave's uploads arrive
instantly in dispatch order — the event ordering key is (arrival_time,
dispatch_seq), so ties resolve to selection order — ages are all 0 where
``decay**0 == 1.0`` exactly, and each aggregation sees the same stacks
and weights bit-for-bit as the synchronous engine's round. mode="async"
then reproduces mode="sync" exactly, for both data engines, both control
planes and both tasks — the same oracle discipline as engine="loop" and
control="host".

The event clock is SIMULATED: it advances only by the Eq. 6/7 latency
model on seeded channel/compute draws. Wall-clock reads
(time.time/perf_counter/...) in this module are repro.check
nondeterminism violations (check/lints.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import control as ctl
from repro.federated import cohort
from repro.federated.server import FeelServer, RoundLog
from repro.obs import trace


@dataclasses.dataclass
class _Upload:
    """One in-flight upload: which UE, which dispatch wave produced it,
    which model version it was computed on, and its per-UE results."""
    ue: int
    wave: int
    version: int            # aggregation version of the params it trained on
    row: int                # row within the wave's stored uploads
    latency: float          # sim-seconds from dispatch to arrival
    acc_local: float
    acc_test: float
    acc_val: Optional[np.ndarray]   # (2,) detector column, None without one


@dataclasses.dataclass
class AggregationLog:
    """Per-aggregation async metadata, alongside the server's RoundLog."""
    version: int
    sim_time: float
    trigger: str            # 'wave' | 'buffer' | 'deadline' | 'drain'
    n_uploads: int
    ages: np.ndarray        # (n,) int staleness ages of the aggregated uploads
    discounts: np.ndarray   # (n,) staleness discounts applied to the weights
    waves: np.ndarray       # (n,) dispatch wave of each aggregated upload


class AsyncFeelEngine:
    """Drives a ``FeelServer`` through the event loop above. ``rounds``
    counts *aggregations* (model versions), the async analogue of rounds."""

    def __init__(self, server: FeelServer):
        assert server.cfg.mode == "async", \
            f"AsyncFeelEngine requires cfg.mode='async', got {server.cfg.mode!r}"
        cfg = server.cfg
        assert cfg.async_buffer is None or cfg.async_buffer >= 1, \
            cfg.async_buffer
        assert cfg.async_latency_scale >= 0.0, cfg.async_latency_scale
        self.server = server
        self.t_sim = 0.0                 # simulated clock (sim-seconds)
        self.version = 0                 # aggregations done == model version
        self.wave = 0                    # dispatches done
        self._seq = 0                    # global dispatch counter (tie-break)
        self._heap: List[Tuple[float, int, _Upload]] = []
        self._buffer: List[_Upload] = []
        # wave -> {"uploads", "weights", "left"}: a wave's trained stack is
        # kept until its last upload is aggregated (refcounted)
        self._store: Dict[int, Dict] = {}
        self._busy = np.zeros(cfg.n_population, bool)
        # latest dispatched plan — the schedule context the next RoundLog
        # reports (values/sched/forced of the most recent wave)
        self._plan = None
        self._dispatch_t = 0.0
        # Eq. 6 train times are round-invariant (sizes and cpu draws fixed)
        self._t_train = server.wireless.train_time(server.sizes,
                                                   server.cpu_hz)
        self.agg_logs: List[AggregationLog] = []

    # ------------------------------------------------------------------ #
    def _dispatch(self) -> None:
        """Schedule + train the next wave over the non-busy UEs and push
        its arrival events."""
        srv = self.server
        with trace.span("async.dispatch") as sp:
            srv.unavailable = self._busy.copy() if self._busy.any() else None
            try:
                values, sched, sel, forced = srv._schedule_round(self.wave)
            finally:
                srv.unavailable = None
            # channel-blind selections (top_value, the forced rewrite)
            # ignore the zeroed gains — drop busy UEs here
            sel = sel[~self._busy[sel]]
            self._plan = (values, sched, forced)
            self._dispatch_t = self.t_sim
            wave = self.wave
            self.wave += 1
            if trace.enabled():
                sp.set(wave=wave, n_selected=int(sel.size),
                       n_busy=int(self._busy.sum()))
            if sel.size == 0:
                return
            uploads, weights, acc_local, acc_test, acc_val = \
                srv._train_cohort(sel, wave)
            gains = srv.wireless.last_gains
            lat = (self._t_train[sel]
                   + srv.wireless.upload_time(gains, sched.alpha)[sel]) \
                * srv.cfg.async_latency_scale
            assert np.all(np.isfinite(lat)), \
                "non-finite upload latency for a scheduled UE"
            self._store[wave] = {"uploads": uploads, "weights": weights,
                                 "left": sel.size}
            self._busy[sel] = True
            for i, ue in enumerate(sel):
                e = _Upload(ue=int(ue), wave=wave, version=self.version,
                            row=i, latency=float(lat[i]),
                            acc_local=float(acc_local[i]),
                            acc_test=float(acc_test[i]),
                            acc_val=(None if acc_val is None
                                     else np.asarray(acc_val[:, i])))
                heapq.heappush(self._heap,
                               (self.t_sim + e.latency, self._seq, e))
                self._seq += 1
            if trace.enabled():
                trace.gauge_set("async.heap_depth", len(self._heap))

    # ------------------------------------------------------------------ #
    def _gather(self, entries: List[_Upload]):
        """(uploads, weights) of the buffered entries in arrival order,
        weights staleness-discounted. At zero latency this reduces to the
        identity gather on the single wave's stack — bit-equal inputs to
        the synchronous aggregation."""
        srv = self.server
        ages = np.array([self.version - e.version for e in entries])
        disc = ctl.staleness_discount(ages, srv.cfg.async_staleness)
        if srv.engine == "loop":
            uploads = [self._store[e.wave]["uploads"][e.row]
                       for e in entries]
            base = np.array([self._store[e.wave]["weights"][e.row]
                             for e in entries], float)
            return uploads, base * disc, ages, disc
        # vectorized: per-wave device gather of the real rows, merged back
        # into arrival order, re-padded to the stable row multiple
        n = len(entries)
        parts, w_parts, pos_parts = [], [], []
        for w in dict.fromkeys(e.wave for e in entries):
            pos = np.array([i for i, e in enumerate(entries)
                            if e.wave == w])
            rows = jnp.asarray(np.array([entries[i].row for i in pos]))
            st = self._store[w]
            parts.append(jax.tree.map(
                lambda l, idx=rows: jnp.take(l, idx, axis=0),
                st["uploads"]))
            w_parts.append(np.asarray(st["weights"])[np.asarray(rows)])
            pos_parts.append(pos)
        inv = np.argsort(np.concatenate(pos_parts), kind="stable")
        stacked = cohort.merge_stacks(parts, inv if len(parts) > 1 else None)
        n_pad = cohort.pad_count(n, FeelServer._N_BUCKET)
        stacked_p = cohort.pad_stacked(stacked, n_pad)
        weights = np.zeros(n_pad)
        weights[:n] = np.concatenate(w_parts)[inv] * disc
        return stacked_p, weights, ages, disc

    def _aggregate(self, trigger: str) -> RoundLog:
        """Flush the buffer into the global model: staleness-discounted
        FedAvg (or the defense plane's robust aggregator), Eq. 1
        finalization for the aggregated UEs, RoundLog + AggregationLog."""
        srv = self.server
        with trace.span("async.aggregate") as sp:
            entries, self._buffer = self._buffer, []
            assert entries, "aggregate called with an empty buffer"
            sel = np.array([e.ue for e in entries])
            uploads, weights, ages, disc = self._gather(entries)
            if trace.enabled():
                sp.set(version=self.version, trigger=trigger,
                       n_uploads=len(entries), mean_age=float(ages.mean()))
                for a in ages:
                    trace.observe("async.upload_age", float(a))
                trace.gauge_set("async.heap_depth", len(self._heap))
            srv._aggregate_uploads(sel, uploads, weights)
            for e in entries:
                st = self._store[e.wave]
                st["left"] -= 1
                if st["left"] == 0:
                    del self._store[e.wave]
            self._busy[sel] = False
            acc_local = np.array([e.acc_local for e in entries])
            acc_test = np.array([e.acc_test for e in entries])
            acc_val = (None if entries[0].acc_val is None
                       else np.stack([e.acc_val for e in entries], axis=1))
            g_acc, g_loss, src_acc, atk_succ = srv._global_metrics()
            values, sched, forced = self._plan
            log = srv._finalize_round(self.version, values, sched, sel,
                                      forced, acc_local, acc_test, g_acc,
                                      src_acc, atk_succ, acc_val, g_loss)
            self.agg_logs.append(AggregationLog(
                version=self.version, sim_time=self.t_sim, trigger=trigger,
                n_uploads=len(entries), ages=ages, discounts=disc,
                waves=np.array([e.wave for e in entries])))
            self.version += 1
            return log

    # ------------------------------------------------------------------ #
    def _trigger(self) -> bool:
        """Buffer-fill trigger: B uploads buffered, or — with
        ``async_buffer=None`` — the whole in-flight set has arrived."""
        if self.server.cfg.async_buffer is not None:
            return len(self._buffer) >= self.server.cfg.async_buffer
        return not self._heap

    def run(self, rounds: Optional[int] = None) -> List[RoundLog]:
        """Run until ``rounds`` aggregations (default cfg.rounds) and
        return the server's RoundLogs (one per aggregation)."""
        cfg = self.server.cfg
        n_agg = rounds or cfg.rounds
        # dual-clock discipline (DESIGN.md §14): while the event loop is
        # driving, every span records the simulated event clock alongside
        # the wall clock. Reading ``t_sim`` is telemetry-only — the sim
        # clock still advances exclusively via the Eq. 6/7 latency model.
        trace.set_sim_clock(lambda: self.t_sim)
        try:
            self._run(n_agg)
        finally:
            trace.set_sim_clock(None)
        return self.server.logs

    def _run(self, n_agg: int) -> None:
        cfg = self.server.cfg
        self._dispatch()
        while self.version < n_agg:
            deadline = (math.inf if cfg.async_deadline is None
                        else self._dispatch_t + cfg.async_deadline)
            if self._heap and (not self._buffer
                               or self._heap[0][0] <= deadline):
                t_arr, _, e = heapq.heappop(self._heap)
                self.t_sim = max(self.t_sim, t_arr)
                self._buffer.append(e)
                if not self._trigger():
                    continue
                trig = "buffer" if cfg.async_buffer is not None else "wave"
            elif self._buffer:
                # next arrival (if any) is past the deadline: flush what
                # has landed; with no deadline this is the drain case
                if math.isfinite(deadline):
                    self.t_sim = max(self.t_sim, deadline)
                    trig = "deadline"
                else:
                    trig = "drain"
            else:
                # unreachable: an empty heap+buffer means no UE is busy,
                # so the preceding dispatch scheduled at least one upload
                # (the forced-round rewrite guarantees a non-empty,
                # non-busy selection)
                raise AssertionError("async engine stalled: empty event "
                                     "heap and empty buffer")
            self._aggregate(trig)
            self._dispatch()
