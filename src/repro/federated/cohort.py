"""Vectorized cohort execution engine (Alg. 1, all scheduled UEs at once).

The paper trains every scheduled UE independently per round; the seed
implemented that as a sequential Python loop (`FeelServer.run_round` ->
`local_train`) that re-traced the per-client epoch for every distinct
client dataset size. Here the round's cohort is stacked into
(N, max_samples, ...) arrays (see ``data.partition.pad_clients`` for the
padding/masking contract) and all N local trainings run in ONE jitted,
vmapped program:

    cohort_train — vmap of (masked epochs + masked local metric) over the
        leading client axis; global params are broadcast in, per-client
        trained params come back stacked on axis 0, ready for
        ``fedavg_stacked`` / the Pallas ``weighted_aggregate`` kernel.
    cohort_eval  — one vmapped pass scoring every uploaded model on the
        (per-UE masked) public test set, replacing the server's per-model
        evaluation loop (Alg. 1 line 14).

The engine is task-generic (federated/task.py): the per-sample arrays are
a pytree ``data`` dict ({"x", "y"} feature/label arrays for the MNIST MLP,
{"tokens"} int32 windows for the LM task) and the per-client train/metric
steps are the TASK's jit-static methods — the vmap/scan/bucket machinery
never mentions a concrete model. Tasks are frozen dataclasses, so passing
them via ``static_argnames`` keys one compile cache entry per task.

Evaluation is over the task's prediction UNITS (test samples for MNIST,
next-token target positions for the LM): ``eval_inputs`` is the task's
device-side test pytree, ``y_units``/``masks`` are (U,)/(N, U) unit-level
labels and per-UE support masks.

Shapes are cohort-size dependent, so each distinct (N, max_samples) pair
compiles once and is cached for all later rounds; padding max_samples to a
round-stable value keeps the number of distinct shapes small.

Size-bucketed sub-cohorts: padding every client to the *global* maximum
wastes ~2x the real sample count under the paper's 1-30 group allocation,
so the server splits a round's cohort into 2-3 ``max_samples`` buckets
(``data.partition.bucket_levels`` — quantized so compiles stay cached),
trains each bucket with ``cohort_train``, and merges the per-bucket stacks
back into selection order (``merge_stacks``) for ONE ``fedavg_stacked``
call whose weights span all buckets. ``cohort_train_multi`` is the
multi-run variant (per-row parameters) used by the batched sweep runner in
``federated/simulation.py`` — seeds/policies become one more slice of the
client axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("task", "epochs", "batch_size"))
def cohort_train(task, params, data, mask, lr, epochs: int,
                 batch_size: int = 50):
    """Train the whole cohort in one vmapped step.

    task — the jit-static FeelTask whose ``sgd_epoch``/``local_metric``
    define the per-client step; params — global model (broadcast to every
    client); data — per-sample array pytree with leaves (N, S, ...),
    mask (N, S) — the padded, stacked cohort.
    Returns (stacked_params with leaves (N, ...), acc_local (N,)) where
    acc_local is each client's self-reported metric on its own (valid)
    samples after local training (Alg. 1 line 11).
    """
    def one(di, mi):
        # fori_loop (not Python unrolling) keeps the traced epoch body
        # single-copy — compile time is the cohort engine's main fixed cost
        p = jax.lax.fori_loop(
            0, epochs,
            lambda _, q: task.sgd_epoch(q, di, mi, lr, batch_size),
            params)
        return p, task.local_metric(p, di, mi)

    return jax.vmap(one)(data, mask)


@partial(jax.jit, static_argnames=("task", "epochs", "batch_size"))
def cohort_train_multi(task, stacked_params, data, mask, lr, epochs: int,
                       batch_size: int = 50):
    """``cohort_train`` with *per-client* parameters (leaves (N, ...)).

    The batched sweep runner's entry point: rows gathered from different
    runs (policy x seed x attack-pair) carry different global models, so the
    run axis folds into the client vmap axis — one compiled program trains
    an arbitrary mix of runs as long as the padded (N, S) shape matches.
    Row results are independent, so a row trains identically whether its
    run's cohort is stacked alone or with other runs.
    """
    def one(p, di, mi):
        q = jax.lax.fori_loop(
            0, epochs,
            lambda _, r: task.sgd_epoch(r, di, mi, lr, batch_size),
            p)
        return q, task.local_metric(q, di, mi)

    return jax.vmap(one)(stacked_params, data, mask)


def pad_count(n: int, multiple: int = 8) -> int:
    """Cohort-axis padding target: next power of two below ``multiple``
    (1, 2, 4), multiples of ``multiple`` above. Keeps the set of compiled
    cohort shapes small WITHOUT ballooning small sub-cohorts — padding a
    2-row bucket to 8 rows would quadruple its training work, which at
    small K costs more than size-bucketing saves."""
    assert n >= 1
    if n >= multiple:
        return -(-n // multiple) * multiple
    p = 1
    while p < n:
        p *= 2
    return p


def merge_stacks(stacked_list, order=None):
    """Concatenate per-bucket stacked pytrees on axis 0; ``order`` (optional
    int array) then permutes rows — the bucketed engine uses it to restore
    the schedule's selection order so FedAvg accumulates in exactly the
    order the loop oracle uses (bit-for-bit parity)."""
    merged = (stacked_list[0] if len(stacked_list) == 1 else
              jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0),
                           *stacked_list))
    if order is not None:
        idx = jnp.asarray(order)
        merged = jax.tree.map(lambda l: jnp.take(l, idx, axis=0), merged)
    return merged


def pad_stacked(stacked, n_total: int):
    """Zero-pad a stacked pytree's leading axis to ``n_total`` rows.

    Null rows get weight 0 in ``fedavg_stacked`` (exact +0.0 contribution)
    and an all-zero eval mask (score 0.0, discarded), so padding the cohort
    axis to a stable multiple keeps compiled eval/aggregate programs
    cache-hot without perturbing results.
    """
    def pad(l):
        n = l.shape[0]
        if n == n_total:
            return l
        return jnp.concatenate(
            [l, jnp.zeros((n_total - n,) + l.shape[1:], l.dtype)], axis=0)
    return jax.tree.map(pad, stacked)


def broadcast_params(params, n: int):
    """Tile a single parameter pytree to (n, ...) rows (sweep stacking)."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p, (n,) + p.shape),
                        params)


@partial(jax.jit, static_argnames=("task",))
def cohort_eval(task, stacked_params, eval_inputs, y_units, masks):
    """Score every uploaded model on the public test set in one vmap.

    stacked_params — leaves (N, ...); eval_inputs — the task's device-side
    test pytree; y_units (U,) — unit-level labels (test labels for MNIST,
    next-token targets for the LM); masks (N, U) — per-UE evaluation unit
    masks (the server restricts Eq. 1's acc_test to the symbols a UE
    claims to hold). Returns (N,) unit accuracies, 0.0 where a mask is
    empty.
    """
    def one(p, m):
        correct = (task.predict_units(p, eval_inputs)
                   == y_units).astype(jnp.float32)
        return jnp.sum(correct * m) / jnp.maximum(jnp.sum(m), 1.0)

    return jax.vmap(one)(stacked_params, masks)


@partial(jax.jit, static_argnames=("task",))
def cohort_eval_rows(task, stacked_params, eval_inputs, y_rows, masks):
    """``cohort_eval`` with per-row labels: y_rows (N, U).

    The sweep's metric phase uses it to score the attack success rate —
    a row whose unit labels are relabelled to the attack's target over
    the source mask — alongside the plain accuracy rows, in the same
    vmapped call.
    """
    def one(p, yr, m):
        correct = (task.predict_units(p, eval_inputs)
                   == yr).astype(jnp.float32)
        return jnp.sum(correct * m) / jnp.maximum(jnp.sum(m), 1.0)

    return jax.vmap(one)(stacked_params, y_rows, masks)


def unstack(stacked_params, i: int):
    """Extract client ``i``'s parameter pytree from the stacked cohort."""
    return jax.tree.map(lambda l: l[i], stacked_params)


# ---------------------------------------------------------------------- #
# telemetry probe surface (DESIGN.md §14)
# ---------------------------------------------------------------------- #
# The four jitted entry points of the data plane. The tracer's first-call
# probe (obs.trace.jit_cache_size before/after a call) splits compile
# from execute on the train spans, and the sweep/serve drivers snapshot
# the whole map as compile-cache gauges at end of run.
JITTED_ENTRY_POINTS = {
    "cohort_train": cohort_train,
    "cohort_train_multi": cohort_train_multi,
    "cohort_eval": cohort_eval,
    "cohort_eval_rows": cohort_eval_rows,
}


def cache_sizes() -> dict:
    """Compile-cache entry count per jitted entry point (-1 if the
    probe API is unavailable on this jax version)."""
    from repro.obs.trace import jit_cache_size
    return {k: jit_cache_size(f) for k, f in JITTED_ENTRY_POINTS.items()}
